"""Benchmark orchestrator — one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints `name,us_per_call,derived` CSV.  `bench_overhead` additionally
persists the end-to-end ingest result (events/sec, speedup vs the
per-event reference, equivalence verdict) to `BENCH_ingest.json` at the
repo root so the perf trajectory is tracked across PRs.  Paper mapping:
    bench_protocols   — Fig 4   (eager vs rendezvous regimes)
    bench_allreduce   — Fig 5   (Allreduce algorithm comparison)
    bench_comm_graph  — Fig 6 + Table II (comm graphs, top contenders)
    bench_misconfig   — Fig 7   (sharding-misconfiguration detection)
    bench_scale       — Fig 8   (profile vs fleet size)
    bench_overhead    — Table III (tracer overhead)
    bench_kernels     — kernels vs oracles (framework hot-spots)
    bench_roofline    — §Roofline table (reads results/sweep.json)
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from _util import REPO, emit  # noqa: E402

BENCHES = [
    "bench_protocols",
    "bench_allreduce",
    "bench_comm_graph",
    "bench_misconfig",
    "bench_scale",
    "bench_overhead",
    "bench_kernels",
    "bench_roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    names = [args.only] if args.only else BENCHES
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        try:
            mod = __import__(name)
            rows = mod.run()
            emit(rows)
        except Exception as e:  # keep the harness going; report at the end
            failures.append((name, repr(e)))
            print(f"{name}/FAILED,-1,{type(e).__name__}")
        else:
            if name == "bench_overhead":
                path = os.path.join(REPO, "BENCH_ingest.json")
                if os.path.exists(path):
                    print(f"# wrote {path}", file=sys.stderr)
    if failures:
        for name, err in failures:
            print(f"# FAILURE {name}: {err[:300]}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
