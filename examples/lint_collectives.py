"""Static collective lint walkthrough: catch comm bugs before any run.

    PYTHONPATH=src python examples/lint_collectives.py

Three passes of the `commcheck` static analyzer — no device, no jax:

  1. a clean synthetic trace and the committed `examples/hlo/` dumps
     (zero findings — the CI gate relies on this),
  2. a trace with ground-truth bugs spliced in by `synth.inject_comm_bugs`
     (every injected class must be flagged, ranked by bytes at risk),
  3. a sharding plan linted pre-trace via `lint_pspecs` against the mesh.

The same analysis drives `python -m repro.core.session lint` and the
findings section of every JSON/HTML report.
"""
import os

from repro.core import commcheck, synth
from repro.core.hlo_parser import parse_hlo_store
from repro.core.events import Trace
from repro.core.topology import MeshSpec

MESH = MeshSpec((2, 4), ("data", "model"))


def show(title, findings):
    print(f"\n== {title}: {len(findings)} finding(s)")
    for f in findings:
        where = f" @ {f.site}" if f.site else ""
        print(f"  [{f.severity}] {f.detector}{where}"
              f"  ({f.wasted_bytes/1e6:.2f} MB at risk)")


def main():
    # 1. clean sources come back empty
    clean = synth.synthetic_trace("clean", MESH, n_sites=400, seed=0)
    show("clean synthetic trace", commcheck.check_trace(clean, MESH))
    hlo_dir = os.path.join(os.path.dirname(__file__), "hlo")
    for fn in sorted(os.listdir(hlo_dir)):
        with open(os.path.join(hlo_dir, fn)) as f:
            store, stats = parse_hlo_store(f.read(), MESH.num_devices)
        tr = Trace.from_store(fn, MESH.shape, MESH.axes, MESH.num_devices,
                              store, op_stats=stats)
        show(f"examples/hlo/{fn}", commcheck.check_trace(tr, MESH))

    # 2. injected bugs: every class flagged, ground truth in `labels`
    buggy, labels = synth.inject_comm_bugs(MESH, n_sites=64, seed=0)
    findings = commcheck.check_trace(buggy, MESH)
    show("trace with injected bugs", findings)
    found = {f.detector for f in findings}
    assert set(labels.values()) <= found, (labels, found)
    print(f"   all {len(labels)} injected bug classes detected")

    # 3. pre-trace sharding lint (duck-typed specs, no jax import)
    sizes = {"data": 2, "model": 4}
    class PartitionSpec(tuple):        # stand-in for jax's, same shape
        pass
    plan = {
        "w1": PartitionSpec(("data", "model")),
        "w2": PartitionSpec(("model", "model")),      # axis used twice
        "w3": PartitionSpec(("expert", None)),        # axis not in mesh
    }
    shapes = {"w1": (128, 512), "w2": (64, 64), "w3": (32, 16)}
    show("sharding plan", commcheck.lint_pspecs(plan, sizes, shapes=shapes))


if __name__ == "__main__":
    main()
