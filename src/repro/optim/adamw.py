"""AdamW with global-norm clipping, configurable state dtypes (bf16 moment
storage for HBM-tight frontier configs) and optional gradient compression
for the cross-pod reduction (see distributed.compression)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"   # bfloat16 halves m/v HBM (llama3-405b)
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(cfg: AdamWConfig, params) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads, state, params
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    with jax.named_scope("optimizer"):
        count = state["count"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
            if cfg.clip_norm else jnp.float32(1.0)
        lr = schedule(cfg, count)
        b1, b2 = cfg.beta1, cfg.beta2
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)
        sdt = jnp.dtype(cfg.state_dtype)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            mhat = m32 / bc1
            vhat = v32 / bc2
            step = mhat / (jnp.sqrt(vhat) + cfg.eps)
            p32 = p.astype(jnp.float32)
            p_new = p32 - lr * (step + cfg.weight_decay * p32)
            return p_new.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
