"""Per-architecture smoke tests: reduced same-family config, one forward
(+ one train step for family representatives) on CPU; shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_ORDER, get_config, smoke_config
from repro.models import api

FAMILY_REPS = ["chatglm3-6b", "mixtral-8x22b", "falcon-mamba-7b",
               "hymba-1.5b", "whisper-tiny", "qwen2-vl-2b"]


@pytest.mark.parametrize("arch", ARCH_ORDER)
def test_smoke_forward(arch):
    cfg = smoke_config(get_config(arch))
    params = api.init_params(cfg, 0)
    batch = api.demo_batch(cfg, 2, 32)
    logits, aux = api.forward(cfg, params, batch, attn_impl="naive")
    B = 2
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = api.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    assert 1.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_smoke_train_step(arch):
    from repro.launch.presets import StepSettings
    from repro.launch.steps import make_train_step
    from repro.optim import AdamWConfig

    cfg = smoke_config(get_config(arch))
    params = api.init_params(cfg, 0)
    from repro.optim import adamw
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    opt = adamw.init(opt_cfg, params)
    step = make_train_step(cfg, opt_cfg, StepSettings(accum=2, remat="dots"))
    batch = api.demo_batch(cfg, 4, 32)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, q: float(jnp.abs(p - q).sum()),
                     params, new_params))
    assert delta > 0
    assert int(new_opt["count"]) == 1


@pytest.mark.parametrize("arch", ARCH_ORDER)
def test_exact_configs_match_assignment(arch):
    """The full (non-smoke) config carries the assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.num_experts, cfg.top_k) == (128, 8)
    if arch == "mixtral-8x22b":
        assert (cfg.num_experts, cfg.top_k) == (8, 2)
        assert cfg.window == 4096
    if arch == "gemma3-4b":
        ws = cfg.layer_windows()
        assert sum(1 for w in ws if w == 0) == 5          # 5 global layers
        assert all(w in (0, 1024) for w in ws)
    if arch in ("falcon-mamba-7b", "hymba-1.5b"):
        assert cfg.ssm_state == 16


def test_param_counts_in_published_range():
    """Total param counts should be near the published sizes."""
    expect = {"llama3-405b": 405e9, "mixtral-8x22b": 141e9,
              "qwen3-moe-235b-a22b": 235e9, "chatglm3-6b": 6.2e9,
              "falcon-mamba-7b": 7.3e9, "gemma3-4b": 4.3e9,
              "h2o-danube-3-4b": 4.0e9, "hymba-1.5b": 1.5e9,
              "qwen2-vl-2b": 1.5e9, "whisper-tiny": 37e6}
    for arch, want in expect.items():
        cfg = get_config(arch)
        got = api.param_count(cfg)
        if arch == "whisper-tiny":
            # position table deliberately sized for the assigned decode_32k
            # shape (real whisper: 448 target positions)
            got -= (cfg.source_len + cfg.max_positions - 448 - cfg.source_len) \
                * cfg.d_model
        assert abs(got - want) / want < 0.25, (arch, got, want)
