"""Columnar trace storage — struct-of-arrays over numpy.

The per-event dataclass list is the right *construction* format (the HLO
parser emits one `CollectiveEvent` per op site, the cost model annotates it
in place), but it is the wrong *aggregation* format: every Table II rollup,
comm-matrix assembly, and detector scan walks Python objects attribute by
attribute.  INAM-style cross-layer profilers solve this with columnar
stores; we do the same.  `TraceStore` holds one numpy array per numeric
field and interned categorical codes for the string fields (kind, link
class, semantic, ...), so aggregations become `np.bincount` over composite
codes instead of Python loops — 1-2 orders of magnitude faster at the
100k-event scale the paper's experiments produce.

`CollectiveEvent` remains the row view: `store.row(i)` / `store.rows()`
materialize dataclass rows, and `Trace` keeps exposing `.events` so every
existing consumer (detectors, renderers, diffing) is unaffected.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import CollectiveEvent

SCHEMA_VERSION = 1

# numeric columns: (name, dtype)
_NUM_COLS: Tuple[Tuple[str, object], ...] = (
    ("operand_bytes", np.int64),
    ("result_bytes", np.int64),
    ("multiplicity", np.int64),
    ("group_size", np.int64),
    ("num_groups", np.int64),
    ("channel_id", np.int64),          # -1 encodes None
    ("async_start", np.bool_),
    ("wire_bytes_per_device", np.float64),
    ("est_time_s", np.float64),
)

# interned string columns
_CAT_COLS: Tuple[str, ...] = (
    "kind", "link_class", "semantic", "protocol", "jax_prim", "scope",
    "dtype", "computation",
)


class Categorical:
    """An interned string column: int32 codes into a first-seen vocab."""

    __slots__ = ("codes", "vocab")

    def __init__(self, codes: np.ndarray, vocab: List[str]):
        self.codes = np.asarray(codes, dtype=np.int32)
        self.vocab = list(vocab)

    @classmethod
    def from_values(cls, values: Sequence[str]) -> "Categorical":
        index: Dict[str, int] = {}
        codes = np.empty(len(values), dtype=np.int32)
        for i, v in enumerate(values):
            code = index.get(v)
            if code is None:
                code = index[v] = len(index)
            codes[i] = code
        return cls(codes, list(index))

    def __len__(self) -> int:
        return len(self.codes)

    def value(self, i: int) -> str:
        return self.vocab[self.codes[i]]

    def values(self) -> List[str]:
        return [self.vocab[c] for c in self.codes]

    def mask_of(self, *labels: str) -> np.ndarray:
        """Boolean mask of rows whose value is one of `labels`."""
        want = {i for i, v in enumerate(self.vocab) if v in labels}
        if not want:
            return np.zeros(len(self.codes), dtype=bool)
        return np.isin(self.codes, np.fromiter(want, dtype=np.int32))

    def mask_prefix(self, prefixes: Tuple[str, ...]) -> np.ndarray:
        want = {i for i, v in enumerate(self.vocab) if v.startswith(prefixes)}
        if not want:
            return np.zeros(len(self.codes), dtype=bool)
        return np.isin(self.codes, np.fromiter(want, dtype=np.int32))


class TraceStore:
    """Struct-of-arrays event store backing a `Trace`.

    Numeric fields are numpy columns; string fields are `Categorical`
    (codes + vocab); the irregular per-row payloads (replica groups,
    permute pairs, mesh axes, names) stay as Python lists — they are only
    touched at row-materialization and comm-matrix-edge-build time.
    """

    def __init__(self, n: int, num: Dict[str, np.ndarray],
                 cat: Dict[str, Categorical],
                 names: List[str], op_names: List[str],
                 axes: List[Tuple[str, ...]],
                 replica_groups: List[List[List[int]]],
                 source_target_pairs: List[Optional[List[Tuple[int, int]]]]):
        self.n = n
        for col, _dt in _NUM_COLS:
            setattr(self, col, num[col])
        for col in _CAT_COLS:
            setattr(self, col, cat[col])
        self.names = names
        self.op_names = op_names
        self.axes = axes
        self.replica_groups = replica_groups
        self.source_target_pairs = source_target_pairs
        self._edges: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None

    # ---- construction ------------------------------------------------------

    @classmethod
    def from_events(cls, events: Iterable[CollectiveEvent]) -> "TraceStore":
        evs = list(events)
        n = len(evs)
        num = {col: np.fromiter(
            ((-1 if e.channel_id is None else e.channel_id) if col == "channel_id"
             else getattr(e, col) for e in evs),
            dtype=dt, count=n) for col, dt in _NUM_COLS}
        cat = {col: Categorical.from_values([getattr(e, col) for e in evs])
               for col in _CAT_COLS}
        return cls(
            n, num, cat,
            names=[e.name for e in evs],
            op_names=[e.op_name for e in evs],
            axes=[tuple(e.axes) for e in evs],
            replica_groups=[e.replica_groups for e in evs],
            source_target_pairs=[e.source_target_pairs for e in evs])

    # ---- row views ---------------------------------------------------------

    def row(self, i: int) -> CollectiveEvent:
        """Materialize row `i` as the classic dataclass view."""
        ch = int(self.channel_id[i])
        return CollectiveEvent(
            name=self.names[i],
            kind=self.kind.value(i),
            async_start=bool(self.async_start[i]),
            operand_bytes=int(self.operand_bytes[i]),
            result_bytes=int(self.result_bytes[i]),
            dtype=self.dtype.value(i),
            replica_groups=self.replica_groups[i],
            group_size=int(self.group_size[i]),
            num_groups=int(self.num_groups[i]),
            op_name=self.op_names[i],
            computation=self.computation.value(i),
            multiplicity=int(self.multiplicity[i]),
            channel_id=None if ch < 0 else ch,
            source_target_pairs=self.source_target_pairs[i],
            link_class=self.link_class.value(i),
            axes=self.axes[i],
            semantic=self.semantic.value(i),
            jax_prim=self.jax_prim.value(i),
            scope=self.scope.value(i),
            protocol=self.protocol.value(i),
            wire_bytes_per_device=float(self.wire_bytes_per_device[i]),
            est_time_s=float(self.est_time_s[i]))

    def rows(self) -> List[CollectiveEvent]:
        return [self.row(i) for i in range(self.n)]

    # ---- derived columns ---------------------------------------------------

    @property
    def weights(self) -> np.ndarray:
        """Execution multiplicity as float (the x-loop-trip-count weight)."""
        return self.multiplicity.astype(np.float64)

    @property
    def wire_total(self) -> np.ndarray:
        """Per-site total wire bytes (per execution), all participants."""
        return (self.wire_bytes_per_device * self.group_size.astype(np.float64)
                * self.num_groups.astype(np.float64))

    # ---- vectorized aggregates --------------------------------------------

    def total_collective_bytes(self) -> float:
        return float(np.dot(self.operand_bytes.astype(np.float64), self.weights))

    def total_wire_bytes(self) -> float:
        return float(np.dot(self.wire_total, self.weights))

    def total_est_time_s(self) -> float:
        return float(np.dot(self.est_time_s, self.weights))

    def overlapped_est_time_s(self) -> float:
        if self.n == 0:
            return 0.0
        per_class = np.bincount(self.link_class.codes,
                                weights=self.est_time_s * self.weights,
                                minlength=len(self.link_class.vocab))
        return float(per_class.max())

    def _aggregate(self, inv: np.ndarray, labels: List[str]
                   ) -> Dict[str, Dict[str, float]]:
        """{label: {bytes, wire_bytes, count, time_s}} via bincount."""
        nb = len(labels)
        w = self.weights
        b = np.bincount(inv, weights=self.operand_bytes * w, minlength=nb)
        wire = np.bincount(inv, weights=self.wire_total * w, minlength=nb)
        c = np.bincount(inv, weights=w, minlength=nb)
        t = np.bincount(inv, weights=self.est_time_s * w, minlength=nb)
        return {labels[i]: {"bytes": float(b[i]), "wire_bytes": float(wire[i]),
                            "count": float(c[i]), "time_s": float(t[i])}
                for i in range(nb)}

    def _join_codes(self, cats: Sequence[Categorical], sep: str = "|"
                    ) -> Tuple[np.ndarray, List[str]]:
        """Composite key codes over several categoricals (occurring only)."""
        if self.n == 0:
            return np.empty(0, dtype=np.int64), []
        combo = np.zeros(self.n, dtype=np.int64)
        for cat in cats:
            combo = combo * len(cat.vocab) + cat.codes
        uniq, inv = np.unique(combo, return_inverse=True)
        labels = []
        for code in uniq:
            parts = []
            for cat in reversed(cats):
                code, r = divmod(code, len(cat.vocab))
                parts.append(cat.vocab[r])
            labels.append(sep.join(reversed(parts)))
        return inv, labels

    def by_kind_and_link(self) -> Dict[str, Dict[str, float]]:
        inv, labels = self._join_codes((self.kind, self.link_class))
        return self._aggregate(inv, labels)

    def by_semantic(self) -> Dict[str, Dict[str, float]]:
        # empty semantic rolls up as "other" (matches the per-event path)
        mapped = [v or "other" for v in self.semantic.vocab]
        remap_index: Dict[str, int] = {}
        remap = np.empty(max(len(mapped), 1), dtype=np.int64)
        merged: List[str] = []
        for i, lab in enumerate(mapped):
            if lab not in remap_index:
                remap_index[lab] = len(merged)
                merged.append(lab)
            remap[i] = remap_index[lab]
        if self.n == 0:
            return {}
        codes = remap[self.semantic.codes]
        uniq, inv = np.unique(codes, return_inverse=True)
        labels = [merged[c] for c in uniq]
        return self._aggregate(inv, labels)

    def by_sem_kind_link(self) -> Dict[str, Dict[str, float]]:
        inv, labels = self._join_codes(
            (self.semantic, self.kind, self.link_class))
        return self._aggregate(inv, labels)

    # ---- comm-matrix edges -------------------------------------------------

    def ring_edges(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Directed (src, dst, bytes) edge arrays for the comm matrix.

        Ring collectives contribute neighbor edges within each replica
        group; permutes follow their explicit source->target pairs.  Built
        once per store and cached — `np.add.at` scatters the whole edge
        list in one call.
        """
        if self._edges is None:
            srcs: List[np.ndarray] = []
            dsts: List[np.ndarray] = []
            ws: List[np.ndarray] = []
            for i in range(self.n):
                mult = float(self.multiplicity[i])
                stp = self.source_target_pairs[i]
                if stp:
                    pairs = np.asarray(stp, dtype=np.int64)
                    srcs.append(pairs[:, 0])
                    dsts.append(pairs[:, 1])
                    ws.append(np.full(len(pairs),
                                      float(self.operand_bytes[i]) * mult))
                    continue
                per_link = float(self.wire_bytes_per_device[i]) * mult
                for group in self.replica_groups[i]:
                    if len(group) <= 1:
                        continue
                    arr = np.asarray(group, dtype=np.int64)
                    srcs.append(arr)
                    dsts.append(np.roll(arr, -1))
                    ws.append(np.full(len(arr), per_link))
            if srcs:
                self._edges = (np.concatenate(srcs), np.concatenate(dsts),
                               np.concatenate(ws))
            else:
                z = np.empty(0, dtype=np.int64)
                self._edges = (z, z.copy(), np.empty(0, dtype=np.float64))
        return self._edges

    # ---- serialization -----------------------------------------------------

    def to_dict(self) -> Dict[str, object]:
        """Compact JSON-able dict (exact integer round-trip)."""
        return {
            "version": SCHEMA_VERSION,
            "n": self.n,
            "num": {col: getattr(self, col).tolist() for col, _ in _NUM_COLS},
            "cat": {col: {"vocab": getattr(self, col).vocab,
                          "codes": getattr(self, col).codes.tolist()}
                    for col in _CAT_COLS},
            "names": self.names,
            "op_names": self.op_names,
            "axes": [list(a) for a in self.axes],
            "replica_groups": self.replica_groups,
            "source_target_pairs": [
                None if p is None else [list(pair) for pair in p]
                for p in self.source_target_pairs],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "TraceStore":
        if d.get("version") != SCHEMA_VERSION:
            raise ValueError(f"unknown TraceStore schema: {d.get('version')!r}")
        n = int(d["n"])
        num = {col: np.asarray(d["num"][col], dtype=dt).reshape(n)
               for col, dt in _NUM_COLS}
        cat = {col: Categorical(
                   np.asarray(d["cat"][col]["codes"], dtype=np.int32).reshape(n),
                   list(d["cat"][col]["vocab"]))
               for col in _CAT_COLS}
        return cls(
            n, num, cat,
            names=list(d["names"]),
            op_names=list(d["op_names"]),
            axes=[tuple(a) for a in d["axes"]],
            replica_groups=[[list(map(int, g)) for g in rgs]
                            for rgs in d["replica_groups"]],
            source_target_pairs=[
                None if p is None else [(int(a), int(b)) for a, b in p]
                for p in d["source_target_pairs"]])

    def npz_arrays(self, prefix: str = "") -> Dict[str, np.ndarray]:
        """Flat array dict for `np.savez_compressed` (no object arrays).

        Numeric and code columns go in natively; the irregular payloads
        (names, groups, pairs, vocabs) ride in one JSON side-car string —
        they are small relative to the columns and compress well.
        """
        arrs: Dict[str, np.ndarray] = {}
        for col, _dt in _NUM_COLS:
            arrs[f"{prefix}{col}"] = getattr(self, col)
        for col in _CAT_COLS:
            arrs[f"{prefix}cat_{col}"] = getattr(self, col).codes
        side = {
            "version": SCHEMA_VERSION,
            "n": self.n,
            "vocab": {col: getattr(self, col).vocab for col in _CAT_COLS},
            "names": self.names,
            "op_names": self.op_names,
            "axes": [list(a) for a in self.axes],
            "replica_groups": self.replica_groups,
            "source_target_pairs": [
                None if p is None else [list(pair) for pair in p]
                for p in self.source_target_pairs],
        }
        arrs[f"{prefix}meta"] = np.array(json.dumps(side))
        return arrs

    @classmethod
    def from_npz_arrays(cls, arrs, prefix: str = "") -> "TraceStore":
        side = json.loads(str(arrs[f"{prefix}meta"]))
        if side.get("version") != SCHEMA_VERSION:
            raise ValueError(f"unknown TraceStore schema: {side.get('version')!r}")
        n = int(side["n"])
        num = {col: np.asarray(arrs[f"{prefix}{col}"], dtype=dt).reshape(n)
               for col, dt in _NUM_COLS}
        cat = {col: Categorical(
                   np.asarray(arrs[f"{prefix}cat_{col}"],
                              dtype=np.int32).reshape(n),
                   list(side["vocab"][col]))
               for col in _CAT_COLS}
        return cls(
            n, num, cat,
            names=list(side["names"]),
            op_names=list(side["op_names"]),
            axes=[tuple(a) for a in side["axes"]],
            replica_groups=[[list(map(int, g)) for g in rgs]
                            for rgs in side["replica_groups"]],
            source_target_pairs=[
                None if p is None else [(int(a), int(b)) for a, b in p]
                for p in side["source_target_pairs"]])
