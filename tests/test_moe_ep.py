"""Sort-based EP dispatch vs the einsum baseline (numerical equivalence
with a no-drop capacity factor, on a real mesh)."""


def test_sort_dispatch_matches_einsum(subproc):
    out = subproc("""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import ARCHS, smoke_config
from repro.distributed.autoshard import activation_sharding
from repro.models import api, moe as moe_mod
from repro.models.meta import materialize

cfg = smoke_config(ARCHS["qwen3-moe-235b-a22b"]).replace(
    num_experts=8, top_k=2,
    capacity_factor=4.0)     # = E/k: no drops in either path
mesh = jax.make_mesh((2, 4), ("data", "model"))

meta = moe_mod.moe_meta(cfg)
params = materialize(meta, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model),
                      jnp.float32).astype(jnp.bfloat16)

def run(dispatch):
    c = cfg.replace(moe_dispatch=dispatch)
    def f(p, xx):
        y, aux = moe_mod.apply_moe(c, p, xx)
        return y, aux
    with activation_sharding(mesh):
        xd = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        pd = jax.device_put(params, NamedSharding(mesh, P()))
        y, aux = jax.jit(f)(pd, xd)
    return np.asarray(y, np.float32), float(aux)

y_e, aux_e = run("einsum")
y_s, aux_s = run("sort")
err = np.max(np.abs(y_e - y_s)) / (np.abs(y_e).max() + 1e-6)
print("REL_ERR", err, "AUX", aux_e, aux_s)
assert err < 0.03, err
assert abs(aux_e - aux_s) < 0.2, (aux_e, aux_s)

# gradients flow through the sort path
def loss(p):
    c = cfg.replace(moe_dispatch="sort")
    with activation_sharding(mesh):
        y, aux = moe_mod.apply_moe(c, p, x)
    return (y.astype(jnp.float32) ** 2).mean() + 0.01 * aux
g = jax.grad(loss)(params)
gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
assert np.isfinite(gn) and gn > 0
print("GRAD_OK", gn)
print("MOE_EP_OK")
""")
    assert "MOE_EP_OK" in out
