from repro.training.watchdog import StragglerWatchdog, StepStats

__all__ = ["StragglerWatchdog", "StepStats"]
