"""Selective-scan (Mamba-1) Pallas kernel (TPU target; interpret-validated).

TPU-native layout (not a port of the CUDA scan):
  * inputs are the discretized terms a_bar, bx [B, S, Di, N] and the readout
    c [B, S, N] (computed by dense einsums outside — those are MXU work and
    XLA handles them well; the *scan* is the part XLA does badly),
  * grid (B, n_chunks, Di/blk): the chunk axis is sequential; the recurrent
    state h [blk, N] lives in VMEM scratch and never touches HBM between
    chunks — the XLA path writes the full [B, S, Di, N] h history,
  * within a chunk the recurrence runs as a fori_loop of VPU ops over
    timesteps; channels (Di x N = 8192 x 16 for falcon-mamba) provide the
    vector parallelism, matching the v5e 8x128 VREG shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, bx_ref, c_ref, y_ref, h_ref, *, chunk: int):
    ci = pl.program_id(2)   # chunk axis is innermost (sequential, carries h)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        a_t = a_ref[0, t]                      # [blk, N]
        bx_t = bx_ref[0, t]
        c_t = c_ref[0, t]                      # [1, N]
        h = a_t * h + bx_t
        y_ref[0, t] = (h * c_t).sum(axis=-1).astype(y_ref.dtype)   # [blk]
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h


def mamba_scan(a_bar, bx, c, *, chunk=256, di_block=512, interpret=False):
    """h_t = a_t * h_{t-1} + bx_t;  y_t[d] = sum_n h_t[d,n] * c_t[n].

    a_bar, bx: [B, S, Di, N] fp32;  c: [B, S, N] fp32  ->  y [B, S, Di] fp32.
    """
    B, S, Di, N = a_bar.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    di_block = min(di_block, Di)
    while Di % di_block:
        di_block //= 2
    n_chunks = S // chunk
    n_di = Di // di_block

    grid = (B, n_di, n_chunks)   # chunks innermost: h carried across them
    kernel = functools.partial(_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, di_block, N),
                         lambda b, di, ci: (b, ci, di, 0)),
            pl.BlockSpec((1, chunk, di_block, N),
                         lambda b, di, ci: (b, ci, di, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, di, ci: (b, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, di_block),
                               lambda b, di, ci: (b, ci, di)),
        out_shape=jax.ShapeDtypeStruct((B, S, Di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((di_block, N), jnp.float32)],
        interpret=interpret,
    )(a_bar, bx, c[:, :, None, :])
    return y
