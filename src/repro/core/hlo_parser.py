"""HLO text parser: extract collective ops, shapes, replica groups, metadata.

This is the UCT-interception analogue.  UCX chooses transports at runtime so
ucTrace hooks the send functions; XLA chooses collectives at compile time so
we read them out of ``compiled.as_text()`` — an *exact* record of every
transfer the step will execute, including:

  * sync and async (`-start`/`-done`) collective forms,
  * iota (`[G,S]<=[dims]T(perm)`) and explicit (`{{0,1},..}`) replica groups,
  * per-op `metadata={op_name="..."}` — the compiled-in call-stack analogue,
  * while-loop trip counts, so collectives inside `lax.scan` bodies are
    counted `trip_count` times (log-processing analogue of matching
    repeated sends).
"""
from __future__ import annotations

import os
import re
import sys
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import CollectiveEvent, HloOpStats
from repro.core.topology import resolve_iota_groups

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_COMMENT_RE = re.compile(r"/\*.*?\*/")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_METADATA_RE = re.compile(r'op_name="([^"]*)"')
_CHANNEL_RE = re.compile(r"channel_id=(\d+)")
_IOTA_RG_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_EXPLICIT_RG_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)?\}")
_STP_RE = re.compile(r"source_target_pairs=\{(\{[^}]*\}(?:,\{[^}]*\})*)?\}")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")


def parse_type_bytes(type_str: str) -> Tuple[int, str]:
    """Total bytes + primary dtype of a (possibly tuple) HLO type string."""
    total = 0
    dtype = ""
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        count = 1
        if dims:
            for d in dims.split(","):
                count *= int(d)
        total += count * DTYPE_BYTES[dt]
        if not dtype:
            dtype = dt
    return total, dtype


@dataclass
class _Computation:
    name: str
    lines: List[str] = field(default_factory=list)


_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _line_scope(line: str) -> str:
    """Deepest named_scope component of the op's metadata (module label)."""
    md = _METADATA_RE.search(line)
    if not md:
        return ""
    from repro.core.attribution import split_op_name
    scope, _prim = split_op_name(md.group(1))
    return scope


def _dot_flops(line: str, type_str: str, shapes: Dict[str, str]) -> float:
    """FLOPs of one dot: 2 x prod(result dims) x prod(lhs contracting dims)."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0.0
    out_elems = 1
    if m.group(2):
        for d in m.group(2).split(","):
            out_elems *= int(d)
    cm = _DOT_DIMS_RE.search(line)
    contract = 1
    if cm is not None:
        # lhs operand shape
        rest = line.split("dot(", 1)[1]
        ops = _OPERANDS_RE.findall(rest.split(")")[0])
        if ops:
            lhs_type = shapes.get(ops[0], "")
            sm = _SHAPE_RE.search(lhs_type)
            if sm and sm.group(2):
                dims = [int(x) for x in sm.group(2).split(",")]
                idxs = [int(x) for x in cm.group(1).split(",")] if cm.group(1) else []
                for i in idxs:
                    if i < len(dims):
                        contract *= dims[i]
    return 2.0 * out_elems * contract


def _split_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    current: Optional[_Computation] = None
    entry_name = None
    for line in text.splitlines():
        stripped = line.strip()
        # computation headers: `[ENTRY] %name (params...) -> type {`
        if stripped.endswith("{") and "->" in stripped and "=" not in \
                stripped.split("(")[0]:
            head = stripped
            is_entry = head.startswith("ENTRY")
            if is_entry:
                head = head[len("ENTRY"):].lstrip()
            name = head.split("(")[0].strip().lstrip("%").strip()
            if name:
                current = _Computation(name)
                comps[name] = current
                if is_entry:
                    entry_name = name
                continue
        if current is not None:
            current.lines.append(line)
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond_comp: _Computation) -> int:
    """Heuristic while-loop trip count: largest int constant in condition."""
    best = 1
    for line in cond_comp.lines:
        for m in _CONST_INT_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def _call_edges(comps: Dict[str, _Computation]
                ) -> Dict[str, List[Tuple[str, int]]]:
    """(caller -> [(callee, trip_factor)]) — extracted in ONE pass over the
    lines (with substring prescreens), so the fixpoint propagation below
    iterates over the tiny call graph instead of re-regexing every line."""
    edges: Dict[str, List[Tuple[str, int]]] = {}
    tc_cache: Dict[str, int] = {}
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        es: List[Tuple[str, int]] = []
        for line in comp.lines:
            if "while(" in line:
                wm = _WHILE_RE.search(line)
                cm = _COND_RE.search(line)
                if wm and cm:
                    cname = cm.group(1)
                    tc = tc_cache.get(cname)
                    if tc is None:
                        cond = comps.get(cname)
                        tc = tc_cache[cname] = \
                            _trip_count(cond) if cond else 1
                    es.append((wm.group(1), tc))
                    es.append((cname, tc))
                    continue
            if "calls=" in line or "to_apply=" in line:
                for rx in (_CALLS_RE, _TO_APPLY_RE):
                    m = rx.search(line)
                    if m:
                        es.append((m.group(1), 1))
        if es:
            edges[name] = es
    return edges


def _multiplicities(comps: Dict[str, _Computation]) -> Dict[str, int]:
    """Execution multiplicity per computation (while bodies x trip count)."""
    entry = comps.get("__entry__")
    if entry is None:
        return {name: 1 for name in comps}
    edges = _call_edges(comps)
    mult: Dict[str, int] = {entry.name: 1}

    # propagate through call sites breadth-first
    changed = True
    passes = 0
    while changed and passes < 50:
        changed = False
        passes += 1
        for name in comps:
            if name == "__entry__" or name not in mult:
                continue
            base = mult[name]
            for callee, k in edges.get(name, ()):
                new = base * k
                if callee in comps and mult.get(callee, 0) < new:
                    mult[callee] = new
                    changed = True
    return mult


def _parse_replica_groups(line: str, num_devices: int) -> List[List[int]]:
    m = _IOTA_RG_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) else None
        try:
            return resolve_iota_groups(g, s, dims, perm)
        except ValueError:
            # malformed iota attr (bad dims product / transpose perm):
            # degrade to a full-range group rather than abort the ingest
            return [list(range(num_devices))]
    m = _EXPLICIT_RG_RE.search(line)
    if m:
        body = m.group(1)
        if not body:
            return [list(range(num_devices))]
        groups = []
        for grp in re.findall(r"\{([^}]*)\}", body):
            if grp.strip():
                groups.append([int(x) for x in grp.split(",")])
        return groups or [list(range(num_devices))]
    return [list(range(num_devices))]


def _parse_stp(line: str) -> Optional[List[Tuple[int, int]]]:
    m = _STP_RE.search(line)
    if not m or not m.group(1):
        return None
    pairs = []
    for grp in re.findall(r"\{([^}]*)\}", m.group(1)):
        a, b = grp.split(",")
        pairs.append((int(a), int(b)))
    return pairs


_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id",
               "iota", "reshape"}
# elementwise/cheap ops: on TPU these fuse into producers/consumers, so
# counting their operands would massively over-state HBM traffic (the
# CPU host backend fuses far less aggressively than the TPU pipeline).
_FUSED_ON_TPU = {
    "add", "subtract", "multiply", "divide", "negate", "abs", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "rsqrt", "sqrt", "tanh",
    "logistic", "sign", "cosine", "sine", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "maximum", "minimum", "compare", "select", "and",
    "or", "not", "xor", "clamp", "convert", "broadcast", "power", "is-finite",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "atan2",
    "remainder", "map", "reverse", "real", "imag", "erf", "expm1", "log1p",
    "popcnt", "clz", "slice", "pad", "concatenate", "copy", "transpose",
    "reduce", "broadcast-in-dim", "stochastic-convert", "cbrt",
}


def _scan_stats(line: str, lm_groups, m: int, stats: HloOpStats,
                shapes: Dict[str, str], kinds: Dict[str, str],
                in_fusion_body: bool) -> bool:
    """Per-line stats accumulation (both parse paths share this).

    Returns True when the line is fully consumed (transpose / fusion /
    convert / reshape bookkeeping ops) — collective extraction only
    proceeds when this returns False.
    """
    _op_result, type_str, op_kind, rest = lm_groups

    if op_kind == "dot":
        fl = _dot_flops(line, type_str, shapes) * m
        stats.flops += fl
        sc = _line_scope(line)
        stats.flops_by_scope[sc] = stats.flops_by_scope.get(sc, 0.0) + fl

    # HBM-traffic estimate: each materialized tensor is written once
    # (result bytes) and read about once downstream; parameter
    # (weight) operands are charged at the consuming op.  Counting
    # operand bytes of every op would double-count each fusion
    # boundary and inflate traffic ~10x at CPU-fusion granularity.
    if (not in_fusion_body and op_kind not in _NO_TRAFFIC
            and op_kind not in _FUSED_ON_TPU):
        rb, _ = parse_type_bytes(type_str)
        pb = 0
        for op_ref in _OPERANDS_RE.findall(rest.split(")")[0]):
            if kinds.get(op_ref) == "parameter":
                b, _d = parse_type_bytes(shapes.get(op_ref, ""))
                pb += b
        tb = (2 * rb + pb) * m
        stats.bytes_accessed += tb
        sc = _line_scope(line)
        stats.bytes_by_scope[sc] = stats.bytes_by_scope.get(sc, 0.0) + tb

    if op_kind in ("transpose", "copy") or op_kind.startswith("transpose"):
        stats.n_transpose += 1
        b, _ = parse_type_bytes(type_str)
        stats.transpose_bytes += b * m
        return True
    if op_kind == "fusion":
        stats.n_fusion += 1
        return True
    if op_kind == "convert":
        stats.n_convert += 1
        return True
    if op_kind in ("reshape", "bitcast"):
        stats.n_reshape += 1
        return True
    return False


def parse_hlo(text: str, num_devices: int) -> Tuple[List[CollectiveEvent], HloOpStats]:
    """Extract collective events (+program stats) from compiled HLO text.

    This is the per-event *reference* path (one `CollectiveEvent` per op
    site); `parse_hlo_store` below is the batched fast path that emits the
    same records straight into columnar form.  Equivalence between the two
    is pinned by tests/test_ingest.py.

    Also accumulates *loop-aware* FLOP and traffic totals (stats.flops /
    stats.bytes_accessed): `compiled.cost_analysis()` counts while-loop
    bodies ONCE, so for a scan-over-layers program it under-reports compute
    by ~num_layers x.  We re-derive both, multiplying by trip counts.
    """
    comps = _split_computations(text)
    mult = _multiplicities(comps)
    events: List[CollectiveEvent] = []
    stats = HloOpStats()

    # symbol tables (per computation) for operand-shape lookups, and the set
    # of fusion-body computations (excluded from byte accounting: their
    # traffic is the fusion op's operands/results at the call site).
    shapes_by_comp: Dict[str, Dict[str, str]] = {}
    kinds_by_comp: Dict[str, Dict[str, str]] = {}
    fusion_bodies: set = set()
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        table: Dict[str, str] = {}
        kinds: Dict[str, str] = {}
        for line in comp.lines:
            line = _COMMENT_RE.sub("", line)
            lm = _OPLINE_RE.match(line)
            if lm:
                table[lm.group(1)] = lm.group(2)
                kinds[lm.group(1)] = lm.group(3)
                if lm.group(3) == "fusion":
                    fm = _CALLS_RE.search(line)
                    if fm:
                        fusion_bodies.add(fm.group(1))
        shapes_by_comp[name] = table
        kinds_by_comp[name] = kinds

    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1)
        shapes = shapes_by_comp.get(name, {})
        kinds = kinds_by_comp.get(name, {})
        in_fusion_body = name in fusion_bodies
        for line in comp.lines:
            line = _COMMENT_RE.sub("", line)
            lm = _OPLINE_RE.match(line)
            if not lm:
                continue
            if _scan_stats(line, lm.groups(), m, stats, shapes, kinds,
                           in_fusion_body):
                continue
            op_result, type_str, op_kind, rest = lm.groups()

            base = op_kind[:-6] if op_kind.endswith("-start") else op_kind
            if base not in COLLECTIVE_KINDS:
                continue
            if op_kind.endswith("-done"):
                continue

            result_bytes, dtype = parse_type_bytes(type_str)
            # operand bytes: for -start forms the result is a (operand, result)
            # tuple; approximate operand size from the paren list shapes if
            # present, else from result type arithmetic.
            operand_bytes = _operand_bytes(rest, type_str, base, line)
            groups = _parse_replica_groups(line, num_devices)
            stp = _parse_stp(line) if base == "collective-permute" else None
            md = _METADATA_RE.search(line)
            ch = _CHANNEL_RE.search(line)
            gsz = max(len(g) for g in groups) if groups else 1
            events.append(CollectiveEvent(
                name=op_result,
                kind=base,
                async_start=op_kind.endswith("-start"),
                operand_bytes=operand_bytes,
                result_bytes=result_bytes,
                dtype=dtype,
                replica_groups=groups,
                group_size=gsz,
                num_groups=len(groups),
                op_name=md.group(1) if md else "",
                computation=name,
                multiplicity=m,
                channel_id=int(ch.group(1)) if ch else None,
                source_target_pairs=stp,
            ))
    return events, stats


def _operand_bytes(rest: str, type_str: str, kind: str, line: str) -> int:
    """Payload (input) bytes of the collective."""
    result_bytes, _ = parse_type_bytes(type_str)
    if kind == "all-gather":
        # result = group_size x operand; report the *result* (gathered) size
        # as payload — matches the roofline "operand sizes" convention of
        # counting the logically-moved tensor once.
        return result_bytes
    if kind == "reduce-scatter":
        # operand = group_size x result; payload is the pre-scatter operand.
        m = _IOTA_RG_RE.search(line)
        if m:
            return result_bytes * int(m.group(2))
        return result_bytes
    # all-reduce / all-to-all / permute: operand size == result size
    # (-start tuples double-count operand+result; halve them)
    if type_str.strip().startswith("(") and kind == "all-reduce":
        return result_bytes // 2
    return result_bytes


# --------------------------------------------------------------------------
# single-pass columnar fast path
# --------------------------------------------------------------------------

# quick substring prescreen: a line can only be a collective op site if one
# of these appears (C-level scan, no regex)
_COLL_HINT_RE = re.compile(
    r"all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast")

# one combined regex matches the whole collective op line — name, (tuple)
# type, kind, async suffix, and the attr tail — in a single pass, replacing
# the generic op-line match + kind dispatch + suffix string surgery of the
# reference path.  The lookbehind keeps the kind from matching inside a
# longer identifier (parity with `_OPLINE_RE`'s greedy kind capture).
_FAST_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*(?<![\w.\-])"
    r"(all-reduce|all-gather|reduce-scatter|ragged-all-to-all|all-to-all|"
    r"collective-permute|collective-broadcast)(-start|-done)?\((.*)$")

# the non-collective sibling: ONE combined regex both matches the op line
# and classifies its stats treatment via which alternative captured —
# transpose-class, fusion, convert, reshape/bitcast, dot, a traffic-exempt
# kind (_NO_TRAFFIC/_FUSED_ON_TPU, folded into the alternation so the hot
# loop does no Python set dispatch), or a generic traffic-charged op.
# Alternatives are all anchored on the trailing `(`, so each line yields
# exactly the token `_OPLINE_RE` would have captured.
_STATS_SKIP_KINDS = sorted(
    (_NO_TRAFFIC | _FUSED_ON_TPU)
    - {"transpose", "copy", "convert", "reshape", "bitcast"},
    key=len, reverse=True)
_FAST_STATS_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*(?<![\w.\-])"
    r"(?:(transpose[a-z0-9\-]*|copy)|(fusion)|(convert)|(reshape|bitcast)|"
    r"(dot)|(?:" + "|".join(_STATS_SKIP_KINDS) + r")|([a-z][a-z0-9\-]*))"
    r"\((.*)$")


# --------------------------------------------------------------------------
# salvage parsing — recover the intact computations of a damaged module
# --------------------------------------------------------------------------

# a line consisting solely of the computation-closing brace (the HLO
# terminator) — the structural-intactness witness salvage clamps spans to
_CLOSE_LINE_RE = re.compile(r"^[ \t]*\}[ \t]*\r?$", re.MULTILINE)


@dataclass
class SalvageReport:
    """What salvage parsing dropped from a damaged module.

    Attached to the store a `parse_hlo_store(..., recover=True)` returns
    so partial ingests carry provenance: how much of the input was
    unusable (`bytes_skipped`), which computations were lost (`dropped`),
    and the first structural or parse error encountered (`first_error`).
    """

    total_bytes: int = 0
    bytes_skipped: int = 0
    computations_total: int = 0
    computations_dropped: int = 0
    dropped: List[str] = field(default_factory=list)
    first_error: str = ""

    @property
    def clean(self) -> bool:
        """True when nothing was dropped — the parse was lossless."""
        return self.bytes_skipped == 0 and self.computations_dropped == 0

    def to_dict(self) -> Dict[str, object]:
        return {
            "clean": self.clean,
            "total_bytes": int(self.total_bytes),
            "bytes_skipped": int(self.bytes_skipped),
            "computations_total": int(self.computations_total),
            "computations_dropped": int(self.computations_dropped),
            "dropped": list(self.dropped),
            "first_error": self.first_error,
        }


def _salvage_split(text: str) -> Tuple[List[Tuple[str, str]], SalvageReport]:
    """Structurally-intact computation chunks of a possibly-damaged module.

    Each verified header span is clamped at its *last* closing-brace
    line: a truncated final computation (no terminator) is dropped
    whole, and non-whitespace trailing garbage after a terminator (a
    header line cut mid-write, spliced junk) is skipped — so no chunk
    ever contains a partial op line that could parse into a wrong row.
    Duplicate names keep the last definition at the first occurrence's
    position, mirroring `_split_computations`' dict-overwrite order.
    """
    starts, ends, names, _entry = _comp_spans(text)
    report = SalvageReport(total_bytes=len(text),
                           computations_total=len(set(names)))
    if not starts:
        if text.strip():
            report.bytes_skipped = len(text)
            report.first_error = "no computation headers found"
        return [], report

    last = {name: i for i, name in enumerate(names)}
    seen: set = set()
    kept: List[Tuple[str, str]] = []
    for name in names:
        if name in seen:
            continue
        seen.add(name)
        i = last[name]
        seg = text[starts[i]:ends[i]]
        close = None
        for m in _CLOSE_LINE_RE.finditer(seg):
            close = m
        if close is None:
            report.computations_dropped += 1
            report.dropped.append(name)
            report.bytes_skipped += len(seg)
            if not report.first_error:
                report.first_error = (f"computation %{name} truncated "
                                      f"(no closing brace)")
            continue
        body, tail = seg[:close.end()], seg[close.end():]
        if tail.strip():
            report.bytes_skipped += len(tail)
            if not report.first_error:
                report.first_error = (f"unparseable trailing bytes after "
                                      f"computation %{name}")
        kept.append((name, body + "\n"))
    return kept, report


def _parse_hlo_store_salvage(text: str, num_devices: int):
    """`parse_hlo_store(recover=True)`: never raise, drop what's broken.

    Two recovery tiers: (1) *structural* — clamp every computation span
    at its closing brace and drop unterminated ones, so any truncation
    offset yields only rows from intact computations; (2) *content* — if
    the cleaned text still fails to parse (corruption inside an intact-
    looking computation, e.g. a mangled replica-group attr), re-parse
    per computation under the shared module context and drop only the
    raising ones, merging the survivors byte-identically to a serial
    parse of them (the PR 5 shard machinery).

    Returns `(store, stats, report)`.
    """
    from repro.core.store import TraceStore

    kept, report = _salvage_split(text)
    clean = "".join(body for _name, body in kept)
    try:
        store, stats = parse_hlo_store(clean, num_devices)
        return store, stats, report
    except Exception as e:
        if not report.first_error:
            report.first_error = f"{type(e).__name__}: {e}"

    _spans, ctx = _split_spans(clean, 1)
    stores, statss = [], []
    for name, body in kept:
        try:
            st, ss = parse_hlo_store(body, num_devices, shard_ctx=ctx)
        except Exception as e:
            report.computations_dropped += 1
            report.dropped.append(name)
            report.bytes_skipped += len(body)
            if not report.first_error:
                report.first_error = f"computation %{name}: {e}"
            continue
        stores.append(st)
        statss.append(ss)
    store = TraceStore.merge(stores) if stores \
        else parse_hlo_store("", num_devices)[0]
    stats = HloOpStats.merged(statss)
    return store, stats, report


def parse_hlo_store(text: str, num_devices: int,
                    shard_ctx: Optional[Dict] = None, recover: bool = False):
    """Single-pass fast path: collective op lines -> `TraceStore` columns.

    Equivalent to `parse_hlo` + `TraceStore.from_events` but ~an order of
    magnitude faster at the 100k-site scale: each collective line is
    consumed by ONE combined compiled regex and appended straight into
    column builders — no `CollectiveEvent` dataclass per site, and every
    repeated payload (op_name metadata, `replica_groups=...` attr text,
    type strings, permute pair lists) is interned so the expensive decode
    (iota resolution, type-byte arithmetic, scope splitting) runs once per
    *unique* string instead of once per site.  Derived columns (link class,
    wire bytes, est time, semantic, ...) are left blank for
    `costmodel.annotate_store` / `attribution.attribute_store`.

    `shard_ctx` is the shared module context produced by
    `split_hlo_module` when `text` is one computation chunk of a larger
    module: it carries the whole-module execution multiplicities and
    fusion-body set, which cannot be derived from a chunk alone (the
    entry computation, while conditions, and fusion call sites may live
    in other chunks).

    `recover=True` switches to salvage mode for damaged dumps: instead
    of raising on a truncated or locally-corrupted module, recover every
    structurally-intact computation (see `_parse_hlo_store_salvage`) and
    return `(store, stats, report)` with a `SalvageReport` describing
    what was dropped.  The default path is untouched — clean ingest pays
    nothing for the recovery machinery.

    Returns `(store, stats)` with `stats` identical to the reference path.
    """
    if recover:
        return _parse_hlo_store_salvage(text, num_devices)

    from repro.core.attribution import split_op_name
    from repro.core.store import Categorical, TraceStore

    comps = _split_computations(text)
    if shard_ctx is None:
        mult = _multiplicities(comps)
        ctx_fusion = ()
    else:
        mult = shard_ctx["mult"]
        ctx_fusion = shard_ctx["fusion_bodies"]
    stats = HloOpStats()

    # -- prepass: fusion bodies + symbol tables.  The full table is only
    # needed for dot-FLOP lhs lookups; otherwise parameters (operand-byte
    # charging) and fusion markers are the only rows ever read from it.
    shapes_by_comp: Dict[str, Dict[str, str]] = {}
    kinds_by_comp: Dict[str, Dict[str, str]] = {}
    fusion_bodies: set = set(ctx_fusion)
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        table: Dict[str, str] = {}
        kinds: Dict[str, str] = {}
        full = any(" dot(" in ln for ln in comp.lines)
        for line in comp.lines:
            if not full and "parameter(" not in line and "fusion(" not in line:
                continue
            if "/*" in line:
                line = _COMMENT_RE.sub("", line)
            lm = _OPLINE_RE.match(line)
            if lm:
                table[lm.group(1)] = lm.group(2)
                kinds[lm.group(1)] = lm.group(3)
                if lm.group(3) == "fusion":
                    fm = _CALLS_RE.search(line)
                    if fm:
                        fusion_bodies.add(fm.group(1))
        shapes_by_comp[name] = table
        kinds_by_comp[name] = kinds

    # -- column builders + interning state ----------------------------------
    names: List[str] = []
    operand_b: List[int] = []
    result_b: List[int] = []
    mults: List[int] = []
    gsizes: List[int] = []
    ngroups_l: List[int] = []
    channels: List[int] = []
    asyncs: List[bool] = []
    kind_codes: List[int] = []
    dtype_codes: List[int] = []
    comp_codes: List[int] = []
    op_codes: List[int] = []
    group_code: List[int] = []
    stp_code: List[int] = []

    kind_index: Dict[str, int] = {}
    kind_vocab: List[str] = []
    dtype_index: Dict[str, int] = {}
    dtype_vocab: List[str] = []
    comp_index: Dict[str, int] = {}
    comp_vocab: List[str] = []
    op_index: Dict[str, int] = {}
    op_vocab: List[str] = []
    scope_by_op: List[str] = []        # stats scope, parallel to op_vocab
    type_cache: Dict[str, Tuple[int, int, bool]] = {}   # -> (bytes, dtc, tuple?)
    pbytes_cache: Dict[str, int] = {}                   # param type -> bytes
    # raw-attr-text front caches over *value-keyed* table interning: the raw
    # string lookup keeps the hot path cheap, while the value index ensures
    # two spellings of the same groups (iota vs explicit) share one table —
    # the invariant `TraceStore.merge` relies on to reproduce a serial parse.
    rg_cache: Dict[Optional[str], Tuple[int, int, int, int]] = {}
    rg_value_idx: Dict[Tuple, int] = {}
    group_tables: List[List[List[int]]] = []
    stp_cache: Dict[str, int] = {}
    stp_value_idx: Dict[Tuple, int] = {}
    stp_tables: List[List[Tuple[int, int]]] = []

    coll_search = _COLL_HINT_RE.search
    fast_match = _FAST_COLLECTIVE_RE.match
    stats_match = _FAST_STATS_RE.match
    tb_cache: Dict[str, int] = {}        # stats type string -> result bytes
    scope_cache: Dict[str, str] = {}     # stats op_name -> named_scope

    def stats_scope(ln: str) -> str:
        md_ = _METADATA_RE.search(ln)
        if md_ is None:
            return ""
        op = md_.group(1)
        sc_ = scope_cache.get(op)
        if sc_ is None:
            sc_ = scope_cache[op] = split_op_name(op)[0] if op else ""
        return sc_

    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1)
        shapes = shapes_by_comp.get(name, {})
        kinds = kinds_by_comp.get(name, {})
        in_fusion_body = name in fusion_bodies
        cc = -1                        # interned on first emitted event
        for line in comp.lines:
            if "/*" in line:
                line = _COMMENT_RE.sub("", line)
            cm = fast_match(line) if coll_search(line) else None
            if cm is None:
                sm = stats_match(line)
                if sm is None:
                    continue
                (_nm, type_str, k_tc, k_fu, k_cv, k_rs, k_dot, k_gen,
                 rest) = sm.groups()
                if k_dot is not None:
                    fl = _dot_flops(line, type_str, shapes) * m
                    stats.flops += fl
                    sc = stats_scope(line)
                    stats.flops_by_scope[sc] = \
                        stats.flops_by_scope.get(sc, 0.0) + fl
                # traffic: generic / fusion / dot ops always charge; the
                # transpose class only when the exact kind is not exempt
                # (plain transpose and copy are fused on TPU, a
                # transpose-variant op is not)
                if (not in_fusion_body
                        and (k_gen is not None or k_fu is not None
                             or k_dot is not None
                             or (k_tc is not None
                                 and k_tc not in _FUSED_ON_TPU))):
                    rb = tb_cache.get(type_str)
                    if rb is None:
                        rb = tb_cache[type_str] = parse_type_bytes(type_str)[0]
                    pb = 0
                    for op_ref in _OPERANDS_RE.findall(rest.split(")")[0]):
                        if kinds.get(op_ref) == "parameter":
                            ts = shapes.get(op_ref, "")
                            b = pbytes_cache.get(ts)
                            if b is None:
                                b = pbytes_cache[ts] = parse_type_bytes(ts)[0]
                            pb += b
                    tb = (2 * rb + pb) * m
                    stats.bytes_accessed += tb
                    sc = stats_scope(line)
                    stats.bytes_by_scope[sc] = \
                        stats.bytes_by_scope.get(sc, 0.0) + tb
                if k_tc is not None:
                    stats.n_transpose += 1
                    rb = tb_cache.get(type_str)
                    if rb is None:
                        rb = tb_cache[type_str] = parse_type_bytes(type_str)[0]
                    stats.transpose_bytes += rb * m
                elif k_fu is not None:
                    stats.n_fusion += 1
                elif k_cv is not None:
                    stats.n_convert += 1
                elif k_rs is not None:
                    stats.n_reshape += 1
                continue

            op_result, type_str, base, suffix, rest = cm.groups()

            # type bytes + dtype (interned per unique type string)
            tent = type_cache.get(type_str)
            if tent is None:
                rb, dt = parse_type_bytes(type_str)
                dtc = dtype_index.get(dt)
                if dtc is None:
                    dtc = dtype_index[dt] = len(dtype_vocab)
                    dtype_vocab.append(dt)
                tent = type_cache[type_str] = \
                    (rb, dtc, type_str.strip().startswith("("))
            rb, dtc, is_tuple = tent

            # op_name metadata (interned; scope resolved once per entry)
            md = _METADATA_RE.search(rest)
            op_name = md.group(1) if md else ""
            oc = op_index.get(op_name)
            if oc is None:
                oc = op_index[op_name] = len(op_vocab)
                op_vocab.append(op_name)
                scope_by_op.append(split_op_name(op_name)[0] if op_name else "")

            # stats contribution (collectives are never traffic-exempt)
            if not in_fusion_body:
                pb = 0
                for op_ref in _OPERANDS_RE.findall(rest.split(")")[0]):
                    if kinds.get(op_ref) == "parameter":
                        ts = shapes.get(op_ref, "")
                        b = pbytes_cache.get(ts)
                        if b is None:
                            b = pbytes_cache[ts] = parse_type_bytes(ts)[0]
                        pb += b
                tb = (2 * rb + pb) * m
                stats.bytes_accessed += tb
                sc = scope_by_op[oc]
                stats.bytes_by_scope[sc] = \
                    stats.bytes_by_scope.get(sc, 0.0) + tb

            if suffix == "-done":       # async completion marker: stats only
                continue

            # replica groups, interned on the raw attr text
            im = _IOTA_RG_RE.search(rest)
            if im is not None:
                rkey = im.group(0)
                gent = rg_cache.get(rkey)
                if gent is None:
                    g, s = int(im.group(1)), int(im.group(2))
                    dims = [int(x) for x in im.group(3).split(",")]
                    perm = [int(x) for x in im.group(4).split(",")] \
                        if im.group(4) else None
                    try:
                        groups = resolve_iota_groups(g, s, dims, perm)
                    except ValueError:
                        # malformed iota attr: full-range fallback, same
                        # as the reference (events-path) parser
                        groups = [list(range(num_devices))]
                        s = num_devices
                    gsz = max(len(gg) for gg in groups) if groups else 1
                    vkey = tuple(tuple(gg) for gg in groups)
                    gc = rg_value_idx.get(vkey)
                    if gc is None:
                        gc = rg_value_idx[vkey] = len(group_tables)
                        group_tables.append(groups)
                    gent = rg_cache[rkey] = (gc, gsz, len(groups), s)
            else:
                em = _EXPLICIT_RG_RE.search(rest)
                rkey = em.group(0) if em is not None else None
                gent = rg_cache.get(rkey)
                if gent is None:
                    groups = _parse_replica_groups(rkey or "", num_devices)
                    gsz = max(len(gg) for gg in groups) if groups else 1
                    vkey = tuple(tuple(gg) for gg in groups)
                    gc = rg_value_idx.get(vkey)
                    if gc is None:
                        gc = rg_value_idx[vkey] = len(group_tables)
                        group_tables.append(groups)
                    gent = rg_cache[rkey] = (gc, gsz, len(groups), 0)
            gc, gsz, ng, iota_s = gent

            # permute pairs, interned on the raw attr text
            sc_code = -1
            if base == "collective-permute":
                sm = _STP_RE.search(rest)
                if sm is not None and sm.group(1):
                    skey = sm.group(0)
                    sc_code = stp_cache.get(skey, -1)
                    if sc_code < 0:
                        pairs = _parse_stp(rest)
                        vkey = tuple(pairs)
                        sc_code = stp_value_idx.get(vkey, -1)
                        if sc_code < 0:
                            sc_code = stp_value_idx[vkey] = len(stp_tables)
                            stp_tables.append(pairs)
                        stp_cache[skey] = sc_code

            # payload bytes (same conventions as `_operand_bytes`)
            if base == "all-gather":
                ob = rb
            elif base == "reduce-scatter":
                ob = rb * iota_s if iota_s else rb
            elif is_tuple and base == "all-reduce":
                ob = rb // 2
            else:
                ob = rb

            ch = _CHANNEL_RE.search(rest)

            if cc < 0:
                cc = comp_index.get(name, -1)
                if cc < 0:
                    cc = comp_index[name] = len(comp_vocab)
                    comp_vocab.append(name)
            names.append(op_result)
            kc = kind_index.get(base)
            if kc is None:
                kc = kind_index[base] = len(kind_vocab)
                kind_vocab.append(base)
            kind_codes.append(kc)
            dtype_codes.append(dtc)
            comp_codes.append(cc)
            op_codes.append(oc)
            operand_b.append(ob)
            result_b.append(rb)
            mults.append(m)
            gsizes.append(gsz)
            ngroups_l.append(ng)
            channels.append(int(ch.group(1)) if ch else -1)
            asyncs.append(suffix == "-start")
            group_code.append(gc)
            stp_code.append(sc_code)

    n = len(names)
    num = {
        "operand_bytes": np.asarray(operand_b, dtype=np.int64),
        "result_bytes": np.asarray(result_b, dtype=np.int64),
        "multiplicity": np.asarray(mults, dtype=np.int64),
        "group_size": np.asarray(gsizes, dtype=np.int64),
        "num_groups": np.asarray(ngroups_l, dtype=np.int64),
        "channel_id": np.asarray(channels, dtype=np.int64),
        "async_start": np.asarray(asyncs, dtype=np.bool_),
        "wire_bytes_per_device": np.zeros(n, dtype=np.float64),
        "est_time_s": np.zeros(n, dtype=np.float64),
    }
    cat = {
        "kind": Categorical(np.asarray(kind_codes, dtype=np.int32), kind_vocab),
        "dtype": Categorical(np.asarray(dtype_codes, dtype=np.int32),
                             dtype_vocab),
        "computation": Categorical(np.asarray(comp_codes, dtype=np.int32),
                                   comp_vocab),
        "op_name": Categorical(np.asarray(op_codes, dtype=np.int32), op_vocab),
        "link_class": Categorical.constant(n),
        "semantic": Categorical.constant(n),
        "protocol": Categorical.constant(n),
        "jax_prim": Categorical.constant(n),
        "scope": Categorical.constant(n),
    }
    store = TraceStore(
        n, num, cat, names,
        group_tables=group_tables,
        group_code=np.asarray(group_code, dtype=np.int32),
        stp_tables=stp_tables,
        stp_code=np.asarray(stp_code, dtype=np.int32),
        axes_tables=[()] if n else [],
        axes_code=np.zeros(n, dtype=np.int32))
    return store, stats


# --------------------------------------------------------------------------
# sharded single-module ingest: splitter + worker fan-out + merge
# --------------------------------------------------------------------------

# a single module above this size is auto-sharded across workers by
# `tracer.trace_from_hlo` (roughly the point where parse time clears the
# process fan-out overhead)
AUTO_SHARD_BYTES = 8 << 20


def auto_shards(n_bytes: int, cpus: Optional[int] = None) -> int:
    """Shard count for a module of `n_bytes` (1 = keep the serial path).

    Small modules and single-core boxes stay serial; large ones split into
    a couple of chunks per usable core so the contiguous partition can
    balance one oversized computation (e.g. a giant while body) against
    many small ones.
    """
    if cpus is None:
        cpus = os.cpu_count() or 1
    if cpus < 2 or n_bytes < AUTO_SHARD_BYTES:
        return 1
    return int(min(4 * cpus, max(2 * cpus, n_bytes // AUTO_SHARD_BYTES)))


# `{`-at-end-of-line *candidates* — a literal-prefix scan (C-level
# fastsearch); each hit is verified against the exact
# `_split_computations` header condition before it becomes a chunk
# boundary (a false split would orphan half a computation, a miss only
# costs balance)
_HDR_CAND_RE = re.compile(r"\{[ \t\r]*\n")
_EDGE_NAME_RE = re.compile(r"%?[\w.\-]+")
_WHILE_SCAN_RE = re.compile(r"while\(")
_FUSION_SCAN_RE = re.compile(r"fusion\(")
_EDGE_LITS = ("calls=", "to_apply=")
_REF_LITS = ("calls=", "to_apply=", "body=", "condition=")
_NAME_CHARS = frozenset(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_.-")


def _line_at(text: str, pos: int) -> str:
    ls = text.rfind("\n", 0, pos) + 1
    le = text.find("\n", pos)
    return text[ls:] if le < 0 else text[ls:le]


def _iter_call_edges(text: str, start: int, end: int):
    """Yield `calls=`/`to_apply=` callee names in text[start:end] via
    literal fastsearch (the alternation regex is ~10x slower here)."""
    for lit in _EDGE_LITS:
        pos = text.find(lit, start, end)
        step = len(lit)
        while pos >= 0:
            m = _EDGE_NAME_RE.match(text, pos + step, end)
            if m:
                yield m.group(0).lstrip("%")
            pos = text.find(lit, pos + step, end)


def _find_refs_to(text: str, name: str):
    """Offsets of `calls=|to_apply=|body=|condition=` references to `name`
    (exact-name matches only), again via literal fastsearch."""
    for lit in _REF_LITS:
        for target in (lit + "%" + name, lit + name):
            step = len(target)
            pos = text.find(target)
            while pos >= 0:
                nxt = pos + step
                if nxt >= len(text) or text[nxt] not in _NAME_CHARS:
                    yield pos
                pos = text.find(target, pos + 1)


def _ref_callers_global(text: str, comp_at) -> Dict[str, List[str]]:
    """{callee name: [caller comps]} over every call/while reference.

    One pass over the four ref literals — used instead of per-name
    `_find_refs_to` scans when many computations contain whiles, where
    the targeted approach would rescan the module once per chain node.
    """
    out: Dict[str, List[str]] = {}
    for lit in _REF_LITS:
        step = len(lit)
        pos = text.find(lit)
        while pos >= 0:
            m = _EDGE_NAME_RE.match(text, pos + step)
            if m:
                caller = comp_at(pos)
                if caller is not None:
                    out.setdefault(m.group(0).lstrip("%"), []).append(caller)
            pos = text.find(lit, pos + step)
    return out


def _comp_spans(text: str
                ) -> Tuple[List[int], List[int], List[str], Optional[str]]:
    """Verified computation header spans: (starts, ends, names, entry).

    A C-level candidate scan for `{`-at-end-of-line, each hit verified
    against the exact `_split_computations` header condition.  Span i
    runs from its header's line start to the next verified header (or
    EOF) — the unit both the sharded splitter and the salvage parser
    partition the module into.
    """
    starts: List[int] = []
    names: List[str] = []
    entry_name: Optional[str] = None
    cand_ends = [m.start() for m in _HDR_CAND_RE.finditer(text)]
    tail = text.rstrip()
    if tail.endswith("{"):                      # no trailing newline at EOF
        cand_ends.append(len(tail) - 1)
    for brace in cand_ends:
        ls = text.rfind("\n", 0, brace) + 1
        stripped = text[ls:brace + 1].strip()
        # the exact `_split_computations` header condition
        if not (stripped.endswith("{") and "->" in stripped
                and "=" not in stripped.split("(")[0]):
            continue
        head = stripped
        is_entry = head.startswith("ENTRY")
        if is_entry:
            head = head[len("ENTRY"):].lstrip()
        name = head.split("(")[0].strip().lstrip("%").strip()
        if not name:
            continue
        starts.append(ls)
        names.append(name)
        if is_entry:
            entry_name = name
    ends = starts[1:] + [len(text)]
    return starts, ends, names, entry_name


def _split_spans(text: str, n_shards: int):
    """(chunk spans, shared context) for a sharded parse of one module.

    Everything runs as C-level regex scans over the raw text (no
    per-line Python loop): verified computation headers give the chunk
    boundaries, and the multiplicity context is rebuilt from *targeted*
    scans — all while edges, plus call edges only where they can change
    the fixpoint (chains activating a while-containing computation, and
    the closure reached from loop bodies).  Edges from multiplicity-1
    computations elsewhere are no-ops in the serial max-propagation
    (they assign the default), so dropping them preserves the result.
    """
    import bisect

    starts, ends, names, entry_name = _comp_spans(text)
    # duplicate names: the serial line parser keeps the *last* definition's
    # content at the *first* occurrence's position (dict overwrite preserves
    # key order), so chunks carry the last span, ordered by first sighting
    last = {name: i for i, name in enumerate(names)}
    live: List[int] = []
    ordered_seen: set = set()
    for name in names:
        if name not in ordered_seen:
            ordered_seen.add(name)
            live.append(last[name])
    span_of = {names[i]: (starts[i], ends[i]) for i in live}

    def comp_at(pos: int) -> Optional[str]:
        i = bisect.bisect_right(starts, pos) - 1
        if i < 0 or last[names[i]] != i:
            return None
        return names[i]

    # -- while edges (body/cond x trip count), callers by offset ------------
    edges: Dict[str, List[Tuple[str, int]]] = {}
    tc_cache: Dict[str, int] = {}
    while_callers: List[str] = []
    for m in _WHILE_SCAN_RE.finditer(text):
        line = _line_at(text, m.start())
        wm = _WHILE_RE.search(line)
        cm = _COND_RE.search(line)
        if not (wm and cm):
            continue
        caller = comp_at(m.start())
        if caller is None:
            continue
        cname = cm.group(1)
        tc = tc_cache.get(cname)
        if tc is None:
            span = span_of.get(cname)
            tc = 1
            if span is not None:
                for cm2 in _CONST_INT_RE.finditer(text, span[0], span[1]):
                    tc = max(tc, int(cm2.group(1)))
            tc_cache[cname] = tc
        edges.setdefault(caller, []).append((wm.group(1), tc))
        edges[caller].append((cname, tc))
        while_callers.append(caller)

    # -- backward: activate while-containing computations -------------------
    # (a while edge only fires once its caller is reachable from the entry,
    # so pull in the call chains that reach each while caller)
    scanned_back: set = set()
    frontier = list(while_callers)
    # targeted per-name scans are cheapest for the common 1-2 loop chains;
    # with many while-containing computations, bucket every reference once
    # instead of rescanning the module per chain node
    ref_map = _ref_callers_global(text, comp_at) \
        if len(set(frontier) - {entry_name}) > 4 else None
    while frontier:
        w = frontier.pop()
        if w in scanned_back or w == entry_name:
            continue
        scanned_back.add(w)
        if ref_map is not None:
            callers = ref_map.get(w, ())
        else:
            callers = [comp_at(pos) for pos in _find_refs_to(text, w)]
        for caller in callers:
            if caller is None or caller == w:
                continue
            edges.setdefault(caller, []).append((w, 1))
            frontier.append(caller)

    # -- forward: closure out of loop bodies/conditions ---------------------
    # (these run with multiplicity > 1; their callees inherit it)
    scanned_fwd: set = set()
    edge_seen: set = set()
    frontier = [callee for es in list(edges.values()) for callee, _k in es]
    while frontier:
        c = frontier.pop()
        if c in scanned_fwd:
            continue
        scanned_fwd.add(c)
        span = span_of.get(c)
        if span is None:
            continue
        for callee in _iter_call_edges(text, span[0], span[1]):
            if (c, callee) not in edge_seen:
                edge_seen.add((c, callee))
                edges.setdefault(c, []).append((callee, 1))
                frontier.append(callee)

    # -- fixpoint (same max-propagation as `_multiplicities`) ---------------
    name_set = set(span_of)
    if entry_name is None:
        mult = {name: 1 for name in name_set}
    else:
        mult = {entry_name: 1}
        changed = True
        passes = 0
        while changed and passes < 50:
            changed = False
            passes += 1
            for name in name_set:
                if name not in mult:
                    continue
                base = mult[name]
                for callee, k in edges.get(name, ()):
                    new = base * k
                    if callee in name_set and mult.get(callee, 0) < new:
                        mult[callee] = new
                        changed = True

    # -- fusion bodies (the byte-accounting exclusion set) ------------------
    fusion_bodies: List[str] = []
    fb_seen: set = set()
    for m in _FUSION_SCAN_RE.finditer(text):
        line = _line_at(text, m.start())
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        lm = _OPLINE_RE.match(line)
        if lm and lm.group(3) == "fusion":
            fm = _CALLS_RE.search(line)
            if fm and fm.group(1) not in fb_seen:
                fb_seen.add(fm.group(1))
                fusion_bodies.append(fm.group(1))

    ctx: Dict[str, object] = {
        "mult": {k2: int(v) for k2, v in mult.items()},
        "fusion_bodies": fusion_bodies,
    }

    # -- contiguous partition of live spans, balanced by byte length --------
    k = max(1, min(n_shards, len(live)))
    weights = [ends[i] - starts[i] for i in live]
    total = sum(weights) or 1
    shard_spans: List[List[Tuple[int, int]]] = [[] for _ in range(k)]
    ci, acc = 0, 0
    for i, w in zip(live, weights):
        shard_spans[ci].append((starts[i], ends[i]))
        acc += w
        if ci < k - 1 and acc >= (ci + 1) * total / k:
            ci += 1
    # coalesce adjacent spans inside each shard into one (start, end)
    spans: List[Tuple[int, int]] = []
    for group in shard_spans:
        if not group:
            continue
        run_s, run_e = group[0]
        out_s, out_e = run_s, run_e
        merged: List[Tuple[int, int]] = []
        for s, e in group[1:]:
            if s == out_e:
                out_e = e
            else:
                merged.append((out_s, out_e))
                out_s, out_e = s, e
        merged.append((out_s, out_e))
        spans.append(tuple(merged))
    return spans, ctx


def split_hlo_module(text: str, n_shards: int
                     ) -> Tuple[List[str], Dict[str, object]]:
    """Partition module text into computation chunks + shared context.

    Chunks are *contiguous* runs of whole computations balanced by size,
    so concatenating the per-chunk parses reproduces the serial row order
    exactly.  The returned context carries the only two pieces of
    whole-module state a chunk cannot derive locally:

      * `mult` — execution multiplicity per computation (the while-loop
        trip-count fixpoint needs the entry computation and every
        condition body, which may land in other chunks), and
      * `fusion_bodies` — computations reached via `fusion(...) calls=`
        (excluded from byte accounting; the calling fusion op may be in
        a different chunk than its body).
    """
    spans, ctx = _split_spans(text, n_shards)
    chunks = ["".join(text[s:e] for s, e in group) for group in spans]
    return chunks, ctx


# (text, num_devices, ctx) inherited copy-on-write by fork workers, so the
# module text never rides through the job pipe; the lock serializes
# concurrent sharded parses so one caller's fork cannot inherit another's
# state (slicing foreign text with local spans would merge garbage)
_FORK_SHARD_STATE = None
_FORK_SHARD_LOCK = threading.Lock()

# spawn workers re-import __main__; in parents spawn cannot bootstrap
# (embedded interpreters, stdin scripts) every worker dies before reading
# the call queue and `ex.map` can block forever — a no-op probe with this
# bound converts the hang into the in-process fallback
_SPAWN_PROBE_TIMEOUT_S = 30.0


def _parse_shard_spans(spans):
    """Fork worker: slice the inherited module text and parse the chunk."""
    text, num_devices, ctx = _FORK_SHARD_STATE
    chunk = "".join(text[s:e] for s, e in spans)
    return parse_hlo_store(chunk, num_devices, shard_ctx=ctx)


def _parse_shard_job(job):
    """Worker: parse one computation chunk under the shared module context."""
    chunk, num_devices, ctx = job
    return parse_hlo_store(chunk, num_devices, shard_ctx=ctx)


def parse_hlo_store_sharded(text: str, num_devices: int, shards: int,
                            max_workers: Optional[int] = None):
    """Parse one large module as `shards` computation chunks, in parallel.

    Each chunk runs `parse_hlo_store` (in a worker process when a pool is
    available, else in-process) and `TraceStore.merge` concatenates the
    shard stores — byte-identical to a serial `parse_hlo_store` of the
    whole text.  Fork workers inherit the text copy-on-write and receive
    only (start, end) spans; spawn fallbacks ship chunk strings.
    `max_workers=0` forces the in-process path (tests, restricted
    environments).

    Returns `(store, stats)` like `parse_hlo_store`.
    """
    global _FORK_SHARD_STATE
    from repro.core.store import TraceStore

    span_groups, ctx = _split_spans(text, shards)
    if len(span_groups) <= 1:
        return parse_hlo_store(text, num_devices)
    results = None
    if max_workers != 0:
        if max_workers is None:
            max_workers = min(len(span_groups), os.cpu_count() or 1)
        import multiprocessing
        import pickle
        from concurrent.futures import ProcessPoolExecutor
        from concurrent.futures.process import BrokenProcessPool
        # fork when safe (cheap, no re-import, text inherited): the guard
        # mirrors session.from_hlo — a jax-loaded parent is multithreaded,
        # and forking a multithreaded process can deadlock workers.
        method = "fork" if (
            "fork" in multiprocessing.get_all_start_methods()
            and "jax" not in sys.modules) else "spawn"
        try:
            mp_ctx = multiprocessing.get_context(method)
            if method == "fork":
                with _FORK_SHARD_LOCK:
                    _FORK_SHARD_STATE = (text, num_devices, ctx)
                    try:
                        with ProcessPoolExecutor(
                                max_workers=max_workers,
                                mp_context=mp_ctx) as ex:
                            results = list(ex.map(_parse_shard_spans,
                                                  span_groups))
                    finally:
                        _FORK_SHARD_STATE = None
            else:
                jobs = [("".join(text[s:e] for s, e in g), num_devices, ctx)
                        for g in span_groups]
                ex = ProcessPoolExecutor(max_workers=max_workers,
                                         mp_context=mp_ctx)
                try:
                    ex.submit(int).result(timeout=_SPAWN_PROBE_TIMEOUT_S)
                    results = list(ex.map(_parse_shard_job, jobs))
                    ex.shutdown()
                except Exception:
                    ex.shutdown(wait=False, cancel_futures=True)
                    raise OSError("spawn pool unusable")
        except (BrokenProcessPool, pickle.PicklingError, ImportError,
                OSError):
            results = None    # pool unavailable here -> in-process shards
    if results is None:
        results = [_parse_shard_job(
            ("".join(text[s:e] for s, e in g), num_devices, ctx))
            for g in span_groups]
    store = TraceStore.merge([r[0] for r in results])
    stats = HloOpStats.merged([r[1] for r in results])
    return store, stats
