"""hymba-1.5b — hybrid: parallel attention + mamba heads. [arXiv:2411.13676]"""
from repro.configs.base import ModelConfig

# SWA everywhere except full attention at first / middle / last layers.
_PATTERN = tuple(0 if i in (0, 15, 31) else 1024 for i in range(32))

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    window_pattern=_PATTERN,
    ssm_state=16,
    d_conv=4,
    expand=2,
    notes="parallel attn+mamba per block, mean-fused; meta-tokens omitted "
          "(orthogonal to communication behavior)",
)
