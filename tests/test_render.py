"""Columnar renderer + code-aligned diff equivalence.

The columnar emitters (`report.to_json`/`to_html`/`timeline`/
`top_contenders_table`, engine="columnar" default) must produce output
**byte-identical** to the retained per-event reference walk
(engine="rows"), the streaming writers must reproduce the one-shot
strings exactly, and `diff.diff_traces`/`diff_n` union-vocab alignment
must return exactly the rows of the dict-aligned reference — including
NEW/GONE classes, site-level keys, and empty-trace edge cases.
"""
import io
import json

import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core import diff, report
from repro.core.events import CollectiveEvent, Trace, site_key
from repro.core.store import union_rollup
from repro.core.synth import synthetic_trace
from repro.core.topology import MeshSpec

MESH = MeshSpec((2, 4), ("data", "model"))


def rand_trace(seed, n_sites=300, **kw):
    return synthetic_trace(f"t{seed}", MESH, n_sites=n_sites, seed=seed, **kw)


def mk_event(**kw):
    base = dict(name="ar", kind="all-reduce", async_start=False,
                operand_bytes=1 << 20, result_bytes=1 << 20, dtype="bf16",
                replica_groups=[[0, 1, 2, 3]], group_size=4, num_groups=1,
                op_name="jit(f)/layer/mlp/psum", computation="main",
                link_class="ici.data", axes=("data",), semantic="ffn",
                jax_prim="psum", scope="layer/mlp", protocol="rndv",
                wire_bytes_per_device=1.5 * (1 << 20), est_time_s=1e-4)
    base.update(kw)
    return CollectiveEvent(**base)


def empty_trace():
    return Trace(label="empty", mesh_shape=(2,), mesh_axes=("data",),
                 num_devices=2, events=[])


# -- JSON ---------------------------------------------------------------------

@given(seed=st.integers(0, 1000))
@settings(max_examples=6, deadline=None)
def test_to_json_byte_identical(seed):
    tr = rand_trace(seed)
    assert report.to_json(tr) == report.to_json(tr, engine="rows")


def test_to_json_is_valid_json():
    tr = rand_trace(3, n_sites=257)
    payload = json.loads(report.to_json(tr))
    assert payload["label"] == "t3"
    assert len(payload["events"]) == 257
    ev = payload["events"][0]
    assert set(ev) == {"name", "kind", "bytes", "mult", "link", "axes",
                       "semantic", "scope", "prim", "protocol", "group_size",
                       "num_groups", "est_time_us"}


@pytest.mark.parametrize("chunk", [1, 7, 64, 10_000])
def test_write_json_streams_identical_bytes(chunk):
    tr = rand_trace(0, n_sites=203)
    want = report.to_json(tr)
    buf = io.StringIO()
    report.write_json(tr, buf, chunk_sites=chunk)
    assert buf.getvalue() == want
    # streaming really chunks: more than one fragment for small chunk sizes
    n_chunks = sum(1 for _ in report.iter_json(tr, chunk_sites=chunk))
    assert n_chunks == 2 + -(-203 // chunk)


def test_to_json_empty_trace():
    tr = empty_trace()
    assert report.to_json(tr) == report.to_json(tr, engine="rows")
    assert json.loads(report.to_json(tr))["events"] == []


def test_to_json_escapes_strings():
    tr = Trace(label='we"ird\nlabel', mesh_shape=(2, 2),
               mesh_axes=("data", "model"), num_devices=4,
               events=[mk_event(op_name='a"b\\c', scope="s\tcope")])
    out = report.to_json(tr)
    assert out == report.to_json(tr, engine="rows")
    assert json.loads(out)["events"][0]["scope"] == "s\tcope"


# -- tables / timeline / HTML -------------------------------------------------

@given(seed=st.integers(0, 1000))
@settings(max_examples=6, deadline=None)
def test_tables_and_timeline_byte_identical(seed):
    tr = rand_trace(seed)
    assert report.top_contenders_table(tr) == \
        report.top_contenders_table(tr, engine="rows")
    assert report.semantic_table(tr) == \
        report.semantic_table(tr, engine="rows")
    assert report.timeline(tr) == report.timeline(tr, engine="rows")


def test_timeline_top_limits_rows():
    tr = rand_trace(1, n_sites=100)
    assert len(report.timeline(tr, top=5).splitlines()) == 6
    assert report.timeline(tr, top=5) == \
        report.timeline(tr, top=5, engine="rows")


def test_to_html_byte_identical_and_streamed():
    tr = rand_trace(2, n_sites=400)
    mesh = MESH
    want = report.to_html(tr, mesh)
    assert want == report.to_html(tr, mesh, engine="rows")
    buf = io.StringIO()
    report.write_html(tr, mesh, buf)
    assert buf.getvalue() == want
    assert want.startswith("<!doctype html>")
    assert "<script src" not in want


def test_tables_empty_trace():
    tr = empty_trace()
    assert report.top_contenders_table(tr) == \
        report.top_contenders_table(tr, engine="rows")
    assert report.timeline(tr) == report.timeline(tr, engine="rows")


# -- code-aligned diff vs dict-aligned reference ------------------------------

@given(seed=st.integers(0, 500))
@settings(max_examples=6, deadline=None)
def test_diff_traces_matches_reference(seed):
    a = rand_trace(seed)
    b = rand_trace(seed + 1, axis_weights=(3.0, 1.0))
    for by in ("kind_link", "semantic", "site", "sem_kind_link"):
        assert diff.diff_traces(a, b, by) == \
            diff.diff_traces(a, b, by, engine="rows"), by


@given(seed=st.integers(0, 500))
@settings(max_examples=6, deadline=None)
def test_diff_n_matches_reference(seed):
    traces = [rand_trace(seed), rand_trace(seed + 1, axis_weights=(3.0, 1.0)),
              rand_trace(seed + 2, axis_weights=(1.0, 3.0))]
    for by in ("kind_link", "semantic", "site"):
        fast = diff.diff_n(traces, by)
        ref = diff.diff_n(traces, by, engine="rows")
        assert fast == ref, by
        assert [r.verdict() for r in fast] == [r.verdict() for r in ref]


def test_diff_new_gone_classes():
    """Classes present in only one trace verdict as NEW/GONE on both paths."""
    a = Trace(label="a", mesh_shape=(2, 2), mesh_axes=("data", "model"),
              num_devices=4, events=[mk_event()])
    b = Trace(label="b", mesh_shape=(2, 2), mesh_axes=("data", "model"),
              num_devices=4,
              events=[mk_event(kind="all-gather", jax_prim="all_gather",
                               op_name="jit(f)/layer/attn/all_gather")])
    for by in ("kind_link", "site"):
        rows = diff.diff_traces(a, b, by)
        assert rows == diff.diff_traces(a, b, by, engine="rows")
        verdicts = {r.key: r.verdict() for r in rows}
        assert sorted(verdicts.values()) == ["GONE", "NEW"]
    nrows = diff.diff_n([a, b], "kind_link")
    assert nrows == diff.diff_n([a, b], "kind_link", engine="rows")
    assert all(r.verdict() == "in 1/2" for r in nrows)


def test_diff_empty_traces():
    e = empty_trace()
    t = rand_trace(4, n_sites=60)
    assert diff.diff_n([], "kind_link") == []
    for by in ("kind_link", "site"):
        assert diff.diff_traces(e, t, by) == \
            diff.diff_traces(e, t, by, engine="rows")
        assert all(r.verdict() == "NEW" for r in diff.diff_traces(e, t, by))
        assert diff.diff_n([e, t], by) == \
            diff.diff_n([e, t], by, engine="rows")
    assert diff.diff_n([e, e], "kind_link") == []


def test_site_alignment_localizes_regression():
    """Doubling one callsite's bytes is visible at site level, keyed on the
    op_name that produced it — not just as a class-level wobble."""
    a = rand_trace(7, n_sites=200)
    b = rand_trace(7, n_sites=200)
    ev = b.events[0]
    ev.operand_bytes *= 4
    b.invalidate()
    changed = site_key(ev)
    rows = {r.key: r for r in diff.diff_traces(a, b, by="site")}
    assert rows == {r.key: r
                    for r in diff.diff_traces(a, b, by="site",
                                              engine="rows")}
    assert rows[changed].bytes_b > rows[changed].bytes_a
    # every site key carries the op_name x kind x axes triple
    assert all(k.count("|") == 2 for k in rows)


def test_union_rollup_shapes():
    a, b = rand_trace(0, 50), rand_trace(1, 50)
    keys, mats = union_rollup([a.store, b.store], "kind_link")
    assert mats.shape == (4, len(keys), 2)
    assert set(keys) == set(a.by_kind_and_link()) | set(b.by_kind_and_link())


def test_session_table_by_site():
    traces = [rand_trace(0, 80), rand_trace(1, 80)]
    out = report.session_table(traces, by="site")
    assert "by site" in out
    assert "TOTAL modeled collective ms" in out


# -- session CLI: report/diff subcommands -------------------------------------

@pytest.fixture
def session_path(tmp_path):
    from repro.core.session import TraceSession
    sess = TraceSession("unit", [rand_trace(0, 80), rand_trace(1, 80)])
    return sess.save(str(tmp_path / "s.json"))


def test_cli_report_json_stream(session_path, tmp_path, capsys):
    from repro.core.session import _main
    out = str(tmp_path / "report.json")
    assert _main(["report", session_path, "t0", "--out", out,
                  "--stream", "--chunk-sites", "16"]) == 0
    assert "wrote json report" in capsys.readouterr().out
    with open(out) as f:
        payload = json.load(f)
    assert payload["label"] == "t0"
    assert len(payload["events"]) == 80


def test_cli_report_html_default_first_trace(session_path, tmp_path):
    from repro.core.session import _main
    out = str(tmp_path / "report.html")
    assert _main(["report", session_path, "--format", "html",
                  "--out", out, "--stream"]) == 0
    with open(out) as f:
        html = f.read()
    assert html.startswith("<!doctype html>")
    assert "trace: t0" in html


def test_cli_report_stdout_and_bad_label(session_path, capsys):
    from repro.core.session import _main
    assert _main(["report", session_path]) == 0
    assert '"label": "t0"' in capsys.readouterr().out
    assert _main(["report", session_path, "nope"]) == 2


def test_cli_report_bad_label_keeps_existing_output(session_path, tmp_path):
    """A typo'd label must not truncate a previously written report."""
    from repro.core.session import _main
    out = tmp_path / "keep.html"
    out.write_text("precious previous report")
    assert _main(["report", session_path, "nope", "--out", str(out)]) == 2
    assert out.read_text() == "precious previous report"


def test_cli_report_creates_output_directory(session_path, tmp_path):
    from repro.core.session import _main
    out = str(tmp_path / "new" / "dir" / "r.json")
    assert _main(["report", session_path, "t1", "--out", out]) == 0
    with open(out) as f:
        assert json.load(f)["label"] == "t1"


def test_cli_diff_by_site(session_path, capsys):
    from repro.core.session import _main
    assert _main(["diff", session_path, "t0", "t1", "--by", "site"]) == 0
    out = capsys.readouterr().out
    assert "by site" in out


def test_cli_table_by_site(session_path, capsys):
    from repro.core.session import _main
    assert _main(["table", session_path, "--by", "site"]) == 0
    assert "session comparison" in capsys.readouterr().out
