"""Three-term roofline from the compiled dry-run artifact.

    compute    = HLO_FLOPs            / (chips x peak_FLOP/s)
    memory     = HLO_bytes_accessed   / (chips x HBM_bw)
    collective = collective_bytes     / (chips x link_bw)

`collective_bytes` is the summed operand sizes of every collective op
(x while-loop multiplicity) parsed from the compiled HLO — cost_analysis
does not report it, which is exactly the gap the paper's tool fills.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.events import Trace
from repro.core.topology import Hardware, V5E


@dataclass
class RooflineReport:
    label: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float = 0.0
    per_device_memory_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """(MODEL_FLOPS/chips) / HLO_FLOPs — remat/redundancy waste detector.

        model_flops is global; hlo_flops is the per-device SPMD program.
        """
        if not self.hlo_flops:
            return 0.0
        return self.model_flops / self.chips / self.hlo_flops

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max(all terms): 1.0 = perfectly compute-bound."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0

    @property
    def model_roofline_fraction(self) -> float:
        """MODEL_FLOPS-based fraction of peak at the modeled step time.

        (model_flops / chips / peak) / bound_s — the honest MFU bound the
        compiled program could reach if perfectly overlapped.
        """
        if not self.bound_s:
            return 0.0
        ideal = self.model_flops / (self.chips * V5E.flops_bf16)
        return ideal / self.bound_s

    def row(self) -> Dict[str, object]:
        return {
            "label": self.label,
            "chips": self.chips,
            "compute_ms": self.compute_s * 1e3,
            "memory_ms": self.memory_s * 1e3,
            "collective_ms": self.collective_s * 1e3,
            "dominant": self.dominant,
            "hlo_gflops": self.hlo_flops / 1e9,
            "model_gflops": self.model_flops / 1e9,
            "useful_ratio": self.useful_flops_ratio,
            "mfu_bound": self.model_roofline_fraction,
            "mem_gb_per_dev": self.per_device_memory_bytes / 1e9,
        }


def roofline(trace: Trace, hw: Hardware = V5E,
             model_flops: float = 0.0) -> RooflineReport:
    """NB: under SPMD, cost_analysis() reports the *per-device* partitioned
    program, and parsed collective operand sizes are per-device too, so each
    term divides by per-chip peak only — algebraically identical to the
    global `X / (chips x peak)` formulation."""
    chips = trace.num_devices
    compute_s = trace.hlo_flops / hw.flops_bf16
    memory_s = trace.hlo_bytes / hw.hbm_bw
    coll_bytes = trace.total_collective_bytes()
    # modeled completion time (latency + bidirectional-ring bandwidth terms,
    # serialized) — finer than the naive bytes/bw division, still an upper
    # bound vs a perfectly-overlapped schedule.
    collective_s = trace.total_est_time_s()
    return RooflineReport(
        label=trace.label,
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        hlo_flops=trace.hlo_flops,
        hlo_bytes=trace.hlo_bytes,
        collective_bytes=coll_bytes,
        model_flops=model_flops,
        per_device_memory_bytes=trace.per_device_memory_bytes,
    )


def kernel_adjusted(rf: RooflineReport, trace: Trace, scope_pattern: str,
                    new_bytes: float, new_flops: Optional[float] = None,
                    hw: Hardware = V5E, label_suffix: str = "+kernel"
                    ) -> RooflineReport:
    """Roofline with one scope's XLA implementation replaced by a Pallas
    kernel's analytic traffic/FLOPs.

    The per-scope attribution (op_name metadata -> bytes_by_scope) is what
    makes this possible: e.g. replace every `attn`-scoped op's HBM traffic
    (XLA blocked attention writes scores per kv-chunk) with the flash
    kernel's q+k+v+o stream, which never spills scores.  This is the
    tracer's version of "what would this kernel buy me" — evaluated from
    the compiled artifact before writing a line of Mosaic.
    """
    import re as _re
    stats = trace.op_stats
    removed_b = sum(v for k, v in stats.bytes_by_scope.items()
                    if _re.search(scope_pattern, k))
    removed_f = sum(v for k, v in stats.flops_by_scope.items()
                    if _re.search(scope_pattern, k))
    new_hbm_bytes = max(trace.hlo_bytes - removed_b, 0.0) + new_bytes
    new_hlo_flops = trace.hlo_flops if new_flops is None else \
        max(trace.hlo_flops - removed_f, 0.0) + new_flops
    return RooflineReport(
        label=rf.label + label_suffix,
        chips=rf.chips,
        compute_s=new_hlo_flops / hw.flops_bf16,
        memory_s=new_hbm_bytes / hw.hbm_bw,
        collective_s=rf.collective_s,
        hlo_flops=new_hlo_flops,
        hlo_bytes=new_hbm_bytes,
        collective_bytes=rf.collective_bytes,
        model_flops=rf.model_flops,
        per_device_memory_bytes=rf.per_device_memory_bytes,
    )


def scenario_adjusted(rf: RooflineReport, result) -> RooflineReport:
    """Roofline with the collective term swapped for a what-if scenario's.

    `result` is a `whatif.ScenarioResult` over the same trace: compute
    and memory terms are untouched (a re-annotation moves no FLOPs or
    HBM bytes), the collective term and wire bytes come from the
    scenario's re-priced annotation.  The `kernel_adjusted` sibling for
    topology/protocol counterfactuals instead of Pallas kernels.
    """
    return RooflineReport(
        label=rf.label + "@" + result.scenario.name,
        chips=rf.chips,
        compute_s=rf.compute_s,
        memory_s=rf.memory_s,
        collective_s=result.est_s,
        hlo_flops=rf.hlo_flops,
        hlo_bytes=rf.hlo_bytes,
        collective_bytes=result.wire,
        model_flops=rf.model_flops,
        per_device_memory_bytes=rf.per_device_memory_bytes,
    )


def scenario_overlay_table(rf: RooflineReport, results, top: int = 3) -> str:
    """Baseline-vs-scenarios roofline rows for dryrun output.

    One row per scenario (ranked best first, `top` shown): the modeled
    collective term under the scenario, the resulting bound, and the
    step speedup vs the baseline roofline.
    """
    lines = [f"{'configuration':36s} {'collective':>11s} {'bound':>11s} "
             f"{'dominant':>10s} {'speedup':>8s}"]
    lines.append(f"{rf.label:36s} {rf.collective_s*1e3:10.2f}m "
                 f"{rf.bound_s*1e3:10.2f}m {rf.dominant:>10s} "
                 f"{'1.00x':>8s}")
    for r in results[:top]:
        adj = scenario_adjusted(rf, r)
        speed = rf.bound_s / adj.bound_s if adj.bound_s else float("inf")
        lines.append(f"{adj.label:36s} {adj.collective_s*1e3:10.2f}m "
                     f"{adj.bound_s*1e3:10.2f}m {adj.dominant:>10s} "
                     f"{speed:7.2f}x")
    return "\n".join(lines)


def scope_breakdown(trace: Trace, top: int = 12) -> str:
    """Per-scope bytes/FLOPs table (profiling view for the perf loop)."""
    stats = trace.op_stats
    scopes = sorted(stats.bytes_by_scope,
                    key=lambda k: -stats.bytes_by_scope[k])[:top]
    lines = [f"{'scope':52s} {'GB':>10s} {'GFLOP':>10s}"]
    for s in scopes:
        lines.append(f"{(s or '(unscoped)'):52s} "
                     f"{stats.bytes_by_scope[s]/1e9:10.2f} "
                     f"{stats.flops_by_scope.get(s, 0.0)/1e9:10.1f}")
    return "\n".join(lines)


def train_model_flops(n_params: int, n_tokens: int) -> float:
    """6 N D (dense) — pass active params for MoE."""
    return 6.0 * n_params * n_tokens


def decode_model_flops(n_params: int, n_tokens: int) -> float:
    """2 N per generated token (fwd only)."""
    return 2.0 * n_params * n_tokens
