"""The paper's primary contribution: multi-layer collective tracing for
JAX/TPU — HLO-parsed "UCT" events, mesh/link attribution, completion cost
model, scope/semantic ("UCP"/"MPI") attribution, detectors and reports.
"""
from repro.core.events import CollectiveEvent, Trace
from repro.core.topology import Hardware, MeshSpec, V5E
from repro.core.tracer import trace_compiled, trace_from_hlo, trace_step
from repro.core.roofline import RooflineReport, roofline

__all__ = [
    "CollectiveEvent", "Trace", "Hardware", "MeshSpec", "V5E",
    "trace_compiled", "trace_from_hlo", "trace_step",
    "RooflineReport", "roofline",
]
