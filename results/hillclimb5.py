import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Round 5: sort-based EP dispatch (beyond-paper) on the MoE cells.
# Prediction: dispatch/combine einsum FLOPs (O(T*E*C)) collapse to
# gather/scatter -> compute term down ~15-25%; one-hot table traffic gone.
import json
from hillclimb2 import run_variant
from hillclimb import attn_kernel_bytes

HERE = os.path.dirname(os.path.abspath(__file__))
rows = []
rows.append(run_variant("qwen3-moe-235b-a22b", "train_4k", "H21_sortEP",
                        {"moe_dispatch": "sort"}, {}, None, "train"))
rows.append(run_variant("qwen3-moe-235b-a22b", "train_4k",
                        "H22_sortEP+flash+accum4",
                        {"moe_dispatch": "sort"},
                        {"accum": 4}, (r"/attn", attn_kernel_bytes), "train"))
with open(os.path.join(HERE, "hillclimb5.json"), "w") as f:
    json.dump(rows, f, indent=1)
