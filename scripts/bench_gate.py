#!/usr/bin/env python
"""CI bench-trajectory gate.

Compares the speedup measured by this run's smoke benches against the
speedup recorded in the committed repo-root BENCH artifacts, and fails
when the run regresses below `--min-ratio` (default 0.8x) of the
recorded value.  Smoke and committed runs use different trace sizes, so
absolute times are not comparable — the *speedup ratio* is the
trajectory signal the ROADMAP asks CI to keep monotone.

Usage (from the repo root):

    python scripts/bench_gate.py \\
        results/BENCH_ingest_smoke.json:BENCH_ingest.json \\
        results/BENCH_render_smoke.json:BENCH_render.json \\
        results/BENCH_shard_smoke.json:BENCH_shard.json:0.5

Each positional argument is `run.json:committed.json[:min_ratio]` — the
optional third field overrides `--min-ratio` for that pair (the shard
bench's speedup is parallel-capacity-bound, so it gets more slack across
runner classes).  Both numbers are printed per bench, and appended to
$GITHUB_STEP_SUMMARY as a table when running under GitHub Actions.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("pairs", nargs="+", metavar="RUN:COMMITTED",
                    help="smoke-result path : committed-artifact path")
    ap.add_argument("--min-ratio", type=float, default=0.8,
                    help="fail when run speedup / committed speedup drops "
                         "below this (default 0.8)")
    args = ap.parse_args(argv)

    md = ["| bench | run speedup | committed speedup | ratio | min | gate |",
          "|---|---:|---:|---:|---:|---|"]
    failed = False
    for pair in args.pairs:
        parts = pair.split(":")
        if len(parts) == 2:
            (run_path, ref_path), min_ratio = parts, args.min_ratio
        elif len(parts) == 3:
            run_path, ref_path = parts[:2]
            try:
                min_ratio = float(parts[2])
            except ValueError:
                print(f"error: bad min ratio in pair {pair!r}",
                      file=sys.stderr)
                return 2
        else:
            print(f"error: bad pair {pair!r} "
                  "(want RUN:COMMITTED[:MIN_RATIO])", file=sys.stderr)
            return 2
        try:
            with open(run_path) as f:
                run = json.load(f)
            with open(ref_path) as f:
                ref = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: cannot read bench pair {pair}: {e}",
                  file=sys.stderr)
            return 2
        name = run.get("bench") or os.path.basename(ref_path)
        run_sp = float(run["speedup"])
        ref_sp = float(ref["speedup"])
        ratio = run_sp / ref_sp if ref_sp > 0 else float("inf")
        ok = ratio >= min_ratio
        failed |= not ok
        verdict = "OK" if ok else "FAIL"
        print(f"{name}: run {run_sp:.2f}x vs committed {ref_sp:.2f}x "
              f"-> ratio {ratio:.2f} [{verdict} >= {min_ratio}]")
        md.append(f"| {name} | {run_sp:.2f}x | {ref_sp:.2f}x | {ratio:.2f} "
                  f"| {min_ratio} | {verdict} |")

    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write("### bench trajectory gate\n\n")
            f.write("\n".join(md) + "\n")
    if failed:
        print(f"bench trajectory gate FAILED (min ratio {args.min_ratio})",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
