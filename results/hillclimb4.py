import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Round 4: with accum=1 + SP saves, is full remat still worth the ~33%
# recompute? (useful=0.67 -> prediction: remat=dots cuts compute term ~25%
# at a few GB of extra checkpoints)
import json
from hillclimb2 import run_variant
from hillclimb import attn_kernel_bytes

HERE = os.path.dirname(os.path.abspath(__file__))
rows = []
for name, remat in (("H19_sp+flash+acc1+dots", "dots"),
                    ("H20_sp+flash+acc1+none", "none")):
    rows.append(run_variant("chatglm3-6b", "train_4k", name, {},
                            {"seq_shard": True, "accum": 1, "remat": remat},
                            (r"/attn", attn_kernel_bytes), "train"))
with open(os.path.join(HERE, "hillclimb4.json"), "w") as f:
    json.dump(rows, f, indent=1)
