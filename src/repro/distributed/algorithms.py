"""Explicit collective algorithms (shard_map + ppermute/psum).

The paper's Fig 5 compares Open MPI vs MPICH Allreduce variants (recursive
doubling / reduce-scatter-allgather / ring) by their traced communication
patterns.  We implement the same three algorithms explicitly so the tracer
can show their distinct collective signatures on the TPU mesh, and compare
them against XLA's built-in all-reduce.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _axis_size(axis_name):
    return jax.lax.axis_size(axis_name)


def ring_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Textbook ring: n-1 reduce-scatter hops + n-1 all-gather hops, one
    1/n-payload neighbor ppermute per hop."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)                     # local copy of each chunk

    # reduce-scatter phase: device i ends up owning the full sum of
    # chunk (i+1) mod n
    carry = jnp.take(chunks, idx, axis=0)
    for s in range(n - 1):
        with jax.named_scope("ring_rs_hop"):
            carry = jax.lax.ppermute(carry, axis_name, perm)
            carry = carry + jnp.take(chunks, jnp.mod(idx - s - 1, n), axis=0)
    owned = jnp.mod(idx + 1, n)

    # all-gather phase: circulate the reduced chunks
    out = jnp.zeros_like(chunks)
    out = jax.lax.dynamic_update_index_in_dim(out, carry, owned, 0)
    cur = carry
    for s in range(n - 1):
        with jax.named_scope("ring_ag_hop"):
            cur = jax.lax.ppermute(cur, axis_name, perm)
            src_owner = jnp.mod(idx - s - 1, n)
            chunk_id = jnp.mod(src_owner + 1, n)
            out = jax.lax.dynamic_update_index_in_dim(out, cur, chunk_id, 0)
    res = out.reshape(-1)
    if pad:
        res = res[:flat.size - pad]
    return res.reshape(x.shape)


def xla_allreduce(x, axis_name):
    """XLA's built-in all-reduce (ring/torus schedule chosen by XLA)."""
    return jax.lax.psum(x, axis_name)


def rsag_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """reduce-scatter + all-gather via the dedicated collectives."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    with jax.named_scope("rsag_rs"):
        scattered = jax.lax.psum_scatter(flat.reshape(n, -1), axis_name,
                                         scatter_dimension=0, tiled=False)
    with jax.named_scope("rsag_ag"):
        gathered = jax.lax.all_gather(scattered, axis_name, tiled=False)
    out = gathered.reshape(-1)
    if pad:
        out = out[:flat.size - pad]
    return out.reshape(x.shape)


def recursive_doubling_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """log2(n) exchange rounds with partner at distance 2^k (full payload)."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    assert n & (n - 1) == 0, "recursive doubling needs power-of-two group"
    out = x
    for k in range(int(math.log2(n))):
        d = 1 << k
        perm = [(i, i ^ d) for i in range(n)]
        with jax.named_scope(f"recdbl_round{k}"):
            out = out + jax.lax.ppermute(out, axis_name, perm)
    return out


ALGORITHMS = {
    "xla": xla_allreduce,              # XLA's all-reduce (baseline)
    "ring": ring_allreduce,
    "rsag": rsag_allreduce,
    "recursive_doubling": recursive_doubling_allreduce,
}


def allreduce_fn(algorithm: str, mesh, axis_name: str = "data",
                 keep_specs: P = None):
    """shard_map-wrapped allreduce over one mesh axis."""
    fn = ALGORITHMS[algorithm]

    @functools.partial(shard_map, mesh=mesh, in_specs=P(axis_name),
                       out_specs=P(axis_name), check_rep=False)
    def run(shard):
        return fn(shard, axis_name)

    return run
