from repro.checkpoint.store import (AsyncCheckpointer, latest_step, prune_old,
                                    restore, save)

__all__ = ["save", "restore", "latest_step", "prune_old", "AsyncCheckpointer"]
