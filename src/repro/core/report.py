"""Renderers: ASCII / JSON / self-contained HTML (the Fig 3 visualizer).

Views (paper analogues):
  * top-contenders table   — Table II: bytes% (count%) per kind x link class
  * communication matrix   — Fig 3b heatmap over mesh coordinates
  * device view            — Fig 3d: per-link-class traffic graph
  * timeline               — Fig 3a: modeled serialized collective schedule
  * semantic breakdown     — the MPI-function layer rollup

The renderers are columnar by default: everything events-proportional
(the JSON event array, the table rollups, the timeline sort) emits
straight from `TraceStore` columns — vocab entries are formatted once
and broadcast through codes, rows never materialize as
`CollectiveEvent` objects, and `write_json`/`write_html` stream the
output in bounded chunks so a 1M-site trace renders without holding
the rendered text (or the row objects) in memory.  The per-event walk
is retained behind `engine="rows"` as the reference; the columnar
output is pinned **byte-identical** to it by tests/test_render.py and
`benchmarks/bench_overhead.py --render-only` (BENCH_render.json),
mirroring the PR 3 ingest pattern.  The mesh-sized sections (summary
line, comm-matrix heatmaps) are shared between engines — they do not
scale with events.
"""
from __future__ import annotations

import html as html_mod
import json
from json.encoder import encode_basestring_ascii as _esc_json
from typing import IO, Iterator, List, Optional

import numpy as np

from repro.core import commcheck
from repro.core.diff import KEY_FNS, _norm_by, diff_n
from repro.core.events import Trace
from repro.core.topology import MeshSpec, comm_matrix, reduce_matrix

# comm-matrix guard: above this per-axis device count the O(n^2) cell grid
# is replaced by a top-K pair summary (the 256+-device renderer fall-over)
MATRIX_MAX_DIM = 64
MATRIX_TOP_K = 32


def trace_findings(trace: Trace):
    """Static-analysis findings for a trace, cached on the trace object.

    Both render engines (and both of `to_json`/`to_html`) share one
    commcheck pass per store: the cache key is the store identity, so a
    mutated/invalidated trace re-analyzes while repeat renders are free.
    """
    store = trace.store
    cached = getattr(trace, "_report_findings", None)
    if cached is not None and cached[0] is store:
        return cached[1]
    findings = commcheck.check_trace(trace)
    trace._report_findings = (store, findings)
    return findings


# --------------------------------------------------------------------------
# ASCII
# --------------------------------------------------------------------------

_CONTENDERS_HEAD = (f"{'key':44s} {'bytes%':>8s} {'count%':>8s} {'GB':>10s} "
                    f"{'count':>8s} {'est_ms':>8s}")


def _contenders_text(rows, tot_b: float, tot_c: float, tot_t: float) -> str:
    """Shared formatter: rows are (key, bytes, count, time_s) tuples."""
    tot_b = tot_b or 1.0
    tot_c = tot_c or 1.0
    lines = [_CONTENDERS_HEAD]
    for k, b, c, t in rows:
        lines.append(
            f"{k:44s} {100*b/tot_b:7.1f}% {100*c/tot_c:7.1f}% "
            f"{b/1e9:10.3f} {int(c):8d} {t*1e3:8.3f}")
    lines.append(f"{'total':44s} {'100.0%':>8s} {'100.0%':>8s} "
                 f"{tot_b/1e9:10.3f} {int(tot_c):8d} {tot_t*1e3:8.3f}")
    return "\n".join(lines)


def top_contenders_table(trace: Trace, by: str = "kind_link",
                         engine: str = "columnar") -> str:
    """Bytes% (count%) per traffic class — Table II analogue.

    Rows sort by descending bytes, ties alphabetically (a total order, so
    both engines render identically).  The total-ms cell accumulates in
    row order on both paths for the same reason (`serial_est_time_s`).
    """
    by = _norm_by(by)
    if engine == "rows":
        agg = trace.by(KEY_FNS[by])
        items = sorted(agg.items(), key=lambda kv: (-kv[1]["bytes"], kv[0]))
        rows = [(k, a["bytes"], a["count"], a["time_s"]) for k, a in items]
        tot_t = 0.0
        for e in trace.events:
            tot_t += e.est_time_s * e.multiplicity
        return _contenders_text(rows,
                                sum(a["bytes"] for a in agg.values()),
                                sum(a["count"] for a in agg.values()), tot_t)
    s = trace.store
    labels, mat = s.rollup(by)
    if labels:
        alph = np.argsort(np.asarray(labels))
        b, c, t = mat[0][alph], mat[2][alph], mat[3][alph]
        order = np.argsort(-b, kind="stable")
        rows = [(labels[int(alph[i])], float(b[i]), float(c[i]), float(t[i]))
                for i in (int(j) for j in order)]
    else:
        rows = []
    return _contenders_text(rows, float(mat[0].sum()), float(mat[2].sum()),
                            s.serial_est_time_s())


def semantic_table(trace: Trace, engine: str = "columnar") -> str:
    return top_contenders_table(trace, by="semantic", engine=engine)


def ascii_matrix(mat: np.ndarray, labels: Optional[List[str]] = None,
                 width: int = 9) -> str:
    n = mat.shape[0]
    labels = labels or [str(i) for i in range(n)]
    peak = mat.max() or 1.0
    shades = " .:-=+*#%@"
    out = []
    for i in range(n):
        row = "".join(shades[min(int(mat[i, j] / peak * (len(shades) - 1)),
                                 len(shades) - 1)] for j in range(n))
        out.append(f"{labels[i]:>6s} |{row}|")
    return "\n".join(out)


_TIMELINE_HEAD = (f"{'t_start_us':>10s} {'dur_us':>9s} {'x':>5s} {'kind':18s} "
                  f"{'link':16s} {'semantic':14s} scope")


def timeline(trace: Trace, top: int = 30, engine: str = "columnar") -> str:
    """Modeled serialized schedule of the heaviest collectives (Fig 3a)."""
    lines = [_TIMELINE_HEAD]
    t = 0.0
    if engine == "rows":
        evs = trace.events
        order = sorted(range(len(evs)),
                       key=lambda i: -(evs[i].est_time_s
                                       * evs[i].multiplicity))[:top]
        for i in order:
            e = evs[i]
            lines.append(f"{t*1e6:10.1f} {e.est_time_s*1e6:9.2f} "
                         f"{e.multiplicity:5d} {e.kind:18s} "
                         f"{e.link_class:16s} {e.semantic:14s} "
                         f"{e.scope[:48]}")
            t += e.est_time_s * e.multiplicity
        return "\n".join(lines)
    s = trace.store
    step = s.est_time_s * s.weights
    order = np.argsort(-step, kind="stable")[:top]
    # vocab lookups + float products only for the selected rows
    rows = zip((s.est_time_s[order] * 1e6).tolist(), step[order].tolist(),
               s.multiplicity[order].tolist(),
               [s.kind.vocab[c] for c in s.kind.codes[order].tolist()],
               [s.link_class.vocab[c]
                for c in s.link_class.codes[order].tolist()],
               [s.semantic.vocab[c] for c in s.semantic.codes[order].tolist()],
               [s.scope.vocab[c][:48] for c in s.scope.codes[order].tolist()])
    for dur, dt, mult, kind, link, sem, scope in rows:
        lines.append(f"{t*1e6:10.1f} {dur:9.2f} {mult:5d} {kind:18s} "
                     f"{link:16s} {sem:14s} {scope}")
        t += dt
    return "\n".join(lines)


def summary(trace: Trace) -> str:
    n_ev = int(trace.store.multiplicity.sum())
    return (
        f"trace '{trace.label}': mesh {trace.mesh_shape} axes {trace.mesh_axes}\n"
        f"  collectives/step: {n_ev} ({trace.store.n} sites)\n"
        f"  collective bytes (operand conv): {trace.total_collective_bytes()/1e9:.3f} GB/device\n"
        f"  wire bytes: {trace.total_wire_bytes()/1e9:.3f} GB total\n"
        f"  modeled collective time: {trace.total_est_time_s()*1e3:.3f} ms (serialized)\n"
        f"  HLO flops/device: {trace.hlo_flops/1e12:.3f} T, bytes: {trace.hlo_bytes/1e9:.2f} GB\n"
        f"  per-device memory: {trace.per_device_memory_bytes/1e9:.2f} GB")


# --------------------------------------------------------------------------
# n-way session comparison (the "Allreduce across MPI libraries" table)
# --------------------------------------------------------------------------

def session_table(traces, by: str = "kind_link", metric: str = "bytes",
                  top: int = 24) -> str:
    """N-way comparison: one row per traffic class, one column per trace.

    `traces` is any sequence of Trace (a TraceSession iterates as one).
    `metric` selects the cell value: bytes (GB), time (ms), or count.
    `by="site"` keys rows on the interned op_name x kind x axes triple —
    the per-callsite view.  The paper's cross-run experiment shape (UCX
    settings / MPI libraries / NUMA bindings) as a single table —
    `diff.render_diff` stays the two-column deep-dive.
    """
    traces = list(traces)
    if not traces:
        return "(empty session)"
    rows = diff_n(traces, by)
    labels = [t.label for t in traces]
    scale, unit = {"bytes": (1e-9, "GB"), "time": (1e3, "ms"),
                   "count": (1.0, "x")}[metric]
    width = max(10, max(len(l) for l in labels) + 1)
    head = f"{'key (' + unit + ')':42s} " + \
        " ".join(f"{l[:width-1]:>{width}s}" for l in labels) + "  verdict"
    lines = [f"session comparison ({len(traces)} traces, by {by})", head]
    for r in rows[:top]:
        vals = {"bytes": r.bytes_, "time": r.times, "count": r.counts}[metric]
        cells = " ".join(f"{v*scale:{width}.3f}" for v in vals)
        lines.append(f"{r.key:42s} {cells}  {r.verdict()}")
    if len(rows) > top:
        lines.append(f"... ({len(rows) - top} more classes)")
    totals = [t.total_est_time_s() * 1e3 for t in traces]
    lines.append(f"{'TOTAL modeled collective ms':42s} " +
                 " ".join(f"{v:{width}.3f}" for v in totals) +
                 ("  best=" + labels[int(np.argmin(totals))] if totals else ""))
    return "\n".join(lines)


# --------------------------------------------------------------------------
# JSON / HTML
# --------------------------------------------------------------------------

def _embed(value, depth: int) -> str:
    """`json.dumps(value, indent=1)` re-indented for embedding at `depth`."""
    return json.dumps(value, indent=1).replace("\n", "\n" + " " * depth)


# one event object of the `indent=1` document; string args arrive
# pre-escaped (with quotes), est_time_us pre-formatted via float repr —
# the exact text `json.dumps` produces for the same values.
_EVENT_TMPL = (
    '  {\n   "name": %s,\n   "kind": %s,\n   "bytes": %d,\n   "mult": %d,\n'
    '   "link": %s,\n   "axes": %s,\n   "semantic": %s,\n   "scope": %s,\n'
    '   "prim": %s,\n   "protocol": %s,\n   "group_size": %d,\n'
    '   "num_groups": %d,\n   "est_time_us": %s\n  }')


def iter_json(trace: Trace, chunk_sites: int = 8192) -> Iterator[str]:
    """Generator over the JSON document text, `chunk_sites` events at a
    time — the streaming core of `to_json`/`write_json`.

    Emits straight from store columns: per-vocab strings are escaped once
    (axes tables pre-rendered as embedded arrays) and broadcast through
    codes; numeric columns convert chunk-wise via `.tolist()`.  Output is
    byte-identical to `json.dumps(..., indent=1)` over the per-event dict
    (`engine="rows"`), which pure-Python-encodes when an indent is set.
    """
    s = trace.store
    head = "{\n" + ",\n".join(
        f' "{k}": {_embed(v, 1)}' for k, v in (
            ("label", trace.label),
            ("mesh_shape", list(trace.mesh_shape)),
            ("mesh_axes", list(trace.mesh_axes)),
            ("hlo_flops", trace.hlo_flops),
            ("hlo_bytes", trace.hlo_bytes),
            ("per_device_memory_bytes", trace.per_device_memory_bytes),
            ("findings", [f.to_dict() for f in trace_findings(trace)])))
    if s.n == 0:
        yield head + ',\n "events": []\n}'
        return
    yield head + ',\n "events": ['
    kindv = [_esc_json(v) for v in s.kind.vocab]
    linkv = [_esc_json(v) for v in s.link_class.vocab]
    semv = [_esc_json(v) for v in s.semantic.vocab]
    scopev = [_esc_json(v) for v in s.scope.vocab]
    primv = [_esc_json(v) for v in s.jax_prim.vocab]
    protov = [_esc_json(v) for v in s.protocol.vocab]
    axesv = [_embed(list(t), 3) for t in s.axes_tables]
    sep = "\n"
    for lo in range(0, s.n, max(chunk_sites, 1)):
        hi = min(lo + max(chunk_sites, 1), s.n)
        rows = zip(
            s.names[lo:hi],
            s.kind.codes[lo:hi].tolist(), s.operand_bytes[lo:hi].tolist(),
            s.multiplicity[lo:hi].tolist(),
            s.link_class.codes[lo:hi].tolist(), s.axes_code[lo:hi].tolist(),
            s.semantic.codes[lo:hi].tolist(), s.scope.codes[lo:hi].tolist(),
            s.jax_prim.codes[lo:hi].tolist(),
            s.protocol.codes[lo:hi].tolist(), s.group_size[lo:hi].tolist(),
            s.num_groups[lo:hi].tolist(),
            (s.est_time_s[lo:hi] * 1e6).tolist())
        yield sep + ",\n".join(
            _EVENT_TMPL % (_esc_json(nm), kindv[kc], ob, mu, linkv[lc],
                           axesv[ac], semv[sc], scopev[scp], primv[pc],
                           protov[prc], gs, ng, repr(us))
            for (nm, kc, ob, mu, lc, ac, sc, scp, pc, prc, gs, ng, us)
            in rows)
        sep = ",\n"
    yield "\n ]\n}"


def to_json(trace: Trace, engine: str = "columnar") -> str:
    if engine == "rows":
        return json.dumps({
            "label": trace.label,
            "mesh_shape": trace.mesh_shape,
            "mesh_axes": trace.mesh_axes,
            "hlo_flops": trace.hlo_flops,
            "hlo_bytes": trace.hlo_bytes,
            "per_device_memory_bytes": trace.per_device_memory_bytes,
            "findings": [f.to_dict() for f in trace_findings(trace)],
            "events": [{
                "name": e.name, "kind": e.kind, "bytes": e.operand_bytes,
                "mult": e.multiplicity, "link": e.link_class,
                "axes": e.axes, "semantic": e.semantic, "scope": e.scope,
                "prim": e.jax_prim, "protocol": e.protocol,
                "group_size": e.group_size, "num_groups": e.num_groups,
                "est_time_us": e.est_time_s * 1e6,
            } for e in trace.events],
        }, indent=1)
    return "".join(iter_json(trace))


def write_json(trace: Trace, fp: IO[str], chunk_sites: int = 8192) -> None:
    """Stream the JSON report to `fp` in bounded memory."""
    for chunk in iter_json(trace, chunk_sites):
        fp.write(chunk)


_HTML_HEAD = """<!doctype html><meta charset="utf-8">
<title>repro trace: %s</title>
<style>
 body{font:13px monospace;background:#111;color:#ddd;margin:24px}
 h2{color:#7fd} table{border-collapse:collapse;margin:12px 0}
 td,th{border:1px solid #333;padding:3px 8px;text-align:right}
 th{background:#222;color:#7fd} td.l{text-align:left}
 .hm td{width:14px;height:14px;padding:0;border:1px solid #222}
 .bar{background:#167;display:inline-block;height:10px}
</style>"""


def iter_html(trace: Trace, mesh: MeshSpec,
              engine: str = "columnar") -> Iterator[str]:
    """Generator over the HTML report sections (join with newlines)."""
    yield _HTML_HEAD % html_mod.escape(trace.label)
    yield f"<h1>trace: {html_mod.escape(trace.label)}</h1>"
    yield "<pre>" + html_mod.escape(summary(trace)) + "</pre>"

    # static-analysis findings (shared between engines; one pass per store)
    findings = trace_findings(trace)
    yield "<h2>commcheck findings (static analysis)</h2>"
    if not findings:
        yield "<pre>no findings — collective structure checks clean</pre>"
    else:
        rows = ["<table><tr><th>severity</th><th>code</th><th>site</th>"
                "<th>MB at risk</th><th class='l'>message</th>"
                "<th class='l'>recommendation</th></tr>"]
        for f in findings[:50]:
            rows.append(
                f"<tr><td>{html_mod.escape(f.severity)}</td>"
                f"<td class='l'>{html_mod.escape(f.detector)}</td>"
                f"<td class='l'>{html_mod.escape(f.site)}</td>"
                f"<td>{f.wasted_bytes/1e6:.2f}</td>"
                f"<td class='l'>{html_mod.escape(f.message)}</td>"
                f"<td class='l'>{html_mod.escape(f.recommendation)}</td></tr>")
        if len(findings) > 50:
            rows.append(f"<tr><td colspan='6' class='l'>... "
                        f"({len(findings) - 50} more)</td></tr>")
        rows.append("</table>")
        yield "".join(rows)

    # top contenders
    yield "<h2>top contenders (kind x link) — Table II analogue</h2>"
    yield "<pre>" + html_mod.escape(
        top_contenders_table(trace, engine=engine)) + "</pre>"
    yield "<h2>semantic (MPI-layer analogue)</h2>"
    yield "<pre>" + html_mod.escape(
        semantic_table(trace, engine=engine)) + "</pre>"

    # comm matrix heatmaps per axis (mesh-sized; shared between engines)
    mat = comm_matrix(mesh, trace)
    for axis in mesh.axes:
        red = reduce_matrix(mat, mesh, axis)
        peak = red.max() or 1.0
        yield f"<h2>comm matrix over axis '{axis}' (GB)</h2>"
        if red.shape[0] > MATRIX_MAX_DIM:
            # big-mesh guard: n^2 <td> cells fall over past ~256 devices —
            # summarize the heaviest pairs instead of painting the grid
            flat = red.ravel()
            k = min(MATRIX_TOP_K, int((flat > 0).sum()))
            top = np.argsort(-flat, kind="stable")[:k]
            rows = [f"<p>{red.shape[0]}x{red.shape[1]} matrix "
                    f"(&gt; {MATRIX_MAX_DIM} groups) — top {k} pairs of "
                    f"{int((flat > 0).sum())} nonzero, "
                    f"{flat.sum()/1e9:.3f} GB total</p>",
                    "<table><tr><th>src</th><th>dst</th><th>GB</th>"
                    "<th class='l'>share</th></tr>"]
            for idx in top.tolist():
                i, j = divmod(idx, red.shape[1])
                bar = int(120 * flat[idx] / peak)
                rows.append(
                    f"<tr><td>{i}</td><td>{j}</td>"
                    f"<td>{flat[idx]/1e9:.3f}</td>"
                    f"<td class='l'><span class='bar' "
                    f"style='width:{bar}px'></span></td></tr>")
            rows.append("</table>")
            yield "".join(rows)
            continue
        rows = ["<table class='hm'>"]
        for i in range(red.shape[0]):
            cells = []
            for j in range(red.shape[1]):
                v = red[i, j] / peak
                col = f"rgb({int(20+v*40)},{int(30+v*160)},{int(60+v*180)})"
                cells.append(f"<td style='background:{col}' "
                             f"title='{i}->{j}: {red[i,j]/1e9:.3f} GB'></td>")
            rows.append("<tr>" + "".join(cells) + "</tr>")
        rows.append("</table>")
        yield "".join(rows)

    # timeline
    yield "<h2>modeled timeline (top collectives)</h2>"
    yield "<pre>" + html_mod.escape(timeline(trace, engine=engine)) + "</pre>"


def to_html(trace: Trace, mesh: MeshSpec, engine: str = "columnar") -> str:
    """Self-contained HTML report (the interactive-visualizer analogue)."""
    return "\n".join(iter_html(trace, mesh, engine))


def write_html(trace: Trace, mesh: MeshSpec, fp: IO[str]) -> None:
    """Stream the HTML report to `fp` section by section."""
    for i, part in enumerate(iter_html(trace, mesh)):
        fp.write(("\n" if i else "") + part)
