"""Table III analogue: tracer overhead.

ucTrace interposes at runtime (1.3x-25x slowdown, GB-scale logs).  Our trace
is compile-time: the overhead is pure offline analysis (HLO parse + assembly)
on top of an unavoidable lower+compile, with zero runtime cost.  We measure
lower/compile/parse wall time and trace size for a dense and a MoE step.

Also measures the *analysis* hot path at the paper's experiment scale: a
100k-event synthetic trace aggregated by (kind x link) + semantic, columnar
(`TraceStore` bincount) vs the per-event Python reference — the columnar
path must be >= 5x faster.
"""
from __future__ import annotations

import json
import time

from _util import run_worker

WORKER = """
import json, time
import jax, jax.numpy as jnp
from repro.configs import ARCHS, smoke_config
from repro.core import MeshSpec, trace_from_hlo
from repro.core.report import to_json
from repro.distributed import sharding as sh
from repro.distributed.autoshard import activation_sharding
from repro.launch.presets import StepSettings
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import adamw

mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = MeshSpec((2, 4), ("data", "model"))
rows = []
for arch in ("chatglm3-6b", "qwen3-moe-235b-a22b"):
    cfg = smoke_config(ARCHS[arch]).replace(
        d_model=128, d_ff=256, moe_d_ff=256 if ARCHS[arch].num_experts else 0,
        num_layers=8, vocab_size=512, num_heads=8, num_kv_heads=4, head_dim=16)
    st = StepSettings(accum=2, remat="full")
    step = make_train_step(cfg, adamw.AdamWConfig(), st)
    params = api.abstract_params(cfg)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = {"m": jax.tree.map(f32, params), "v": jax.tree.map(f32, params),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    shape = type("S", (), {"global_batch": 8, "seq_len": 128, "kind": "train"})()
    batch = api.batch_specs(cfg, shape)
    pspecs = sh.param_pspecs(cfg, mesh)
    jfn = jax.jit(step, in_shardings=(
        sh.named(mesh, pspecs),
        sh.named(mesh, {"m": pspecs, "v": pspecs,
                        "count": jax.sharding.PartitionSpec()}), None),
        donate_argnums=(0, 1))
    t0 = time.perf_counter()
    with activation_sharding(mesh):
        lowered = jfn.lower(params, opt, batch)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    text = compiled.as_text()
    tr = trace_from_hlo(text, spec, label=arch,
                        cost_analysis=compiled.cost_analysis(),
                        memory_analysis=compiled.memory_analysis())
    t3 = time.perf_counter()
    js = to_json(tr)
    rows.append((f"overhead/{arch}/lower", (t1 - t0) * 1e6, "baseline-cost"))
    rows.append((f"overhead/{arch}/compile", (t2 - t1) * 1e6, "baseline-cost"))
    rows.append((f"overhead/{arch}/trace_parse", (t3 - t2) * 1e6,
                 f"overhead_ratio={(t3-t2)/max(t2-t0,1e-9):.3f}|"
                 f"hlo_KB={len(text)//1024}|trace_KB={len(js)//1024}|"
                 f"runtime_overhead=0x (compile-time tool)"))
print("JSON" + json.dumps(rows))
"""


def _agg_100k_case(n_sites: int = 100_000, iters: int = 3):
    """Columnar vs per-event aggregation on a 100k-event synthetic trace."""
    from repro.core.synth import synthetic_trace
    from repro.core.topology import MeshSpec

    tr = synthetic_trace("agg100k", MeshSpec((2, 4), ("data", "model")),
                         n_sites=n_sites, seed=0)

    def legacy():
        a = tr.by(lambda e: f"{e.kind}|{e.link_class}")
        b = tr.by(lambda e: e.semantic or "other")
        return a, b

    def columnar():
        return tr.by_kind_and_link(), tr.by_semantic()

    t0 = time.perf_counter()
    build = tr.store                      # one-time column build, timed apart
    t_build = (time.perf_counter() - t0) * 1e6
    assert build.n == n_sites

    t0 = time.perf_counter()
    for _ in range(iters):
        ref = legacy()
    t_legacy = (time.perf_counter() - t0) / iters * 1e6

    t0 = time.perf_counter()
    for _ in range(iters):
        col = columnar()
    t_col = (time.perf_counter() - t0) / iters * 1e6

    # equivalence guard: same keys, same byte totals
    match = all(
        set(r) == set(c)
        and all(abs(r[k]["bytes"] - c[k]["bytes"]) < 1e-6 for k in r)
        for r, c in zip(ref, col))
    speedup = t_legacy / max(t_col, 1e-9)
    return [
        (f"overhead/agg{n_sites//1000}k/per_event", t_legacy, "baseline-cost"),
        (f"overhead/agg{n_sites//1000}k/columnar", t_col,
         f"speedup={speedup:.1f}x|target>=5x|sites={n_sites}|"
         f"store_build_us={t_build:.0f}|equivalent={match}"),
    ]


def run():
    rows = _agg_100k_case()
    out = run_worker(WORKER, devices=8)
    for line in out.splitlines():
        if line.startswith("JSON"):
            return rows + [tuple(r) for r in json.loads(line[4:])]
    raise RuntimeError("no JSON output from worker")
