"""Flash attention Pallas kernel (TPU target; validated with interpret=True).

TPU-native design (not a CUDA port):
  * BlockSpec tiling keeps one (bq x d) query tile + one (bk x d) KV tile in
    VMEM; the MXU sees (bq x d) @ (d x bk) and (bq x bk) @ (bk x d) matmuls
    with d and bk multiples of 128 (bq a multiple of 8 for fp32 sublanes).
  * online softmax: running (m, l, acc) live in VMEM scratch across the
    sequential kv grid dimension — scores NEVER touch HBM (the whole point;
    the XLA `blocked` path writes them per chunk, see EXPERIMENTS.md §Perf).
  * causal + sliding-window block skipping via `pl.when`: fully-masked
    (q-block, kv-block) pairs skip both MXU passes, recovering the ~2x
    triangular waste the XLA path pays.
  * GQA: grid is (B, H, nq, nk); the kv head index is h // (H // K) in the
    index_map, so no KV replication in HBM.

head_dim is padded to a multiple of 128 by the wrapper (h2o-danube: 120).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, q_offset: int,
            bq: int, bk: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq + q_offset
    k_start = ki * bk

    # block-level relevance: skip blocks fully above the causal diagonal or
    # fully outside the sliding window
    relevant = True
    if causal:
        relevant = jnp.logical_and(True, k_start <= q_start + bq - 1)
    if window > 0:
        relevant = jnp.logical_and(relevant,
                                   k_start + bk - 1 >= q_start - window + 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, k_idx <= q_idx)
        if window > 0:
            ok = jnp.logical_and(ok, (q_idx - k_idx) < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                            # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                         # [bq, bk]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    scale=None, bq=128, bk=128, interpret=False):
    """q [B,H,Sq,D], k/v [B,K,Skv,D] -> [B,H,Sq,D].  D % 128 == 0."""
    B, H, Sq, D = q.shape
    K, Skv = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),   # l (running denom)
            pltpu.VMEM((bq, D), jnp.float32),   # acc (unnormalized out)
        ],
        interpret=interpret,
    )(q, k, v)
