"""Model facade: one entry point per family for meta/init/forward/serve,
plus ShapeDtypeStruct input specs for the dry-run.

Every function takes the `ModelConfig` first; family dispatch happens here
so launch/, training/ and the tracer never branch on family themselves.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import encdec, transformer
from repro.models import meta as meta_mod
from repro.models.losses import fused_next_token_loss


def n_image_patches(cfg, seq_len: int) -> int:
    """Static patch count for the VLM stub frontend."""
    return min(1024, max(1, seq_len // 4))


def model_meta(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.model_meta(cfg)
    return transformer.model_meta(cfg)


def init_params(cfg: ModelConfig, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    return meta_mod.materialize(model_meta(cfg), key, cfg.param_dtype)


def abstract_params(cfg: ModelConfig, dtype: str = None):
    return meta_mod.abstract(model_meta(cfg), dtype or cfg.param_dtype)


def param_logical_axes(cfg: ModelConfig):
    return meta_mod.logical_axes(model_meta(cfg))


def param_count(cfg: ModelConfig) -> int:
    return meta_mod.param_count(model_meta(cfg))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k of num_experts experts)."""
    total = param_count(cfg)
    if not cfg.num_experts:
        return total
    expert_p = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts * cfg.num_layers
    active_expert_p = expert_p * cfg.top_k // cfg.num_experts
    return total - expert_p + active_expert_p


def flops_param_count(cfg: ModelConfig) -> int:
    """N for MODEL_FLOPS = 6·N·D: active matmul params per token.

    Excludes the embedding gather (0 matmul FLOPs) and learned position
    tables; includes the LM-head matmul (D x V) whether tied or not.
    """
    n = active_param_count(cfg)
    n -= cfg.vocab_size * cfg.d_model          # in_table gather
    if cfg.rope == "learned":
        n -= (cfg.source_len + cfg.max_positions) * cfg.d_model
    if cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model      # tied head still matmuls
    return n


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------

def forward(cfg, params, batch, *, attn_impl="auto", remat="none"):
    if cfg.family == "encdec":
        return encdec.forward(cfg, params, batch, attn_impl=attn_impl,
                              remat=remat)
    return transformer.forward(cfg, params, batch, attn_impl=attn_impl,
                               remat=remat)


def loss_fn(cfg, params, batch, *, attn_impl="auto", remat="none",
            embed_impl="onehot"):
    """Training loss: fused head+xent on hidden states (no [B,S,V] logits)."""
    mod = encdec if cfg.family == "encdec" else transformer
    hidden, aux = mod.forward_hidden(cfg, params, batch, attn_impl=attn_impl,
                                     remat=remat, embed_impl=embed_impl)
    return fused_next_token_loss(cfg, params["embed"], hidden, batch, aux)


def prefill(cfg, params, batch, *, attn_impl="auto", cache_len=None):
    if cfg.family == "encdec":
        return encdec.prefill(cfg, params, batch, attn_impl=attn_impl,
                              cache_len=cache_len)
    return transformer.prefill(cfg, params, batch, attn_impl=attn_impl,
                               cache_len=cache_len)


def decode_step(cfg, params, cache, tokens, pos, *, positions=None):
    if cfg.family == "encdec":
        return encdec.decode_step(cfg, params, cache, tokens, pos)
    return transformer.decode_step(cfg, params, cache, tokens, pos,
                                   positions=positions)


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Train/prefill batch structure for (cfg, shape)."""
    B, S = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    if cfg.family == "encdec":
        return {"frame_embeds": _sds((B, cfg.source_len, cfg.d_model), f32),
                "tokens": _sds((B, S), i32)}
    if cfg.family == "vlm":
        n_img = n_image_patches(cfg, S)
        return {"patch_embeds": _sds((B, n_img, cfg.d_model), f32),
                "tokens": _sds((B, S - n_img), i32),
                "positions": _sds((3, B, S), i32)}
    return {"tokens": _sds((B, S), i32)}


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16):
    """Decode-cache structure for (cfg, shape).

    Stacked dict {k: [L,B,Sc,K,Dh], ...} when all layers share one KV
    length (decode scans over layers — single-layer buffer liveness, fast
    compiles); per-layer list for heterogeneous windowed retention
    (gemma3/hymba at 500k) and enc-dec.
    """
    B, S = shape.global_batch, shape.seq_len
    windows = cfg.layer_windows()
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    di = cfg.expand * cfg.d_model
    Ln = cfg.num_layers

    if cfg.family != "encdec" and transformer.uniform_cache(
            cfg, shape.windowed_cache):
        entry: Dict[str, Any] = {}
        if cfg.family != "ssm":
            w = windows[0]
            sc = min(S, w) if (shape.windowed_cache and w > 0) else S
            entry["k"] = _sds((Ln, B, sc, K, Dh), dtype)
            entry["v"] = _sds((Ln, B, sc, K, Dh), dtype)
        if cfg.family in ("ssm", "hybrid"):
            entry["conv"] = _sds((Ln, B, cfg.d_conv - 1, di), jnp.float32)
            entry["ssm"] = _sds((Ln, B, di, cfg.ssm_state), jnp.float32)
        return entry

    out = []
    for li in range(Ln):
        entry = {}
        if cfg.family != "ssm":
            w = windows[li]
            sc = min(S, w) if (shape.windowed_cache and w > 0) else S
            entry["k"] = _sds((B, sc, K, Dh), dtype)
            entry["v"] = _sds((B, sc, K, Dh), dtype)
        if cfg.family in ("ssm", "hybrid"):
            entry["conv"] = _sds((B, cfg.d_conv - 1, di), jnp.float32)
            entry["ssm"] = _sds((B, di, cfg.ssm_state), jnp.float32)
        if cfg.family == "encdec":
            entry["cross_k"] = _sds((B, cfg.source_len, K, Dh), dtype)
            entry["cross_v"] = _sds((B, cfg.source_len, K, Dh), dtype)
        out.append(entry)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B = shape.global_batch
    specs = {"cache": cache_specs(cfg, shape),
             "tokens": _sds((B, 1), jnp.int32),
             "pos": _sds((), jnp.int32)}
    if cfg.family == "vlm":
        specs["positions"] = _sds((3, B, 1), jnp.int32)
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """All step inputs (minus params) for the (cfg, shape) cell."""
    if shape.kind in ("train", "prefill"):
        return {"batch": batch_specs(cfg, shape)}
    return decode_input_specs(cfg, shape)


# --------------------------------------------------------------------------
# concrete demo batches (smoke tests / examples)
# --------------------------------------------------------------------------

def demo_batch(cfg: ModelConfig, batch_size: int, seq_len: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    if cfg.family == "encdec":
        k1, k2 = jax.random.split(key)
        return {"frame_embeds": jax.random.normal(
                    k1, (batch_size, cfg.source_len, cfg.d_model), jnp.float32),
                "tokens": jax.random.randint(
                    k2, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.family == "vlm":
        n_img = n_image_patches(cfg, seq_len)
        k1, k2 = jax.random.split(key)
        pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32),
                               (3, batch_size, seq_len))
        return {"patch_embeds": jax.random.normal(
                    k1, (batch_size, n_img, cfg.d_model), jnp.float32),
                "tokens": jax.random.randint(
                    k2, (batch_size, seq_len - n_img), 0, cfg.vocab_size, jnp.int32),
                "positions": pos}
    return {"tokens": jax.random.randint(
        key, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)}
