#!/usr/bin/env python
"""CI bench-trajectory gate.

Compares the speedup measured by this run's smoke benches against the
speedup recorded in the committed repo-root BENCH artifacts, and fails
when the run regresses below `--min-ratio` (default 0.8x) of the
recorded value.  Smoke and committed runs use different trace sizes, so
absolute times are not comparable — the *speedup ratio* is the
trajectory signal the ROADMAP asks CI to keep monotone.

Usage (from the repo root):

    python scripts/bench_gate.py \\
        results/BENCH_ingest_smoke.json:BENCH_ingest.json \\
        results/BENCH_render_smoke.json:BENCH_render.json

Each positional argument is `run.json:committed.json`.  Both numbers are
printed per bench, and appended to $GITHUB_STEP_SUMMARY as a table when
running under GitHub Actions.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("pairs", nargs="+", metavar="RUN:COMMITTED",
                    help="smoke-result path : committed-artifact path")
    ap.add_argument("--min-ratio", type=float, default=0.8,
                    help="fail when run speedup / committed speedup drops "
                         "below this (default 0.8)")
    args = ap.parse_args(argv)

    md = ["| bench | run speedup | committed speedup | ratio | gate |",
          "|---|---:|---:|---:|---|"]
    failed = False
    for pair in args.pairs:
        try:
            run_path, ref_path = pair.split(":", 1)
        except ValueError:
            print(f"error: bad pair {pair!r} (want RUN:COMMITTED)",
                  file=sys.stderr)
            return 2
        try:
            with open(run_path) as f:
                run = json.load(f)
            with open(ref_path) as f:
                ref = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: cannot read bench pair {pair}: {e}",
                  file=sys.stderr)
            return 2
        name = run.get("bench") or os.path.basename(ref_path)
        run_sp = float(run["speedup"])
        ref_sp = float(ref["speedup"])
        ratio = run_sp / ref_sp if ref_sp > 0 else float("inf")
        ok = ratio >= args.min_ratio
        failed |= not ok
        verdict = "OK" if ok else "FAIL"
        print(f"{name}: run {run_sp:.2f}x vs committed {ref_sp:.2f}x "
              f"-> ratio {ratio:.2f} [{verdict} >= {args.min_ratio}]")
        md.append(f"| {name} | {run_sp:.2f}x | {ref_sp:.2f}x | {ratio:.2f} "
                  f"| {verdict} |")

    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write("### bench trajectory gate\n\n")
            f.write("\n".join(md) + "\n")
    if failed:
        print(f"bench trajectory gate FAILED (min ratio {args.min_ratio})",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
