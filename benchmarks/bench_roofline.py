"""§Roofline driver: reads the dry-run sweep results JSON (produced by
`python -m repro.launch.dryrun --arch all --shape all --out results/sweep.json`)
and emits the per-cell roofline rows.  If no sweep file exists it runs a
reduced single-cell dry-run in a 512-device subprocess as a liveness check.
"""
from __future__ import annotations

import json
import os

from _util import REPO, run_worker

SWEEPS = [os.path.join(REPO, "results", "sweep.json"),
          os.path.join(REPO, "results", "sweep_multipod.json")]


def run():
    rows = []
    found = False
    for sweep in SWEEPS:
        if not os.path.exists(sweep):
            continue
        found = True
        with open(sweep) as f:
            cells = json.load(f)
        seen = {}
        for c in cells:   # keep last occurrence (re-runs override)
            seen[(c["arch"], c["shape"])] = c
        for c in seen.values():
            if "skipped" in c:
                rows.append((f"roofline/{c['arch']}/{c['shape']}", -1.0,
                             f"SKIP:{c['skipped'][:60]}"))
                continue
            if "failed" in c:
                rows.append((f"roofline/{c['arch']}/{c['shape']}", -1.0,
                             f"FAIL:{c['failed'][:60]}"))
                continue
            rows.append((
                f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
                float(c["compute_ms"]) * 1e3,
                f"hbm_ms={c['memory_ms']:.1f}|coll_ms={c['collective_ms']:.1f}|"
                f"dom={c['dominant']}|mfu_bound={c['mfu_bound']:.3f}|"
                f"useful={c['useful_ratio']:.2f}|mem={c['mem_model_gb']}GB|"
                f"fits={c['fits_hbm']}"))
    if found:
        return rows

    out = run_worker("""
import json
from repro.launch.dryrun import lower_cell
r = lower_cell("hymba-1.5b", "train_4k")
r.pop("trace", None); r.pop("compiled", None)
print("JSON" + json.dumps([(f"roofline/{r['arch']}/{r['shape']}",
    r["compute_ms"] * 1e3,
    f"dom={r['dominant']}|mfu_bound={r['mfu_bound']:.3f}")]))
""", devices=512, timeout=560)
    for line in out.splitlines():
        if line.startswith("JSON"):
            rows += [tuple(r) for r in json.loads(line[4:])]
    return rows
