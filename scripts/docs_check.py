#!/usr/bin/env python
"""Docs gate: markdown links must resolve, USAGE.md examples must run.

Two checks, both from the repo root:

1. **Intra-repo links** — every relative `[text](target)` in every
   tracked `*.md` file must point at an existing file (anchors are
   stripped; `http(s)://` and `mailto:` targets are skipped).
2. **Executable examples** — every line beginning with `session ` inside
   a fenced code block of USAGE.md is executed as
   `python -m repro.core.session ...` (PYTHONPATH=src) and must exit 0.
   A trailing `# exit=N` comment declares an intended nonzero exit
   (e.g. the documented error-path examples).

Exit 1 on any failure, with one line per problem.  This is the CI
`docs` job and part of `TIER=smoke scripts/test.sh`, so the user guide
cannot drift from the CLI it documents.
"""
import os
import re
import shlex
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^\s*(```|~~~)")
EXIT_RE = re.compile(r"#\s*exit=(\d+)\s*$")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files():
    out = []
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames
                       if not d.startswith(".") and d not in
                       ("__pycache__", "results", "node_modules")]
        out += [os.path.join(dirpath, f) for f in filenames
                if f.endswith(".md")]
    return sorted(out)


def check_links():
    problems = []
    for path in md_files():
        with open(path, encoding="utf-8") as f:
            lines = f.readlines()
        fenced = False
        for ln, line in enumerate(lines, 1):
            if FENCE_RE.match(line):
                fenced = not fenced
                continue
            if fenced:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(SKIP_PREFIXES):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), rel))
                if not os.path.exists(resolved):
                    problems.append(
                        f"{os.path.relpath(path, ROOT)}:{ln}: "
                        f"broken link -> {target}")
    return problems


def usage_commands():
    """(lineno, argv-after-`session`, expected-exit) per fenced example."""
    path = os.path.join(ROOT, "USAGE.md")
    cmds = []
    fenced = False
    with open(path, encoding="utf-8") as f:
        for ln, line in enumerate(f, 1):
            if FENCE_RE.match(line):
                fenced = not fenced
                continue
            s = line.strip()
            if not fenced or not s.startswith("session "):
                continue
            expect = 0
            m = EXIT_RE.search(s)
            if m:
                expect = int(m.group(1))
                s = s[:m.start()].rstrip()
            cmds.append((ln, shlex.split(s)[1:], expect))
    return cmds


def run_examples():
    problems = []
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (os.pathsep + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else "")
    cmds = usage_commands()
    if not cmds:
        return ["USAGE.md: no fenced `session ...` examples found"]
    for ln, argv, expect in cmds:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.core.session"] + argv,
            cwd=ROOT, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True, timeout=600)
        if proc.returncode != expect:
            tail = (proc.stderr or proc.stdout).strip().splitlines()
            problems.append(
                f"USAGE.md:{ln}: `session {' '.join(argv)}` exited "
                f"{proc.returncode} (expected {expect})"
                + (f" — {tail[-1]}" if tail else ""))
        else:
            print(f"docs_check: ok (exit {proc.returncode}) "
                  f"session {' '.join(argv)}")
    return problems


def main():
    problems = check_links()
    problems += run_examples()
    for p in problems:
        print(f"docs_check: FAIL {p}", file=sys.stderr)
    n_links = sum(1 for _ in md_files())
    if not problems:
        print(f"docs_check: PASS ({n_links} markdown files link-checked, "
              f"{len(usage_commands())} USAGE.md examples executed)")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
