"""commcheck — static collective-correctness analysis over `TraceStore`.

Everything else in the tracer is *dynamic*: detectors fire after a trace
is ingested and priced.  This module is the static pass — it verifies the
collective communication structure of a compiled (or synthetic) program
without executing anything, in the spirit of the cross-layer validation
the INAM-style cluster profilers run post-hoc.  A malformed collective
that would surface as a hang on real hardware becomes a ranked diagnostic
at lint time.

Four analysis families:

  1. **match / deadlock analysis** (`check_matches`) — sites sharing a
     `channel_id` claim to be one collective instance stream (XLA channel
     semantics).  Per match class we flag: channel reuse across different
     collective kinds (`channel_collision`), payload shape/dtype
     disagreement within a matched class (`shape_mismatch`), devices left
     out of every group of the class (`group_coverage`), and — over the
     cross-device match graph (devices connected by shared groups) —
     participants that disagree on *how many* instances they execute
     (`deadlock_order`): the ranks expecting the extra instance block
     forever, the classic mismatched-collective-ordering deadlock.
  2. **replica-group validation** (`check_replica_groups`) — per unique
     group table: device ids outside the mesh (`device_out_of_range`),
     a device in more than one group of the same collective
     (`group_overlap`), group sizes inconsistent with the mesh axes they
     span (`group_mesh_mismatch`), and degenerate all-size-1 groups that
     move no data (`degenerate_group`).  Permute pair lists get the
     analogous checks (`check_permutes`).
  3. **sharding-spec lint** (`lint_pspecs`) — pre-trace validation of
     PartitionSpec trees against the mesh: an axis used twice in one spec
     (`pspec_dup_axis`), spec axes absent from the mesh
     (`pspec_unknown_axis`), dims not divisible by their axis product
     (`pspec_indivisible`), and unsharded dominant dims while mesh axes
     sit idle (`pspec_unsharded_dim`).  Duck-typed over anything that
     iterates like a `jax.sharding.PartitionSpec` — no jax import here.
  4. **severity ranking** — every finding carries the cost-model
     wire-bytes / est-time at risk of the implicated sites
     (`costmodel.annotate_store` fills the columns), and `check_trace`
     returns `detect.rank_findings` order: critical > warn > info,
     largest bytes at risk first.

Vectorization: the per-site work is numpy over interned codes — group
tables expand once per *unique* table (`store.expand_groups`), coverage
is one scatter (`store.table_device_counts`), match classes come from one
`np.unique` over the channel column.  Python loops run only over unique
tables and multi-site match classes (a handful each in real modules),
never over events.

Finding codes are stable: `session lint --json` emits
`Finding.to_dict()` — the same schema as `session detect --json`.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel
from repro.core.detect import Finding, rank_findings
from repro.core.events import Trace
from repro.core.store import TraceStore
from repro.core.topology import Hardware, MeshSpec, V5E, varying_axes

__all__ = [
    "check_trace", "check_store", "check_replica_groups", "check_matches",
    "check_permutes", "lint_pspecs", "findings_json",
]


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------

def _risk(store: TraceStore, rows: np.ndarray) -> Dict[str, float]:
    """Cost-model weight of the implicated rows (wire bytes, est time)."""
    rows = np.asarray(rows, dtype=np.int64)
    w = store.weights[rows]
    return {
        "wasted_bytes": float((store.wire_total[rows] * w).sum()),
        "time_at_risk_s": float((store.est_time_s[rows] * w).sum()),
    }


# fix advice per finding code.  Static findings are correctness bugs, so
# the quantification is the modeled step time the implicated collectives
# account for (`time_at_risk_s`) — what the fix unblocks — rather than a
# counterfactual re-pricing (`whatif` quantifies the dynamic detectors).
_ADVICE: Dict[str, str] = {
    "device_out_of_range": "fix the replica groups to index devices that "
                           "exist in the mesh",
    "group_overlap": "make the replica groups of each collective disjoint",
    "degenerate_group": "delete the collective or widen its groups — "
                        "size-1 groups move no data",
    "group_mesh_mismatch": "retile the replica groups so each evenly "
                           "covers the mesh axes it spans",
    "group_coverage": "include every device in a replica group (SPMD runs "
                      "the op on all ranks)",
    "channel_collision": "give each collective instance its own channel id",
    "shape_mismatch": "make matched participants agree on payload "
                      "shape/dtype",
    "deadlock_order": "align the collective call order across ranks",
    "permute_dup_target": "route at most one source to each permute target",
    "permute_dup_source": "check the intended ring/shift pattern "
                          "(multicast source)",
    "permute_self_loop": "drop the self-loop pairs — they move no data",
    "pspec_dup_axis": "use each mesh axis in at most one dim of the spec",
    "pspec_unknown_axis": "name only axes the mesh defines",
    "pspec_indivisible": "pad the dim or pick axes whose product divides it",
    "pspec_unsharded_dim": "shard the dominant dim over the idle axes",
}


def _advise(findings: List[Finding]) -> List[Finding]:
    """Attach the fix advice + unblocked-time figure to each finding."""
    from repro.core.whatif import fmt_time
    for f in findings:
        if f.recommendation:
            continue
        advice = _ADVICE.get(f.detector)
        if advice is None:
            continue
        f.est_saved_s = f.time_at_risk_s
        f.recommendation = advice if f.time_at_risk_s == 0 else \
            f"{advice} — unblocks est {fmt_time(f.time_at_risk_s)}/step"
    return findings


def _first_row_per_code(codes: np.ndarray, rows: np.ndarray,
                        n_codes: int) -> np.ndarray:
    """First row index using each code (-1 = unused), one reverse scatter."""
    first = np.full(n_codes, -1, dtype=np.int64)
    if len(rows):
        first[codes[::-1]] = rows[::-1]
    return first


def _fmt_devices(devs: Sequence[int], limit: int = 8) -> str:
    devs = [int(d) for d in devs]
    body = ", ".join(map(str, devs[:limit]))
    return body + (", ..." if len(devs) > limit else "")


def _axis_prod(mesh: MeshSpec, axes: Tuple[str, ...]) -> int:
    p = 1
    for a in axes:
        p *= mesh.shape[mesh.axes.index(a)]
    return p


def _table_counts(table, nd: int) -> np.ndarray:
    """Per-device appearance counts of one group table (value-based
    sibling of `store.table_device_counts`; out-of-range ids dropped)."""
    counts = np.zeros(nd, dtype=np.int64)
    for g in table:
        for d in g:
            d = int(d)
            if 0 <= d < nd:
                counts[d] += 1
    return counts


# --------------------------------------------------------------------------
# per-unit analysis bodies — computed from table *values*, so the batch
# checkers below and the streaming `CommcheckState` produce identical
# findings for the same unit regardless of which store's codes carried it
# --------------------------------------------------------------------------

def _group_table_finding(table, mesh: MeshSpec, sites: int,
                         kw: Dict) -> Optional[Finding]:
    """Structural verdict on one unique replica-group table (<= 1 finding)."""
    nd = mesh.num_devices
    flat = [int(d) for g in table for d in g]
    bad = sorted({d for d in flat if d < 0 or d >= nd})
    if bad:
        return Finding(
            "device_out_of_range", "critical",
            f"replica groups at {sites} site(s) name device(s) "
            f"[{_fmt_devices(bad)}] outside the {nd}-device mesh", **kw)
    seen: Dict[int, int] = {}
    for d in flat:
        seen[d] = seen.get(d, 0) + 1
    dups = sorted(d for d, c in seen.items() if c > 1)
    if dups:
        return Finding(
            "group_overlap", "critical",
            f"device(s) [{_fmt_devices(dups)}] appear in more than one "
            f"replica group of the same collective at {sites} site(s) — "
            f"groups must be disjoint", **kw)
    sizes = sorted({len(g) for g in table})
    if sizes and sizes[-1] <= 1:
        return Finding(
            "degenerate_group", "info",
            f"all replica groups are size 1 at {sites} site(s) — the "
            f"collective moves no data (dead comm)", **kw)
    if len(sizes) > 1:
        return Finding(
            "group_mesh_mismatch", "warn",
            f"ragged replica groups (sizes {sizes}) at {sites} site(s) "
            f"— the groups of one collective should tile the mesh "
            f"uniformly", **kw)
    # uniform sizes: each group must evenly tile the axes it spans
    bad_groups = 0
    example: Tuple[str, ...] = ()
    for g in table:
        if len(g) <= 1:
            continue
        va = varying_axes(mesh, g)
        if _axis_prod(mesh, va) % len(g):
            bad_groups += 1
            example = va
    if bad_groups:
        return Finding(
            "group_mesh_mismatch", "warn",
            f"{bad_groups}/{len(table)} replica group(s) of size "
            f"{sizes[0]} at {sites} site(s) do not evenly tile the mesh "
            f"axes they span {example} — group sizes should divide the "
            f"spanned axis product", **kw)
    return None


def _permute_table_findings(pairs, nd: int, sites: int,
                            kw: Dict) -> List[Finding]:
    """Range / fan-in / fan-out / self-loop checks on one pair table."""
    out: List[Finding] = []
    pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    if ((pairs < 0) | (pairs >= nd)).any():
        bad = np.unique(pairs[(pairs < 0) | (pairs >= nd)])
        out.append(Finding(
            "device_out_of_range", "critical",
            f"collective-permute pairs at {sites} site(s) name "
            f"device(s) [{_fmt_devices(bad)}] outside the {nd}-device "
            f"mesh", **kw))
        return out
    src, dst = pairs[:, 0], pairs[:, 1]
    if len(np.unique(dst)) < len(dst):
        out.append(Finding(
            "permute_dup_target", "critical",
            f"collective-permute at {sites} site(s) lists a target "
            f"device more than once — two sources write the same "
            f"destination buffer", **kw))
    elif len(np.unique(src)) < len(src):
        out.append(Finding(
            "permute_dup_source", "warn",
            f"collective-permute at {sites} site(s) sends from the "
            f"same source more than once (multicast) — check the "
            f"intended ring/shift pattern", **kw))
    n_self = int((src == dst).sum())
    if n_self:
        out.append(Finding(
            "permute_self_loop", "info",
            f"{n_self} self-loop pair(s) in a collective-permute at "
            f"{sites} site(s) — those transfers move no data", **kw))
    return out


def _f_coverage_singleton(sites: int, missing, nd: int, **kw) -> Finding:
    return Finding(
        "group_coverage", "critical",
        f"{sites} collective site(s) leave {len(missing)} of "
        f"{nd} devices out of every replica group (missing: "
        f"[{_fmt_devices(missing)}]) — in SPMD every device "
        f"executes the op, so the excluded ranks hang", **kw)


def _class_findings(chan: int, members: Sequence[Tuple],
                    tables: Dict, mesh: MeshSpec, kw: Dict) -> List[Finding]:
    """Signature + match-graph checks on one multi-site channel class.

    `members` are `(kind, bytes, dtype, multiplicity, table_key)` value
    tuples in row order; `tables` maps each referenced table key to the
    table itself.
    """
    out: List[Finding] = []
    nd = mesh.num_devices
    kind_names = {m[0] for m in members}
    if len(kind_names) > 1:
        names = sorted(kind_names)
        out.append(Finding(
            "channel_collision", "critical",
            f"channel {chan} is reused by {len(members)} sites of "
            f"different collective kinds ({', '.join(names)}) — a "
            f"channel id must identify one collective instance", **kw))
        return out
    kind = members[0][0]
    sigs = {(m[1], m[2]) for m in members}
    if len(sigs) > 1:
        blist = sorted({b for b, _ in sigs})
        dlist = sorted({d for _, d in sigs})
        out.append(Finding(
            "shape_mismatch", "critical",
            f"sites matched on channel {chan} disagree on payload "
            f"shape/dtype (bytes {blist}, dtypes {dlist}) — matched "
            f"{kind} participants must agree elementwise", **kw))
        return out
    # per-device instance counts across the class
    counts = np.zeros(nd, dtype=np.int64)
    cnt_by_key = {key: _table_counts(t, nd) for key, t in tables.items()}
    for m in members:
        counts += m[3] * cnt_by_key[m[4]]
    if (counts == 0).any():
        missing = np.flatnonzero(counts == 0)
        out.append(Finding(
            "group_coverage", "critical",
            f"{len(missing)} of {nd} devices never participate in any "
            f"{kind} on channel {chan} (missing: "
            f"[{_fmt_devices(missing)}]) — the excluded ranks hang",
            **kw))
    if len(tables) > 1:
        # match graph: devices sharing a group are matched partners
        uf = _UnionFind(nd)
        for t in tables.values():
            for g in t:
                ok = [int(d) for d in g if 0 <= int(d) < nd]
                for d in ok[1:]:
                    uf.union(ok[0], d)
        comps: Dict[int, List[int]] = {}
        for d in np.flatnonzero(counts > 0):
            comps.setdefault(uf.find(int(d)), []).append(int(d))
        for comp in comps.values():
            cs = counts[comp]
            lo, hi = int(cs.min()), int(cs.max())
            if lo != hi:
                out.append(Finding(
                    "deadlock_order", "critical",
                    f"devices matched on channel {chan} disagree on how "
                    f"many {kind} instances they execute ({lo} vs {hi} "
                    f"across {len(comp)} connected devices) — the "
                    f"ranks expecting the extra instance block forever "
                    f"(mismatched collective ordering)", **kw))
                break
    return out


# --------------------------------------------------------------------------
# family 2: replica-group validation (per unique table)
# --------------------------------------------------------------------------

def check_replica_groups(store: TraceStore, mesh: MeshSpec) -> List[Finding]:
    """Structural validity of every unique replica-group table in use.

    Permute rows are excluded — their group attr is the parser's
    full-range fallback; their real participants (the pair lists) are
    validated by `check_permutes`.
    """
    out: List[Finding] = []
    if store.n == 0 or not store.group_tables:
        return out
    nd = mesh.num_devices
    n_tables = len(store.group_tables)
    ring = store.stp_code < 0
    ring_rows = np.flatnonzero(ring)
    gc = store.group_code[ring_rows]
    w = (store.wire_total * store.weights)
    t_s = (store.est_time_s * store.weights)
    wb = np.bincount(gc, weights=w[ring_rows], minlength=n_tables)
    ts = np.bincount(gc, weights=t_s[ring_rows], minlength=n_tables)
    nrows = np.bincount(gc, minlength=n_tables)
    first = _first_row_per_code(gc, ring_rows, n_tables)

    for t in range(n_tables):
        if nrows[t] == 0:
            continue
        kw = dict(wasted_bytes=float(wb[t]), time_at_risk_s=float(ts[t]),
                  site=store.names[first[t]] if first[t] >= 0 else f"groups#{t}")
        f = _group_table_finding(store.group_tables[t], mesh,
                                 int(nrows[t]), kw)
        if f is not None:
            out.append(f)
    return out


# --------------------------------------------------------------------------
# family 1: match / deadlock analysis (per channel match class)
# --------------------------------------------------------------------------

def _match_classes(store: TraceStore, rows: np.ndarray
                   ) -> Iterator[Tuple[int, np.ndarray]]:
    """(channel, member rows) for every channel shared by >= 2 sites."""
    ch = store.channel_id[rows]
    order = rows[np.argsort(ch, kind="stable")]
    chs = store.channel_id[order]
    uch, start, counts = np.unique(chs, return_index=True, return_counts=True)
    for i in np.flatnonzero(counts > 1):
        yield int(uch[i]), order[start[i]:start[i] + counts[i]]


class _UnionFind:
    """Tiny union-find over device ids (mesh-sized, not event-sized)."""

    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, x: int) -> int:
        p = self.parent
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return x

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def check_matches(store: TraceStore, mesh: MeshSpec) -> List[Finding]:
    """Channel-keyed match analysis: collision, shape, coverage, deadlock.

    Sites sharing a `channel_id` form one match class (XLA channel
    semantics: the channel identifies a collective instance stream).
    Sites without a channel — or with a unique one — are their own class;
    for those, coverage is the only applicable check and runs vectorized.
    Multi-site classes (rare) additionally get the signature and
    match-graph checks in a per-class loop.
    """
    out: List[Finding] = []
    if store.n == 0:
        return out
    nd = mesh.num_devices
    ring_rows = np.flatnonzero(store.stp_code < 0)
    if not len(ring_rows):
        return out
    cnt_t = store.table_device_counts(nd)
    present_t = cnt_t > 0
    miss_t = nd - present_t.sum(axis=1)

    chan_rows = ring_rows[store.channel_id[ring_rows] >= 0]
    multi: List[Tuple[int, np.ndarray]] = list(_match_classes(store, chan_rows))
    in_multi = np.zeros(store.n, dtype=bool)
    for _c, rows in multi:
        in_multi[rows] = True
    single = ring_rows[~in_multi[ring_rows]]

    # -- singleton classes: vectorized per-site coverage --------------------
    if len(single):
        bad = single[miss_t[store.group_code[single]] > 0]
        for t in np.unique(store.group_code[bad]):
            rows_t = bad[store.group_code[bad] == t]
            missing = np.flatnonzero(~present_t[t])
            out.append(_f_coverage_singleton(
                len(rows_t), missing, nd,
                site=store.names[int(rows_t[0])], **_risk(store, rows_t)))

    # -- multi-site classes: signature + match-graph checks -----------------
    for chan, rows in multi:
        kw = dict(site=f"channel {chan}", **_risk(store, rows))
        members = [(store.kind.value(int(r)), int(store.operand_bytes[r]),
                    store.dtype.value(int(r)), int(store.multiplicity[r]),
                    int(store.group_code[r])) for r in rows]
        tables = {}
        for m in members:
            tables.setdefault(m[4], store.group_tables[m[4]])
        out += _class_findings(chan, members, tables, mesh, kw)
    return out


# --------------------------------------------------------------------------
# permute pair validation
# --------------------------------------------------------------------------

def check_permutes(store: TraceStore, mesh: MeshSpec) -> List[Finding]:
    """Per unique source/target pair table: range, fan-in/out, self-loops."""
    out: List[Finding] = []
    if store.n == 0 or not store.stp_tables:
        return out
    nd = mesh.num_devices
    n_t = len(store.stp_tables)
    rows_m = np.flatnonzero(store.stp_code >= 0)
    sc = store.stp_code[rows_m]
    w = store.wire_total * store.weights
    t_s = store.est_time_s * store.weights
    wb = np.bincount(sc, weights=w[rows_m], minlength=n_t)
    ts = np.bincount(sc, weights=t_s[rows_m], minlength=n_t)
    nrows = np.bincount(sc, minlength=n_t)
    first = _first_row_per_code(sc, rows_m, n_t)
    for t in range(n_t):
        if nrows[t] == 0:
            continue
        kw = dict(wasted_bytes=float(wb[t]), time_at_risk_s=float(ts[t]),
                  site=store.names[first[t]] if first[t] >= 0 else f"pairs#{t}")
        out += _permute_table_findings(store.stp_tables[t], nd,
                                       int(nrows[t]), kw)
    return out


# --------------------------------------------------------------------------
# family 3: sharding-spec lint (pre-trace, duck-typed PartitionSpecs)
# --------------------------------------------------------------------------

def _default_is_leaf(x) -> bool:
    return type(x).__name__ == "PartitionSpec"


def _walk_specs(tree, shapes, path: str, is_leaf):
    if tree is None:
        return
    if is_leaf(tree):
        yield path, tree, shapes
        return
    if isinstance(tree, dict):
        for k, v in tree.items():
            sub = shapes.get(k) if isinstance(shapes, dict) else None
            yield from _walk_specs(v, sub, f"{path}/{k}" if path else str(k),
                                   is_leaf)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            sub = shapes[i] if isinstance(shapes, (list, tuple)) \
                and i < len(shapes) else None
            yield from _walk_specs(v, sub, f"{path}/{i}" if path else str(i),
                                   is_leaf)
    else:
        # unknown leaf type: treat as spec-like (iterable of entries)
        yield path, tree, shapes


def _entry_axes(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def lint_pspecs(pspecs, axis_sizes: Dict[str, int], shapes=None, *,
                big_dim: int = 4096, is_leaf=None,
                prefix: str = "") -> List[Finding]:
    """Statically validate a PartitionSpec tree against mesh axis sizes.

    `pspecs` is any nesting of dict/list/tuple with PartitionSpec-like
    leaves (anything iterating as `None | str | tuple[str, ...]` entries
    — duck-typed, so plain tuples work in jax-free tests via `is_leaf`).
    `shapes`, when given, mirrors the tree with per-leaf dim tuples and
    enables the divisibility and unsharded-dominant-dim checks.
    `wasted_bytes` ranks spec findings by f32 tensor bytes at stake.
    """
    if is_leaf is None:
        is_leaf = _default_is_leaf
    out: List[Finding] = []
    for path, spec, shape in _walk_specs(pspecs, shapes, prefix, is_leaf):
        entries = list(spec)
        per_dim = [_entry_axes(e) for e in entries]
        used = [a for axes in per_dim for a in axes]
        weight = float(np.prod(shape)) * 4.0 if shape else 0.0
        kw = dict(site=path or "<spec>", wasted_bytes=weight)
        dups = sorted({a for a in used if used.count(a) > 1})
        if dups:
            out.append(Finding(
                "pspec_dup_axis", "critical",
                f"PartitionSpec{tuple(entries)} uses mesh axis(es) {dups} "
                f"in more than one dim — an axis can shard only one dim",
                **kw))
        unknown = sorted({a for a in used if a not in axis_sizes})
        if unknown:
            out.append(Finding(
                "pspec_unknown_axis", "critical",
                f"PartitionSpec{tuple(entries)} names mesh axis(es) "
                f"{unknown} absent from the mesh "
                f"(have {sorted(axis_sizes)})", **kw))
            continue
        if not shape:
            continue
        for d, (dim, axes) in enumerate(zip(shape, per_dim)):
            prod = int(np.prod([axis_sizes[a] for a in axes])) if axes else 1
            if axes and prod and dim % prod:
                out.append(Finding(
                    "pspec_indivisible", "warn",
                    f"dim {d} (size {dim}) of PartitionSpec{tuple(entries)} "
                    f"is not divisible by its axis product {prod} "
                    f"({'x'.join(axes)}) — XLA pads or falls back to "
                    f"replication", **kw))
        idle = [a for a, s in axis_sizes.items() if s > 1 and a not in used]
        if idle and len(shape) > len([a for a in per_dim if a]) - 1:
            big = max(range(len(shape)), key=lambda i: shape[i],
                      default=None)
            if big is not None and shape[big] >= big_dim \
                    and (big >= len(per_dim) or not per_dim[big]):
                out.append(Finding(
                    "pspec_unsharded_dim", "warn",
                    f"dominant dim {big} (size {shape[big]}) of "
                    f"PartitionSpec{tuple(entries)} is unsharded while mesh "
                    f"axis(es) {sorted(idle)} sit idle — shard it or accept "
                    f"the replicated memory/traffic", **kw))
    return _advise(out)


def findings_json(findings: Sequence[Finding]) -> List[Dict[str, object]]:
    """The stable machine schema (shared with `session detect --json`)."""
    return [f.to_dict() for f in findings]


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def check_store(store: TraceStore, mesh: MeshSpec) -> List[Finding]:
    """All trace-level families over one columnar store (unranked)."""
    return _advise(check_replica_groups(store, mesh)
                   + check_matches(store, mesh)
                   + check_permutes(store, mesh))


def check_trace(trace: Trace, mesh: Optional[MeshSpec] = None,
                hw: Hardware = V5E) -> List[Finding]:
    """Static analysis of one trace, ranked by severity then bytes at risk.

    Annotates the store through `costmodel.annotate_store` first when the
    cost columns are empty (a store ingested without annotation), so the
    ranking weight is available; traces from the normal pipelines are
    already priced and pass through untouched.
    """
    if mesh is None:
        mesh = MeshSpec(tuple(trace.mesh_shape), tuple(trace.mesh_axes))
    store = trace.store
    if store.n and not store.wire_bytes_per_device.any():
        try:
            costmodel.annotate_store(store, mesh, hw)
        except (ValueError, IndexError, KeyError):
            pass    # un-annotatable (e.g. out-of-range devices): rank by 0
    return rank_findings(check_store(store, mesh))


# --------------------------------------------------------------------------
# streaming analysis — fold appended chunks, re-render fresh findings
# --------------------------------------------------------------------------

class CommcheckState:
    """Streaming `check_store`: absorb ingested chunks, render on demand.

    `update(store)` folds one (annotated) chunk in; `findings()` then
    returns the ranked findings a batch `check_trace` would produce over
    the union of all chunks seen so far.  Retained state is
    compiled-program-shaped: unique group/pair tables with per-table
    site/risk sums, plus one small member record per channel-carrying
    row — channel match classes cannot be collapsed early because a
    later chunk may add members that flip a singleton into a multi-site
    class.  The analysis bodies (`_group_table_finding`,
    `_class_findings`, `_permute_table_findings`) are shared with the
    batch checkers, so messages are string-identical; accumulated risk
    sums group per chunk and are close, not bitwise-equal, to one batch
    pass.
    """

    def __init__(self, mesh: MeshSpec):
        self.mesh = mesh
        self._off = 0    # global row offset across chunks
        # value-key -> table, insertion order == the union store's
        # first-seen table code order (chunks intern in row order, and
        # we fold chunk tables in their code order, exactly like merge)
        self._gtables: Dict[Tuple, List] = {}
        self._ptables: Dict[Tuple, List] = {}
        self._gstat: Dict[Tuple, Dict] = {}     # ring rows per group table
        self._pstat: Dict[Tuple, Dict] = {}     # permute rows per pair table
        self._nochan: Dict[Tuple, Dict] = {}    # channel-less ring rows
        self._chan: Dict[int, List[Dict]] = {}  # channel -> member records

    @staticmethod
    def _fold(stat: Dict[Tuple, Dict], key: Tuple, sites: int, wb: float,
              ts: float, first: Optional[Tuple[int, str]]) -> None:
        st = stat.setdefault(key, {"sites": 0, "wb": 0.0, "ts": 0.0,
                                   "first": None})
        st["sites"] += sites
        st["wb"] += wb
        st["ts"] += ts
        if first is not None and (st["first"] is None
                                  or first < st["first"]):
            st["first"] = first

    def update(self, store: TraceStore) -> None:
        gkeys = []
        for table in store.group_tables:
            key = tuple(tuple(int(x) for x in g) for g in table)
            self._gtables.setdefault(key, table)
            gkeys.append(key)
        pkeys = []
        for t in store.stp_tables:
            key = tuple((int(a), int(b)) for a, b in t)
            self._ptables.setdefault(key, t)
            pkeys.append(key)
        if store.n == 0:
            return
        w = store.wire_total * store.weights
        t_s = store.est_time_s * store.weights
        ring_rows = np.flatnonzero(store.stp_code < 0)
        stp_rows = np.flatnonzero(store.stp_code >= 0)

        def fold_rows(stat, rows, code, keys):
            n_t = len(keys)
            if not n_t or not len(rows):
                return
            c = code[rows]
            wb = np.bincount(c, weights=w[rows], minlength=n_t)
            ts = np.bincount(c, weights=t_s[rows], minlength=n_t)
            nrows = np.bincount(c, minlength=n_t)
            first = _first_row_per_code(c, rows, n_t)
            for t in np.flatnonzero(nrows):
                fi = int(first[t])
                self._fold(stat, keys[t], int(nrows[t]), float(wb[t]),
                           float(ts[t]),
                           (self._off + fi, store.names[fi]))

        fold_rows(self._gstat, ring_rows, store.group_code, gkeys)
        fold_rows(self._pstat, stp_rows, store.stp_code, pkeys)

        ch = store.channel_id
        chan_rows = ring_rows[ch[ring_rows] >= 0]
        for r in chan_rows.tolist():
            self._chan.setdefault(int(ch[r]), []).append({
                "kind": store.kind.value(r),
                "bytes": int(store.operand_bytes[r]),
                "dtype": store.dtype.value(r),
                "mult": int(store.multiplicity[r]),
                "table": gkeys[store.group_code[r]],
                "wb": float(w[r]), "ts": float(t_s[r]),
                "gidx": self._off + r, "name": store.names[r]})
        nochan_rows = ring_rows[ch[ring_rows] < 0]
        fold_rows(self._nochan, nochan_rows, store.group_code, gkeys)
        self._off += store.n

    def findings(self) -> List[Finding]:
        mesh = self.mesh
        nd = mesh.num_devices
        out: List[Finding] = []
        # family 2: replica-group structure, in union table order
        for key, table in self._gtables.items():
            st = self._gstat.get(key)
            if not st:
                continue
            kw = dict(wasted_bytes=st["wb"], time_at_risk_s=st["ts"],
                      site=st["first"][1])
            f = _group_table_finding(table, mesh, st["sites"], kw)
            if f is not None:
                out.append(f)
        # family 1: matches.  Singleton classes = channel-less rows plus
        # channels that (so far) have exactly one member.
        singles: Dict[Tuple, Dict] = {}
        for key, st in self._nochan.items():
            self._fold(singles, key, st["sites"], st["wb"], st["ts"],
                       st["first"])
        for chan in sorted(self._chan):
            members = self._chan[chan]
            if len(members) == 1:
                m = members[0]
                self._fold(singles, m["table"], 1, m["wb"], m["ts"],
                           (m["gidx"], m["name"]))
        for key, table in self._gtables.items():
            st = singles.get(key)
            if not st:
                continue
            present = _table_counts(table, nd) > 0
            missing = np.flatnonzero(~present)
            if len(missing):
                out.append(_f_coverage_singleton(
                    st["sites"], missing, nd, site=st["first"][1],
                    wasted_bytes=st["wb"], time_at_risk_s=st["ts"]))
        for chan in sorted(self._chan):
            members = self._chan[chan]
            if len(members) < 2:
                continue
            kw = dict(site=f"channel {chan}",
                      wasted_bytes=sum(m["wb"] for m in members),
                      time_at_risk_s=sum(m["ts"] for m in members))
            tables = {}
            for m in members:
                tables.setdefault(m["table"], self._gtables[m["table"]])
            out += _class_findings(
                chan,
                [(m["kind"], m["bytes"], m["dtype"], m["mult"], m["table"])
                 for m in members],
                tables, mesh, kw)
        # permute pair tables, in union table order
        for key, pairs in self._ptables.items():
            st = self._pstat.get(key)
            if not st:
                continue
            kw = dict(wasted_bytes=st["wb"], time_at_risk_s=st["ts"],
                      site=st["first"][1])
            out += _permute_table_findings(pairs, nd, st["sites"], kw)
        return rank_findings(_advise(out))
