"""Table III analogue: tracer overhead.

ucTrace interposes at runtime (1.3x-25x slowdown, GB-scale logs).  Our trace
is compile-time: the overhead is pure offline analysis (HLO parse + assembly)
on top of an unavoidable lower+compile, with zero runtime cost.  We measure
lower/compile/parse wall time and trace size for a dense and a MoE step.

Also measures the analysis hot paths at the paper's experiment scale:

  * aggregation — a 100k-event trace rolled up by (kind x link) + semantic,
    columnar (`TraceStore` bincount) vs the per-event Python reference
    (>= 5x gate),
  * end-to-end ingest — parse -> attribute -> annotate -> store of a
    100k-site synthetic HLO module, single-pass columnar engine vs the
    per-event reference pipeline (>= 5x gate, byte-identical aggregates).
    The result is persisted to BENCH_ingest.json at the repo root so the
    perf trajectory is tracked across PRs, and
  * render + diff — JSON/HTML reports and a 3-way site-level session diff
    of a 100k-site trace, columnar emitters (`report` engine="columnar",
    `diff` union-vocab alignment) vs the per-event reference walk
    (engine="rows"), byte-identical output required (>= 5x gate).
    Persisted to BENCH_render.json at the repo root.
  * sharded single-module ingest — one giant multi-computation module
    split per-computation across spawn workers
    (`hlo_parser.parse_hlo_store_sharded` + `TraceStore.merge`) vs the
    serial columnar engine, merged store byte-identical required.  The
    2x speedup gate applies on boxes with >= 4 usable cores (parallel
    parse is CPU-bound; below that only the CI trajectory ratio gates).
    Persisted to BENCH_shard.json at the repo root.
  * append-mode ingest — one multi-computation module split into chunks,
    parsed and folded into a rolling store via `TraceStore.append` (the
    watch daemon's streaming path) vs one batch parse, appended store
    byte-identical required (>= 0.5x gate: chunking must stay within 2x
    of batch).  Persisted to BENCH_append.json at the repo root.
  * session persistence — save + load round-trip of a 2-trace session,
    compressed-npz columnar arrays vs compact JSON, exact round-trip
    required (the ratio is the size-independent trajectory signal).
    Persisted to BENCH_persist.json at the repo root.
  * warehouse tree merge — 256 per-host stores reduced via
    `TraceStore.merge_tree` (k-ary tree over a process pool) vs the
    serial left fold, result `identical` to the flat merge required.
    The 2x gate applies at >= 4 usable cores (mirrors BENCH_shard);
    the tree also wins algorithmically (O(n log n) vs O(n^2) row
    traffic), which is what single-core runs record.  Persisted to
    BENCH_merge.json at the repo root.
  * mmap zero-copy load — a fleet session opened eagerly vs
    `load(mmap=True)` on an uncompressed npz, gated on *peak RSS*
    (subprocess `ru_maxrss` deltas over an imports-only baseline), not
    wall clock: the mmap open must stay under an absolute ceiling and
    the eager/mmap RSS ratio is the trajectory signal; `query`/`diff`
    on a fleet slice must be byte-identical across the two load modes.
    Persisted to BENCH_mmapload.json at the repo root.

CI smoke entry points (no jax worker, smaller traces):

    python benchmarks/bench_overhead.py --ingest-only [--sites N]
    python benchmarks/bench_overhead.py --render-only [--sites N]
    python benchmarks/bench_overhead.py --shard-only [--sites N]
    python benchmarks/bench_overhead.py --append-only [--sites N]
    python benchmarks/bench_overhead.py --persist-only [--sites N]
    python benchmarks/bench_overhead.py --merge-only [--sites N]
    python benchmarks/bench_overhead.py --mmapload-only [--sites N]
"""
from __future__ import annotations

import json
import os
import time

from _util import REPO, run_worker

WORKER = """
import json
import time
import jax
import jax.numpy as jnp
from repro.configs import ARCHS, smoke_config
from repro.core import MeshSpec, trace_from_hlo
from repro.core.report import to_json
from repro.distributed import sharding as sh
from repro.distributed.autoshard import activation_sharding
from repro.launch.presets import StepSettings
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import adamw

mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = MeshSpec((2, 4), ("data", "model"))
rows = []
for arch in ("chatglm3-6b", "qwen3-moe-235b-a22b"):
    cfg = smoke_config(ARCHS[arch]).replace(
        d_model=128, d_ff=256, moe_d_ff=256 if ARCHS[arch].num_experts else 0,
        num_layers=8, vocab_size=512, num_heads=8, num_kv_heads=4, head_dim=16)
    st = StepSettings(accum=2, remat="full")
    step = make_train_step(cfg, adamw.AdamWConfig(), st)
    params = api.abstract_params(cfg)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = {"m": jax.tree.map(f32, params), "v": jax.tree.map(f32, params),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    shape = type("S", (), {"global_batch": 8, "seq_len": 128, "kind": "train"})()
    batch = api.batch_specs(cfg, shape)
    pspecs = sh.param_pspecs(cfg, mesh)
    jfn = jax.jit(step, in_shardings=(
        sh.named(mesh, pspecs),
        sh.named(mesh, {"m": pspecs, "v": pspecs,
                        "count": jax.sharding.PartitionSpec()}), None),
        donate_argnums=(0, 1))
    t0 = time.perf_counter()
    with activation_sharding(mesh):
        lowered = jfn.lower(params, opt, batch)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    text = compiled.as_text()
    tr = trace_from_hlo(text, spec, label=arch,
                        cost_analysis=compiled.cost_analysis(),
                        memory_analysis=compiled.memory_analysis())
    t3 = time.perf_counter()
    js = to_json(tr)
    rows.append((f"overhead/{arch}/lower", (t1 - t0) * 1e6, "baseline-cost"))
    rows.append((f"overhead/{arch}/compile", (t2 - t1) * 1e6, "baseline-cost"))
    rows.append((f"overhead/{arch}/trace_parse", (t3 - t2) * 1e6,
                 f"overhead_ratio={(t3-t2)/max(t2-t0,1e-9):.3f}|"
                 f"hlo_KB={len(text)//1024}|trace_KB={len(js)//1024}|"
                 f"runtime_overhead=0x (compile-time tool)"))
print("JSON" + json.dumps(rows))
"""


def _write_bench_payload(stem: str, n_sites: int, payload: dict,
                         json_path: str = None) -> None:
    """Persist a bench payload: the repo-root artifact tracks the perf
    trajectory across PRs, so only full-size runs may write it (smoke
    sizes are not comparable and land in results/ instead).  Written
    atomically — the watch-daemon smoke job reads these mid-run."""
    from repro.core.persist import atomic_open
    if json_path is None:
        if n_sites >= 100_000:
            json_path = os.path.join(REPO, f"{stem}.json")
        else:
            os.makedirs(os.path.join(REPO, "results"), exist_ok=True)
            json_path = os.path.join(REPO, "results", f"{stem}_smoke.json")
    with atomic_open(json_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def _agg_100k_case(n_sites: int = 100_000, iters: int = 3):
    """Columnar vs per-event aggregation on a 100k-event synthetic trace."""
    from repro.core.synth import synthetic_trace
    from repro.core.topology import MeshSpec

    tr = synthetic_trace("agg100k", MeshSpec((2, 4), ("data", "model")),
                         n_sites=n_sites, seed=0)

    def legacy():
        a = tr.by(lambda e: f"{e.kind}|{e.link_class}")
        b = tr.by(lambda e: e.semantic or "other")
        return a, b

    def columnar():
        return tr.by_kind_and_link(), tr.by_semantic()

    t0 = time.perf_counter()
    build = tr.store                      # one-time column build, timed apart
    t_build = (time.perf_counter() - t0) * 1e6
    assert build.n == n_sites

    t0 = time.perf_counter()
    for _ in range(iters):
        ref = legacy()
    t_legacy = (time.perf_counter() - t0) / iters * 1e6

    t0 = time.perf_counter()
    for _ in range(iters):
        col = columnar()
    t_col = (time.perf_counter() - t0) / iters * 1e6

    # equivalence guard: same keys, same byte totals
    match = all(
        set(r) == set(c)
        and all(abs(r[k]["bytes"] - c[k]["bytes"]) < 1e-6 for k in r)
        for r, c in zip(ref, col))
    speedup = t_legacy / max(t_col, 1e-9)
    return [
        (f"overhead/agg{n_sites//1000}k/per_event", t_legacy, "baseline-cost"),
        (f"overhead/agg{n_sites//1000}k/columnar", t_col,
         f"speedup={speedup:.1f}x|target>=5x|sites={n_sites}|"
         f"store_build_us={t_build:.0f}|equivalent={match}"),
    ]


def _ingest_case(n_sites: int = 100_000, json_path: str = None):
    """End-to-end ingest: parse -> attribute -> annotate -> store, columnar
    engine vs per-event reference, with an exact-equality aggregate guard.

    Gate: >= 5x at 100k sites, batched aggregates byte-identical to the
    per-event reference path.
    """
    from repro.core.synth import synthetic_hlo
    from repro.core.topology import MeshSpec
    from repro.core.tracer import trace_from_hlo

    mesh = MeshSpec((2, 4), ("data", "model"))
    text = synthetic_hlo(n_sites=n_sites, seed=0)

    def aggregates(tr):
        return (tr.by_kind_and_link(), tr.by_semantic(),
                tr.total_collective_bytes(), tr.total_wire_bytes(),
                tr.total_est_time_s(), tr.overlapped_est_time_s())

    t0 = time.perf_counter()
    tr_ref = trace_from_hlo(text, mesh, label="ref", engine="rows")
    ref_aggs = aggregates(tr_ref)
    t_ref = time.perf_counter() - t0

    t0 = time.perf_counter()
    tr_fast = trace_from_hlo(text, mesh, label="fast", engine="columnar")
    fast_aggs = aggregates(tr_fast)
    t_fast = time.perf_counter() - t0

    sites = tr_fast.sites
    # equivalence guard: byte-identical aggregates (exact ==, no tolerance)
    equivalent = (sites == tr_ref.sites and ref_aggs == fast_aggs)
    speedup = t_ref / max(t_fast, 1e-9)
    payload = {
        "bench": "ingest_e2e",
        "sites": sites,
        "hlo_kb": len(text) // 1024,
        "ref_s": round(t_ref, 4),
        "columnar_s": round(t_fast, 4),
        "ref_events_per_sec": round(sites / max(t_ref, 1e-9)),
        "columnar_events_per_sec": round(sites / max(t_fast, 1e-9)),
        "speedup": round(speedup, 2),
        "target": 5.0,
        "equivalent": equivalent,
    }
    _write_bench_payload("BENCH_ingest", n_sites, payload, json_path)
    rows = [
        (f"overhead/ingest{n_sites//1000}k/per_event", t_ref * 1e6,
         "baseline-cost"),
        (f"overhead/ingest{n_sites//1000}k/columnar", t_fast * 1e6,
         f"speedup={speedup:.1f}x|target>=5x|sites={sites}|"
         f"events_per_sec={payload['columnar_events_per_sec']}|"
         f"equivalent={equivalent}"),
    ]
    return rows, payload


def _render_case(n_sites: int = 100_000, json_path: str = None):
    """Renderer + diff: columnar emitters vs the per-event reference.

    Workload: JSON report, HTML report, a 3-trace site-level `diff_n`,
    and a pairwise `diff_traces` — once with engine="rows" (per-event
    walks, dict-aligned diff), once columnar.  Gate: >= 5x at 100k sites
    with byte-identical renderer output and identical diff rows; the
    streaming `write_json` must reproduce `to_json` exactly.
    """
    import io

    from repro.core import diff as diff_mod
    from repro.core import report as report_mod
    from repro.core.synth import synthetic_trace
    from repro.core.topology import MeshSpec

    mesh = MeshSpec((2, 4), ("data", "model"))
    traces = [
        synthetic_trace("base", mesh, n_sites=n_sites, seed=0),
        synthetic_trace("dp-heavy", mesh, n_sites=n_sites, seed=1,
                        axis_weights=(3.0, 1.0)),
        synthetic_trace("tp-heavy", mesh, n_sites=n_sites, seed=2,
                        axis_weights=(1.0, 3.0)),
    ]
    tr = traces[0]
    for t in traces:        # materialize both views outside the timing
        _ = t.events, t.store

    def render(engine):
        return (report_mod.to_json(tr, engine=engine),
                report_mod.to_html(tr, mesh, engine=engine),
                diff_mod.diff_n(traces, by="site", engine=engine),
                diff_mod.diff_traces(traces[0], traces[1], by="kind_link",
                                     engine=engine))

    t0 = time.perf_counter()
    ref = render("rows")
    t_ref = time.perf_counter() - t0

    t0 = time.perf_counter()
    fast = render("columnar")
    t_fast = time.perf_counter() - t0

    buf = io.StringIO()
    report_mod.write_json(tr, buf, chunk_sites=max(n_sites // 4, 1))
    identical = (ref[0] == fast[0] and ref[1] == fast[1]
                 and ref[2] == fast[2] and ref[3] == fast[3]
                 and buf.getvalue() == fast[0])
    speedup = t_ref / max(t_fast, 1e-9)
    payload = {
        "bench": "render_diff",
        "sites": n_sites,
        "n_traces": len(traces),
        "json_kb": len(fast[0]) // 1024,
        "ref_s": round(t_ref, 4),
        "columnar_s": round(t_fast, 4),
        "speedup": round(speedup, 2),
        "target": 5.0,
        "byte_identical": identical,
    }
    _write_bench_payload("BENCH_render", n_sites, payload, json_path)
    rows = [
        (f"overhead/render{n_sites//1000}k/per_event", t_ref * 1e6,
         "baseline-cost"),
        (f"overhead/render{n_sites//1000}k/columnar", t_fast * 1e6,
         f"speedup={speedup:.1f}x|target>=5x|sites={n_sites}|"
         f"json_kb={payload['json_kb']}|byte_identical={identical}"),
    ]
    return rows, payload


def _shard_case(n_sites: int = 100_000, json_path: str = None):
    """Sharded single-module ingest vs the serial columnar engine.

    One synthetic multi-computation module (the 405B-dump shape: many
    `%stage<k>` computations plus a while body) parses once serially and
    once split per-computation across workers, with the merged store
    required byte-identical (`TraceStore.identical`) to the serial one.

    Gate: >= 2x at 100k sites *when the box has >= 4 usable cores*
    (`gate_applies` in the payload) — the sharded path is CPU-bound
    parallel parse, so 2-core runners physically cap below 2x and rely
    on the CI trajectory ratio instead.
    """
    import dataclasses

    from repro.core import hlo_parser
    from repro.core.synth import synthetic_hlo
    from repro.core.topology import MeshSpec
    from repro.core.tracer import trace_from_hlo

    mesh = MeshSpec((2, 4), ("data", "model"))
    text = synthetic_hlo(n_sites=n_sites, seed=0, n_computations=64)
    shards = max(hlo_parser.auto_shards(len(text)), 2)
    usable = min(shards, os.cpu_count() or 1)

    t0 = time.perf_counter()
    tr_serial = trace_from_hlo(text, mesh, label="serial", shards=1)
    t_serial = time.perf_counter() - t0

    t0 = time.perf_counter()
    tr_shard = trace_from_hlo(text, mesh, label="sharded", shards=shards)
    t_shard = time.perf_counter() - t0

    def stats_match(a: dict, b: dict) -> bool:
        # int fields exact; float stats within 1e-9 relative — the shard
        # partial sums reassociate additions, which is exact only while
        # the integer-valued totals stay below 2^53 (a 405B-class dump
        # can exceed that without the parse being wrong)
        for key, va in a.items():
            vb = b[key]
            if isinstance(va, dict):
                if set(va) != set(vb) or any(
                        abs(va[s] - vb[s]) > 1e-9 * max(abs(va[s]), 1.0)
                        for s in va):
                    return False
            elif isinstance(va, float):
                if abs(va - vb) > 1e-9 * max(abs(va), 1.0):
                    return False
            elif va != vb:
                return False
        return True

    identical = (
        tr_shard.store.identical(tr_serial.store)
        and stats_match(dataclasses.asdict(tr_shard.op_stats),
                        dataclasses.asdict(tr_serial.op_stats))
        and tr_shard.by_kind_and_link() == tr_serial.by_kind_and_link()
        and tr_shard.total_est_time_s() == tr_serial.total_est_time_s())
    speedup = t_serial / max(t_shard, 1e-9)
    payload = {
        "bench": "shard_ingest",
        "sites": tr_shard.sites,
        "hlo_kb": len(text) // 1024,
        "shards": shards,
        "usable_cores": usable,
        "serial_s": round(t_serial, 4),
        "sharded_s": round(t_shard, 4),
        "speedup": round(speedup, 2),
        "target": 2.0,
        "gate_applies": usable >= 4 and n_sites >= 100_000,
        "byte_identical": identical,
    }
    _write_bench_payload("BENCH_shard", n_sites, payload, json_path)
    rows = [
        (f"overhead/shard{n_sites//1000}k/serial", t_serial * 1e6,
         "baseline-cost"),
        (f"overhead/shard{n_sites//1000}k/sharded", t_shard * 1e6,
         f"speedup={speedup:.2f}x|target>=2x@4cores|shards={shards}|"
         f"usable_cores={usable}|byte_identical={identical}"),
    ]
    return rows, payload


def _append_case(n_sites: int = 100_000, n_chunks: int = 16,
                 json_path: str = None):
    """Streaming append-mode ingest vs one batch parse.

    One multi-computation module splits into `n_chunks` per-computation
    chunks (the watch daemon's arrival order); each chunk parses and
    folds into a rolling store via `TraceStore.append`.  The appended
    store must be byte-identical (`TraceStore.identical`) to the batch
    `parse_hlo_store` of the whole text — the live-profiling invariant.

    Gate: >= 0.5x of the batch parse — amortized-doubling buffers and
    cached interning keep the chunked path within 2x of batch despite
    paying per-chunk parser overhead N times; a super-linear append
    (re-copying columns per chunk) collapses this ratio.
    """
    from repro.core import hlo_parser
    from repro.core.store import IncrementalRollup, TraceStore
    from repro.core.synth import synthetic_hlo

    mesh_devices = 8
    text = synthetic_hlo(n_sites=n_sites, seed=0, n_computations=64)
    chunks, ctx = hlo_parser.split_hlo_module(text, n_chunks)

    t0 = time.perf_counter()
    batch, _ = hlo_parser.parse_hlo_store(text, mesh_devices)
    t_batch = time.perf_counter() - t0

    t0 = time.perf_counter()
    acc = TraceStore.empty()
    roll = IncrementalRollup("kind_link")
    for c in chunks:
        store, _ = hlo_parser.parse_hlo_store(c, mesh_devices,
                                              shard_ctx=ctx)
        acc.append(store)
        roll.update(store)
    t_append = time.perf_counter() - t0

    identical = acc.identical(batch) and len(roll.labels) > 0
    speedup = t_batch / max(t_append, 1e-9)
    payload = {
        "bench": "append_ingest",
        "sites": acc.n,
        "hlo_kb": len(text) // 1024,
        "chunks": len(chunks),
        "batch_s": round(t_batch, 4),
        "append_s": round(t_append, 4),
        "speedup": round(speedup, 2),
        "target": 0.5,
        "byte_identical": identical,
    }
    _write_bench_payload("BENCH_append", n_sites, payload, json_path)
    rows = [
        (f"overhead/append{n_sites//1000}k/batch_parse", t_batch * 1e6,
         "baseline-cost"),
        (f"overhead/append{n_sites//1000}k/chunked_append", t_append * 1e6,
         f"speedup={speedup:.2f}x|target>=0.5x|chunks={len(chunks)}|"
         f"byte_identical={identical}"),
    ]
    return rows, payload


def _persist_case(n_sites: int = 100_000, json_path: str = None):
    """Session save/load round-trip: compressed npz vs compact JSON.

    Both formats must round-trip the columnar stores *exactly*
    (`TraceStore.identical`); the gated number is the npz/JSON
    round-trip ratio — roughly size-independent, so the smoke run
    tracks the committed trajectory, and an npz serialization
    regression drops it below the CI ratio gate.
    """
    import tempfile

    from repro.core.session import TraceSession
    from repro.core.synth import synthetic_trace
    from repro.core.topology import MeshSpec

    mesh = MeshSpec((2, 4), ("data", "model"))
    sess = TraceSession("persist", [
        synthetic_trace("a", mesh, n_sites=n_sites, seed=0),
        synthetic_trace("b", mesh, n_sites=n_sites, seed=1,
                        axis_weights=(3.0, 1.0)),
    ])
    for t in sess:                      # build stores outside the timing
        _ = t.store

    with tempfile.TemporaryDirectory() as td:
        jp = os.path.join(td, "sess.json")
        zp = os.path.join(td, "sess.npz")
        t0 = time.perf_counter()
        sess.save(jp)
        loaded_json = TraceSession.load(jp)
        t_json = time.perf_counter() - t0
        t0 = time.perf_counter()
        sess.save(zp)
        loaded_npz = TraceSession.load(zp)
        t_npz = time.perf_counter() - t0
        json_kb = os.path.getsize(jp) // 1024
        npz_kb = os.path.getsize(zp) // 1024

    def same(loaded):
        return (loaded.labels() == sess.labels() and all(
            a.store.identical(b.store)
            and a.total_est_time_s() == b.total_est_time_s()
            for a, b in zip(sess, loaded)))

    round_trip_ok = same(loaded_json) and same(loaded_npz)
    speedup = t_json / max(t_npz, 1e-9)
    payload = {
        "bench": "session_persist",
        "sites": n_sites,
        "n_traces": len(sess),
        "json_kb": json_kb,
        "npz_kb": npz_kb,
        "json_s": round(t_json, 4),
        "npz_s": round(t_npz, 4),
        "speedup": round(speedup, 2),
        "target": 1.0,
        "round_trip_ok": round_trip_ok,
    }
    _write_bench_payload("BENCH_persist", n_sites, payload, json_path)
    rows = [
        (f"overhead/persist{n_sites//1000}k/json_roundtrip", t_json * 1e6,
         "baseline-cost"),
        (f"overhead/persist{n_sites//1000}k/npz_roundtrip", t_npz * 1e6,
         f"speedup={speedup:.2f}x|target>=1x|json_kb={json_kb}|"
         f"npz_kb={npz_kb}|round_trip_ok={round_trip_ok}"),
    ]
    return rows, payload


def _merge_case(n_sites: int = 100_000, n_stores: int = 256,
                json_path: str = None):
    """Warehouse tree-reduction merge vs the serial left fold.

    `n_stores` per-host stores (distinct-seed synthetic modules, cycled
    so setup stays parse-light) reduce two ways: the O(n^2)-row-traffic
    left fold (`acc = merge([acc, s])`, the naive warehouse loop) and
    `TraceStore.merge_tree` (k-ary, process pool when cores allow).
    Both must be `identical` to the flat `TraceStore.merge` — the
    associativity invariant the query layer leans on.

    Gate: >= 2x over the fold at >= 4 usable cores (BENCH_shard's core
    guard); below that the run still records the algorithmic win —
    tree depth log_k(n) copies each row O(log n) times vs the fold's
    O(n) — which is why single-core smoke ratios stay meaningful.
    """
    from repro.core import hlo_parser
    from repro.core.store import TraceStore
    from repro.core.synth import synthetic_hlo

    per = max(n_sites // n_stores, 1)
    base = []
    for seed in range(min(n_stores, 16)):
        text = synthetic_hlo(n_sites=per, seed=seed, n_computations=1)
        store, _ = hlo_parser.parse_hlo_store(text, 8)
        base.append(store)
    stores = [base[i % len(base)] for i in range(n_stores)]
    usable = min(os.cpu_count() or 1, 8)

    flat = TraceStore.merge(stores)

    t0 = time.perf_counter()
    acc = stores[0]
    for s in stores[1:]:
        acc = TraceStore.merge([acc, s])
    t_fold = time.perf_counter() - t0

    t0 = time.perf_counter()
    tree = TraceStore.merge_tree(stores, arity=8, workers=usable)
    t_tree = time.perf_counter() - t0

    identical = tree.identical(flat) and acc.identical(flat)
    speedup = t_fold / max(t_tree, 1e-9)
    payload = {
        "bench": "merge_tree",
        "sites": flat.n,
        "stores": n_stores,
        "arity": 8,
        "usable_cores": usable,
        "fold_s": round(t_fold, 4),
        "tree_s": round(t_tree, 4),
        "speedup": round(speedup, 2),
        "target": 2.0,
        "gate_applies": usable >= 4 and n_sites >= 100_000,
        "byte_identical": identical,
    }
    _write_bench_payload("BENCH_merge", n_sites, payload, json_path)
    rows = [
        (f"overhead/merge{n_sites//1000}k/serial_fold", t_fold * 1e6,
         "baseline-cost"),
        (f"overhead/merge{n_sites//1000}k/tree_reduce", t_tree * 1e6,
         f"speedup={speedup:.2f}x|target>=2x@4cores|stores={n_stores}|"
         f"usable_cores={usable}|byte_identical={identical}"),
    ]
    return rows, payload


# Runs once per load mode in a child interpreter so the RSS high-water
# mark isolates that mode's footprint; mode "base" stops after the
# imports and prices the interpreter + numpy baseline the deltas
# subtract out.  Forked children inherit the parent's peak RSS (the
# bench parent holds the whole fleet session), so the worker resets
# its high-water mark to current RSS (`clear_refs`) after the imports
# and reads `VmHWM` — `ru_maxrss` is the fallback where /proc is
# missing, with the base subtraction absorbing the inherited peak.
_MMAP_WORKER = """
import json, resource, sys
mode, path = sys.argv[1], sys.argv[2]
from repro.core.session import TraceSession

def peak_kb():
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1])
    except OSError:
        pass
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss

try:
    with open("/proc/self/clear_refs", "w") as f:
        f.write("5")
except OSError:
    pass
out = {"query": None, "diff": None}
if mode != "base":
    sess = TraceSession.load(path, mmap=(mode == "mmap"))
    out["query"] = json.dumps(sess.query(host="00*", by="kind_link"),
                              sort_keys=True)
    out["diff"] = sess.diff("host=000", "host=001", as_json=True)
out["rss_kb"] = peak_kb()
print("JSON" + json.dumps(out))
"""


def _mmapload_case(n_sites: int = 1_000_000, json_path: str = None):
    """Eager vs memory-mapped fleet-session load, gated on peak RSS.

    An 8-host fleet session (`n_sites` total) is saved uncompressed,
    then three child interpreters report `ru_maxrss`: imports-only
    (base), eager `load`, and `load(mmap=True)` — each also running the
    same fleet `query` + slice `diff`.  Deltas over base make the
    numbers machine-portable; the gates are (1) the mmap delta under an
    absolute ceiling (`max(64MB, 200B/site)` — a materialized load
    costs ~160B/site in columns alone, so a leaky mmap path cannot
    hide), and (2) query/diff output byte-identical across load modes.
    The eager/mmap delta ratio is the CI trajectory `speedup`.
    """
    import subprocess
    import sys
    import tempfile

    from repro.core.session import TraceSession
    from repro.core.synth import synthetic_trace
    from repro.core.topology import MeshSpec

    n_hosts = 8
    per = max(n_sites // n_hosts, 1)
    mesh = MeshSpec((2, 4), ("data", "model"))
    sess = TraceSession("mmapfleet", [
        synthetic_trace(f"host{h:03d}_step000", mesh, n_sites=per, seed=h)
        for h in range(n_hosts)])
    for t in sess:
        _ = t.store

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") \
        + os.pathsep + env.get("PYTHONPATH", "")

    def probe(mode, path):
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, "-c", _MMAP_WORKER, mode, path],
            capture_output=True, text=True, env=env, check=True)
        dt = time.perf_counter() - t0
        for line in proc.stdout.splitlines():
            if line.startswith("JSON"):
                return json.loads(line[4:]), dt
        raise RuntimeError(f"no JSON output from {mode} worker:\n"
                           + proc.stderr)

    with tempfile.TemporaryDirectory() as td:
        zp = os.path.join(td, "fleet.npz")
        sess.save(zp, compress=False)
        npz_mb = os.path.getsize(zp) / 1e6
        base, _ = probe("base", zp)
        eager, t_eager = probe("eager", zp)
        mmap_, t_mmap = probe("mmap", zp)

    # floor both deltas at 1MB: tiny smoke runs otherwise divide page
    # noise by page noise and the trajectory ratio loses its meaning
    eager_delta = max(eager["rss_kb"] - base["rss_kb"], 1024) / 1024.0
    mmap_delta = max(mmap_["rss_kb"] - base["rss_kb"], 1024) / 1024.0
    ceiling_mb = max(64.0, n_sites * 200 / 1e6)
    under_ceiling = mmap_delta <= ceiling_mb
    byte_identical = (eager["query"] == mmap_["query"]
                      and eager["diff"] == mmap_["diff"]
                      and eager["query"] is not None)
    speedup = eager_delta / mmap_delta
    payload = {
        "bench": "mmap_load",
        "sites": n_hosts * per,
        "n_traces": n_hosts,
        "npz_mb": round(npz_mb, 1),
        "rss_base_mb": round(base["rss_kb"] / 1024.0, 1),
        "eager_delta_mb": round(eager_delta, 1),
        "mmap_delta_mb": round(mmap_delta, 1),
        "rss_ceiling_mb": round(ceiling_mb, 1),
        "rss_under_ceiling": under_ceiling,
        "byte_identical": byte_identical,
        "speedup": round(speedup, 2),
        "target": 2.0,
        "gate_applies": n_sites >= 100_000,
        "ok": under_ceiling and byte_identical,
    }
    _write_bench_payload("BENCH_mmapload", n_sites, payload, json_path)
    rows = [
        (f"overhead/mmap{n_sites//1000}k/eager_load", t_eager * 1e6,
         f"rss_delta_mb={eager_delta:.1f}|baseline-cost"),
        (f"overhead/mmap{n_sites//1000}k/mmap_load", t_mmap * 1e6,
         f"rss_delta_mb={mmap_delta:.1f}|rss_ratio={speedup:.2f}x|"
         f"ceiling_mb={ceiling_mb:.0f}|under_ceiling={under_ceiling}|"
         f"byte_identical={byte_identical}"),
    ]
    return rows, payload


def run():
    rows = _agg_100k_case()
    render_rows, _rpayload = _render_case()     # 100k: writes BENCH_render.json
    rows += render_rows
    ingest_rows, _payload = _ingest_case()      # 100k: writes BENCH_ingest.json
    rows += ingest_rows
    shard_rows, _spayload = _shard_case()       # 100k: writes BENCH_shard.json
    rows += shard_rows
    append_rows, _apayload = _append_case()     # 100k: BENCH_append.json
    rows += append_rows
    persist_rows, _ppayload = _persist_case()   # 100k: BENCH_persist.json
    rows += persist_rows
    merge_rows, _mpayload = _merge_case()       # 100k: BENCH_merge.json
    rows += merge_rows
    mmap_rows, _mmpayload = _mmapload_case()    # 1M: BENCH_mmapload.json
    rows += mmap_rows
    out = run_worker(WORKER, devices=8)
    for line in out.splitlines():
        if line.startswith("JSON"):
            return rows + [tuple(r) for r in json.loads(line[4:])]
    raise RuntimeError("no JSON output from worker")


if __name__ == "__main__":
    # smoke entry points for CI: the ingest and/or render cases only (pure
    # numpy, no jax compile workers), with a configurable trace size.
    import argparse
    import sys

    sys.path.insert(0, os.path.join(REPO, "src"))

    ap = argparse.ArgumentParser()
    ap.add_argument("--ingest-only", action="store_true")
    ap.add_argument("--render-only", action="store_true")
    ap.add_argument("--shard-only", action="store_true")
    ap.add_argument("--append-only", action="store_true")
    ap.add_argument("--persist-only", action="store_true")
    ap.add_argument("--merge-only", action="store_true")
    ap.add_argument("--mmapload-only", action="store_true")
    ap.add_argument("--sites", type=int,
                    default=int(os.environ.get("INGEST_SITES", 100_000)))
    args = ap.parse_args()
    if not (args.ingest_only or args.render_only or args.shard_only
            or args.append_only or args.persist_only or args.merge_only
            or args.mmapload_only):
        ap.error("pass --ingest-only / --render-only / --shard-only / "
                 "--append-only / --persist-only / --merge-only / "
                 "--mmapload-only as a direct entry point")
    cases = [
        # (enabled, case fn, artifact stem, equivalence key, label)
        (args.ingest_only, _ingest_case, "BENCH_ingest", "equivalent",
         "ingest"),
        (args.render_only, _render_case, "BENCH_render", "byte_identical",
         "render"),
        (args.shard_only, _shard_case, "BENCH_shard", "byte_identical",
         "shard"),
        (args.append_only, _append_case, "BENCH_append", "byte_identical",
         "append"),
        (args.persist_only, _persist_case, "BENCH_persist", "round_trip_ok",
         "persist"),
        (args.merge_only, _merge_case, "BENCH_merge", "byte_identical",
         "merge"),
        (args.mmapload_only, _mmapload_case, "BENCH_mmapload", "ok",
         "mmapload"),
    ]
    failed = False
    for enabled, case_fn, stem, equiv_key, label in cases:
        if not enabled:
            continue
        rows, payload = case_fn(n_sites=args.sites)
        dest = f"{stem}.json" if args.sites >= 100_000 \
            else f"results/{stem}_smoke.json"
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
        gate_applies = payload.get("gate_applies", args.sites >= 100_000)
        if not payload[equiv_key]:
            print(f"FAIL: {label} output diverges from its reference "
                  "engine", file=sys.stderr)
            failed = True
        elif payload["speedup"] < payload["target"] and gate_applies:
            print(f"FAIL: {label} speedup {payload['speedup']}x below the "
                  f"{payload['target']}x gate", file=sys.stderr)
            failed = True
        else:
            print(f"{label} ok: {payload['speedup']}x at {payload['sites']} "
                  f"sites -> {dest}")
    sys.exit(1 if failed else 0)
