"""The paper's primary contribution: multi-layer collective tracing for
JAX/TPU — HLO-parsed "UCT" events, mesh/link attribution, completion cost
model, scope/semantic ("UCP"/"MPI") attribution, detectors and reports.
"""
from repro.core.events import CollectiveEvent, Trace
from repro.core.store import TraceStore
from repro.core.topology import Hardware, MeshSpec, V5E
from repro.core.tracer import trace_compiled, trace_from_hlo, trace_step
from repro.core.roofline import RooflineReport, roofline
from repro.core.whatif import Scenario, reannotate, sweep

__all__ = [
    "CollectiveEvent", "Trace", "TraceStore", "TraceSession",
    "Hardware", "MeshSpec", "V5E",
    "trace_compiled", "trace_from_hlo", "trace_step",
    "RooflineReport", "roofline",
    "Scenario", "reannotate", "sweep",
]


def __getattr__(name):
    # lazy so `python -m repro.core.session` doesn't import the module twice
    if name == "TraceSession":
        from repro.core.session import TraceSession
        return TraceSession
    raise AttributeError(name)
