"""Renderer coverage: tables, matrices, timeline, JSON/HTML outputs."""
import json

import numpy as np
import pytest

from repro.core import report
from repro.core.events import CollectiveEvent, Trace
from repro.core.topology import MeshSpec


def mk_event(**kw):
    base = dict(name="ar", kind="all-reduce", async_start=False,
                operand_bytes=1 << 20, result_bytes=1 << 20, dtype="bf16",
                replica_groups=[[0, 1, 2, 3]], group_size=4, num_groups=1,
                op_name="jit(f)/layer/mlp/psum", computation="main",
                link_class="ici.data", axes=("data",), semantic="ffn",
                jax_prim="psum", scope="layer/mlp", protocol="rndv",
                wire_bytes_per_device=1.5 * (1 << 20), est_time_s=1e-4)
    base.update(kw)
    return CollectiveEvent(**base)


@pytest.fixture
def trace():
    evs = [
        mk_event(),
        mk_event(name="ag", kind="all-gather", semantic="attention",
                 scope="layer/attn", operand_bytes=1 << 22, multiplicity=4),
        mk_event(name="gs", semantic="grad_sync", scope="opt_update",
                 operand_bytes=1 << 24, est_time_s=5e-4),
        mk_event(name="cp", kind="collective-permute", semantic="pipeline",
                 replica_groups=[[0, 1]], group_size=2,
                 source_target_pairs=[(0, 1), (1, 2), (2, 3), (3, 0)]),
    ]
    return Trace(label="unit", mesh_shape=(2, 2), mesh_axes=("data", "model"),
                 num_devices=4, events=evs, hlo_flops=1e12, hlo_bytes=1e9)


def test_top_contenders_table(trace):
    out = report.top_contenders_table(trace)
    assert "all-reduce|ici.data" in out
    assert "all-gather|ici.data" in out
    lines = out.splitlines()
    assert lines[0].split()[0] == "key"
    assert lines[-1].startswith("total")
    assert "100.0%" in lines[-1]
    # rows sorted by descending bytes: grad_sync's 16MB all-reduce first
    assert "all-reduce" in lines[1]


def test_semantic_table(trace):
    out = report.semantic_table(trace)
    for sem in ("ffn", "attention", "grad_sync", "pipeline"):
        assert sem in out


def test_ascii_matrix_shading():
    mat = np.array([[0.0, 10.0], [5.0, 0.0]])
    out = report.ascii_matrix(mat, labels=["a", "b"])
    lines = out.splitlines()
    assert len(lines) == 2
    assert lines[0].strip().startswith("a |")
    # peak cell renders the densest shade, zero renders blank
    assert "@" in lines[0]
    assert out.count("@") == 1


def test_ascii_matrix_all_zero():
    out = report.ascii_matrix(np.zeros((2, 2)))
    assert "@" not in out


def test_timeline(trace):
    out = report.timeline(trace)
    lines = out.splitlines()
    assert "t_start_us" in lines[0]
    # heaviest (est*mult) first: grad_sync all-reduce (500us)
    assert "grad_sync" in lines[1]
    assert len(lines) == 1 + trace.store.n


def test_summary(trace):
    out = report.summary(trace)
    assert "trace 'unit'" in out
    assert f"({trace.store.n} sites)" in out


def test_to_json_roundtrips(trace):
    payload = json.loads(report.to_json(trace))
    assert payload["label"] == "unit"
    assert payload["mesh_shape"] == [2, 2]
    assert len(payload["events"]) == 4
    ev = {e["name"]: e for e in payload["events"]}
    assert ev["gs"]["semantic"] == "grad_sync"
    assert ev["ag"]["mult"] == 4
    assert ev["ar"]["bytes"] == 1 << 20
    # JSON -> string -> JSON is stable
    assert json.loads(json.dumps(payload)) == payload


def test_to_html_self_contained(trace):
    mesh = MeshSpec((2, 2), ("data", "model"))
    html = report.to_html(trace, mesh)
    assert html.startswith("<!doctype html>")
    assert "trace: unit" in html
    # self-contained: no external fetches
    assert "src=\"http" not in html and "href=\"http" not in html
    assert "<script src" not in html
    # one heatmap per mesh axis + the main sections
    assert html.count("comm matrix over axis") == 2
    for section in ("top contenders", "semantic", "modeled timeline"):
        assert section in html


def test_session_table_renders(trace):
    other = Trace(label="variant", mesh_shape=(2, 2),
                  mesh_axes=("data", "model"), num_devices=4,
                  events=[mk_event(operand_bytes=1 << 23)])
    out = report.session_table([trace, other])
    assert "unit" in out and "variant" in out
    assert "TOTAL modeled collective ms" in out
    assert "best=" in out


def test_session_table_empty():
    assert report.session_table([]) == "(empty session)"
