"""Mamba-1 selective SSM block (falcon-mamba / hymba mamba heads).

Training/prefill uses a chunked scan: an outer `lax.scan` over sequence
chunks carries the recurrent state h [B, d_inner, N]; within a chunk the
recurrence is evaluated with a numerically-stable `associative_scan`.
The TPU hot path is the Pallas kernel in `repro.kernels.mamba_scan`
(same chunking, explicit VMEM tiles); this module is the XLA reference
used for CPU smoke tests and the dry-run.

Decode carries (conv_state [B, d_conv-1, d_inner], ssm_state [B, d_inner, N]).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.meta import ParamMeta


def dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def ssm_meta(cfg, d_model=None):
    d = d_model or cfg.d_model
    di = cfg.expand * d
    n = cfg.ssm_state
    r = max(1, math.ceil(d / 16))
    return {
        "in_proj": ParamMeta((d, 2 * di), ("embed", "inner")),
        "conv_w": ParamMeta((cfg.d_conv, di), (None, "inner"), scale=0.5),
        "conv_b": ParamMeta((di,), ("inner",), init="zeros"),
        "x_proj": ParamMeta((di, r + 2 * n), ("inner", None)),
        "dt_w": ParamMeta((r, di), (None, "inner")),
        "dt_bias": ParamMeta((di,), ("inner",), init="constant", scale=-4.6),
        "a_log": ParamMeta((di, n), ("inner", None), init="a_log"),
        "d_skip": ParamMeta((di,), ("inner",), init="ones"),
        "out_proj": ParamMeta((di, d), ("inner", "embed")),
    }


def _ssm_inputs(cfg, p, xc, d):
    """Common pre-scan computation. xc [B, S, di] (post-conv, post-silu).

    Returns (a_bar, bx, c) with
      a_bar [B,S,di,N] = exp(delta * A), bx [B,S,di,N], c [B,S,N].
    """
    r = max(1, math.ceil(d / 16))
    n = cfg.ssm_state
    proj = jnp.einsum("bsd,dr->bsr", xc, p["x_proj"].astype(xc.dtype))
    dt_raw, b_ssm, c_ssm = jnp.split(proj, [r, r + n], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_raw, p["dt_w"].astype(xc.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                      # [B,S,di]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                 # [di,N]
    a_bar = jnp.exp(delta[..., None] * a)                        # [B,S,di,N]
    bx = (delta * xc.astype(jnp.float32))[..., None] \
        * b_ssm.astype(jnp.float32)[..., None, :]                # [B,S,di,N]
    return a_bar, bx, c_ssm.astype(jnp.float32)


def _conv1d_causal(cfg, p, x, conv_state=None):
    """Depthwise causal conv over S. x [B,S,di] -> [B,S,di].

    conv_state [B, d_conv-1, di] prepends history (decode/chunk-streaming).
    """
    dc = cfg.d_conv
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    w = p["conv_w"].astype(x.dtype)                              # [dc, di]
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(dc))
    return out + p["conv_b"].astype(x.dtype)


def _chunk_scan(a_bar, bx, h0):
    """Within-chunk associative scan. a_bar/bx [B,C,di,N], h0 [B,di,N].

    Returns (h_all [B,C,di,N], h_last).
    """
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    h_all = a_cum * h0[:, None] + b_cum
    return h_all, h_all[:, -1]


def apply_ssm(cfg, p, x, *, chunk=256, d_model=None):
    """Full-sequence selective SSM. x [B,S,D] -> [B,S,D].

    With `cfg.ssm_inloop`, the discretized terms a_bar/bx [B,C,di,N] are
    computed per chunk *inside* the scan instead of materializing the full
    [B,S,di,N] tensors up front (S/C times smaller live footprint and HBM
    traffic — the XLA stand-in for what the Pallas kernel does in VMEM).
    """
    with jax.named_scope("ssm"):
        d = d_model or cfg.d_model
        di = cfg.expand * d
        dt = x.dtype
        B, S, _ = x.shape
        xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt))
        x_in, z = jnp.split(xz, 2, axis=-1)
        xc = jax.nn.silu(_conv1d_causal(cfg, p, x_in))

        chunk = min(chunk, S)
        while S % chunk:
            chunk //= 2
        nck = S // chunk
        reshape = lambda t: t.reshape(B, nck, chunk, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))
        h0 = jnp.zeros((B, di, cfg.ssm_state), jnp.float32)

        def scan_chunk(h, a_c, bx_c, c_c):
            h_all, h_last = _chunk_scan(a_c, bx_c, h)
            y_c = jnp.einsum("bcdn,bcn->bcd", h_all, c_c)        # [B,C,di]
            return h_last, y_c

        if cfg.ssm_inloop:
            def outer(h, xc_c):
                a_c, bx_c, c_c = _ssm_inputs(cfg, p, xc_c, d)
                return scan_chunk(h, a_c, bx_c, c_c)
            _, y = jax.lax.scan(outer, h0, reshape(xc))
        else:
            a_bar, bx, c = _ssm_inputs(cfg, p, xc, d)

            def outer(h, args):
                return scan_chunk(h, *args)
            _, y = jax.lax.scan(outer, h0,
                                (reshape(a_bar), reshape(bx), reshape(c)))
        y = y.transpose(1, 0, 2, 3).reshape(B, S, di)
        y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
        y = y.astype(dt) * jax.nn.silu(z)
        return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt))


def init_ssm_state(cfg, batch, d_model=None, dtype=jnp.float32):
    d = d_model or cfg.d_model
    di = cfg.expand * d
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


def decode_ssm(cfg, p, x, state, *, d_model=None):
    """Single-token SSM step. x [B,1,D] -> ([B,1,D], new_state)."""
    with jax.named_scope("ssm_decode"):
        d = d_model or cfg.d_model
        dt = x.dtype
        xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt))
        x_in, z = jnp.split(xz, 2, axis=-1)                      # [B,1,di]
        xc = jax.nn.silu(_conv1d_causal(cfg, p, x_in, conv_state=state["conv"]))
        new_conv = jnp.concatenate(
            [state["conv"][:, 1:], x_in.astype(state["conv"].dtype)], axis=1)
        a_bar, bx, c = _ssm_inputs(cfg, p, xc, d)                # [B,1,di,N]
        h = a_bar[:, 0] * state["ssm"] + bx[:, 0]                # [B,di,N]
        y = jnp.einsum("bdn,bn->bd", h, c[:, 0])[:, None, :]     # [B,1,di]
        y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
        y = y.astype(dt) * jax.nn.silu(z)
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt))
        return out, {"conv": new_conv, "ssm": h}
