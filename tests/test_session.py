"""TraceStore round-trips, columnar/legacy equivalence, TraceSession I/O."""
import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core.events import CollectiveEvent, Trace
from repro.core.session import (TraceSession, demo_session, trace_from_dict,
                                trace_to_dict)
from repro.core.store import TraceStore
from repro.core.synth import synthetic_trace
from repro.core.topology import MeshSpec


def rand_trace(seed: int, n_sites: int = 200, mesh=None) -> Trace:
    mesh = mesh or MeshSpec((2, 4), ("data", "model"))
    return synthetic_trace(f"rand{seed}", mesh, n_sites=n_sites, seed=seed)


def agg_close(a, b):
    assert set(a) == set(b), (set(a) ^ set(b))
    for k in a:
        for field in ("bytes", "wire_bytes", "count", "time_s"):
            assert a[k][field] == pytest.approx(b[k][field], rel=1e-12), \
                (k, field)


# -- columnar vs legacy per-event equivalence -------------------------------

@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_by_kind_and_link_matches_legacy(seed):
    tr = rand_trace(seed)
    agg_close(tr.by_kind_and_link(),
              tr.by(lambda e: f"{e.kind}|{e.link_class}"))


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_by_semantic_matches_legacy(seed):
    tr = rand_trace(seed)
    agg_close(tr.by_semantic(), tr.by(lambda e: e.semantic or "other"))


def test_by_sem_kind_link_matches_legacy():
    tr = rand_trace(7)
    agg_close(tr.store.by_sem_kind_link(),
              tr.by(lambda e: f"{e.semantic}|{e.kind}|{e.link_class}"))


def test_totals_match_legacy():
    tr = rand_trace(3)
    evs = tr.events
    assert tr.total_collective_bytes() == pytest.approx(
        sum(e.operand_bytes * e.multiplicity for e in evs))
    assert tr.total_wire_bytes() == pytest.approx(
        sum(e.total_wire_bytes * e.multiplicity for e in evs))
    assert tr.total_est_time_s() == pytest.approx(
        sum(e.est_time_s * e.multiplicity for e in evs))
    per_class = {}
    for e in evs:
        per_class[e.link_class] = per_class.get(e.link_class, 0.0) \
            + e.est_time_s * e.multiplicity
    assert tr.overlapped_est_time_s() == pytest.approx(max(per_class.values()))


def test_comm_matrix_store_matches_legacy():
    from repro.core.topology import comm_matrix
    mesh = MeshSpec((2, 4), ("data", "model"))
    tr = rand_trace(11, mesh=mesh)
    fast = comm_matrix(mesh, tr)                  # columnar edge-list path
    slow = comm_matrix(mesh, list(tr.events))     # per-event reference
    np.testing.assert_allclose(fast, slow, rtol=1e-12)


def test_empty_trace_aggregates():
    tr = Trace(label="empty", mesh_shape=(2,), mesh_axes=("data",),
               num_devices=2, events=[])
    assert tr.by_kind_and_link() == {}
    assert tr.by_semantic() == {}
    assert tr.total_est_time_s() == 0.0
    assert tr.overlapped_est_time_s() == 0.0


# -- row views + store round-trip -------------------------------------------

def test_store_rows_roundtrip_events():
    tr = rand_trace(5, n_sites=50)
    rows = tr.store.rows()
    assert rows == tr.events          # dataclass equality, field by field


def test_store_dict_roundtrip_identical_aggregates():
    tr = rand_trace(9)
    store2 = TraceStore.from_dict(
        json.loads(json.dumps(tr.store.to_dict())))
    assert store2.n == tr.store.n
    agg_close(store2.by_kind_and_link(), tr.by_kind_and_link())
    agg_close(store2.by_semantic(), tr.by_semantic())
    assert store2.rows() == tr.store.rows()


def test_trace_dict_roundtrip(tmp_path):
    tr = rand_trace(13)
    tr.hlo_flops = 1.5e12
    tr2 = trace_from_dict(json.loads(json.dumps(trace_to_dict(tr))))
    assert tr2.label == tr.label
    assert tr2.mesh_shape == tr.mesh_shape
    assert tr2.hlo_flops == tr.hlo_flops
    agg_close(tr2.by_kind_and_link(), tr.by_kind_and_link())
    assert tr2.events == tr.events


def test_trace_store_invalidation_on_append():
    tr = rand_trace(1, n_sites=10)
    before = tr.total_collective_bytes()
    ev = tr.events[0]
    tr.events.append(CollectiveEvent(
        name="extra", kind=ev.kind, async_start=False,
        operand_bytes=1 << 25, result_bytes=1 << 25, dtype="bf16",
        replica_groups=ev.replica_groups, group_size=ev.group_size,
        num_groups=ev.num_groups, op_name="", computation="main"))
    assert tr.total_collective_bytes() == pytest.approx(before + (1 << 25))


# -- sessions ---------------------------------------------------------------

@pytest.mark.parametrize("ext", ["json", "npz"])
def test_session_save_load_roundtrip(tmp_path, ext):
    sess = TraceSession("unit", [rand_trace(0, 100), rand_trace(1, 100)])
    path = sess.save(str(tmp_path / f"s.{ext}"))
    loaded = TraceSession.load(path)
    assert loaded.name == "unit"
    assert loaded.labels() == sess.labels()
    for a, b in zip(sess, loaded):
        agg_close(a.by_kind_and_link(), b.by_kind_and_link())
        agg_close(a.by_semantic(), b.by_semantic())
        assert a.total_est_time_s() == pytest.approx(b.total_est_time_s())


def test_session_rejects_duplicate_labels():
    sess = TraceSession("unit", [rand_trace(0, 20)])
    with pytest.raises(ValueError):
        sess.add(rand_trace(0, 20))


def test_session_get_and_diff():
    sess = TraceSession("unit", [rand_trace(0, 100), rand_trace(1, 100)])
    assert sess.get("rand0").label == "rand0"
    with pytest.raises(KeyError):
        sess.get("nope")
    out = sess.diff("rand0", "rand1")
    assert "trace diff" in out


def test_session_table_and_totals():
    sess = demo_session(n_sites=200)
    assert len(sess) == 3
    out = sess.table()
    for label in sess.labels():
        assert label[:10] in out
    totals = sess.totals()
    assert len(totals) == 3
    assert all(r["est_ms"] > 0 for r in totals)
    # semantic view has the MPI-layer classes
    assert "grad_sync" in sess.table(by="semantic", metric="time")


def test_session_cli_demo(tmp_path, capsys):
    from repro.core.session import _main
    out_path = str(tmp_path / "demo.json")
    assert _main(["demo", "--out", out_path, "--sites", "120"]) == 0
    captured = capsys.readouterr().out
    assert "3 traces" in captured
    assert "session comparison" in captured
    assert _main(["show", out_path]) == 0
    assert _main(["table", out_path, "--by", "semantic"]) == 0


# -- diff UX: --top / --only-regressed / --json ------------------------------

def test_diff_top_and_only_regressed_filters():
    from repro.core.diff import diff_traces, render_diff
    a, b = rand_trace(0, 400), rand_trace(1, 400)
    rows = diff_traces(a, b)
    assert len(rows) > 3
    out_top = render_diff(a, b, top=2)
    # header + column line + 2 rows + TOTAL line
    assert len(out_top.splitlines()) == 5
    assert "top 2" in out_top
    out_reg = render_diff(a, b, only_regressed=True)
    body = out_reg.splitlines()[2:-1]
    assert all(("GREW" in ln) or ("NEW" in ln) for ln in body)
    assert "regressed only" in out_reg
    # default output unchanged (pinned header shape)
    assert render_diff(a, b).splitlines()[0] == \
        "trace diff: 'rand0' -> 'rand1'  (by kind_link)"


def test_diff_json_machine_readable():
    from repro.core.diff import diff_json, diff_traces
    a, b = rand_trace(0, 300), rand_trace(2, 300)
    payload = json.loads(json.dumps(diff_json(a, b, by="site", top=5)))
    assert payload["a"] == "rand0" and payload["b"] == "rand2"
    assert payload["by"] == "site" and payload["top"] == 5
    assert len(payload["rows"]) == 5
    ref = diff_traces(a, b, by="site")[:5]
    for row, r in zip(payload["rows"], ref):
        assert row["key"] == r.key
        assert row["bytes_a"] == r.bytes_a and row["bytes_b"] == r.bytes_b
        assert row["verdict"] == r.verdict()
        if r.bytes_a == 0 and r.bytes_b > 0:
            assert row["bytes_ratio"] is None
    assert payload["total_time_a_s"] == a.total_est_time_s()


def test_session_diff_cli_flags(tmp_path, capsys):
    from repro.core.session import _main
    out = str(tmp_path / "sess.json")
    demo_session(n_sites=150).save(out)
    assert _main(["diff", out, "dp8-baseline", "dp2xtp4",
                  "--by", "site", "--top", "3", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["by"] == "site"
    assert len(payload["rows"]) <= 3
    assert _main(["diff", out, "dp8-baseline", "dp2xtp4",
                  "--only-regressed"]) == 0
    assert "regressed only" in capsys.readouterr().out


# -- persistence: exact round-trips (the --persist-only bench invariant) -----

@pytest.mark.parametrize("ext", ["json", "npz"])
def test_session_roundtrip_stores_identical(tmp_path, ext):
    sess = TraceSession("rt", [rand_trace(0, 150), rand_trace(1, 150)])
    path = sess.save(str(tmp_path / f"rt.{ext}"))
    loaded = TraceSession.load(path)
    assert loaded.labels() == sess.labels()
    for a, b in zip(sess, loaded):
        assert a.store.identical(b.store)
        assert a.total_est_time_s() == b.total_est_time_s()


def test_save_load_extensionless_contract(tmp_path):
    # save defaults an extensionless path to .json and returns the path
    # actually written; load applies the same defaulting, so the caller
    # can round-trip through either the returned path or the original
    sess = TraceSession("ext", [rand_trace(0, 60)])
    bare = str(tmp_path / "noext")
    path = sess.save(bare)
    assert path == bare + ".json"
    import os
    assert os.path.exists(path) and not os.path.exists(bare)
    assert TraceSession.load(path).labels() == sess.labels()
    assert TraceSession.load(bare).labels() == sess.labels()


# -- atomic persistence: a failed save never destroys the previous file ------

def test_atomic_open_failure_leaves_target_and_no_tmp(tmp_path):
    from repro.core.persist import atomic_open
    import os
    target = tmp_path / "artifact.json"
    target.write_text("previous complete artifact")
    with pytest.raises(RuntimeError):
        with atomic_open(str(target)) as f:
            f.write("half-writ")
            raise RuntimeError("writer died mid-emit")
    assert target.read_text() == "previous complete artifact"
    assert [p for p in os.listdir(tmp_path) if p.endswith(".tmp")] == []
    with pytest.raises(ValueError):
        with atomic_open(str(target), mode="r"):
            pass


@pytest.mark.parametrize("ext", ["json", "npz"])
def test_session_save_failure_preserves_previous_save(tmp_path, ext,
                                                      monkeypatch):
    import os
    path = str(tmp_path / f"s.{ext}")
    TraceSession("old", [rand_trace(0, 40)]).save(path)
    before = open(path, "rb").read()
    boom = RuntimeError("serializer died")
    if ext == "json":
        monkeypatch.setattr(json, "dump",
                            lambda *a, **k: (_ for _ in ()).throw(boom))
    else:
        import repro.core.session as session_mod
        monkeypatch.setattr(session_mod, "write_npz",
                            lambda *a, **k: (_ for _ in ()).throw(boom))
    with pytest.raises(RuntimeError):
        TraceSession("new", [rand_trace(1, 40)]).save(path)
    assert open(path, "rb").read() == before
    assert [p for p in os.listdir(tmp_path) if p.endswith(".tmp")] == []


# -- from_hlo error policy: IngestError names the input, pool loss retries ---

def test_from_hlo_ingest_error_names_offending_input():
    from repro.core.session import IngestError
    mesh = MeshSpec((2, 4), ("data", "model"))
    items = [("good", ""), ("bad", None)]       # None explodes in the parser
    with pytest.raises(IngestError, match="bad"):
        TraceSession.from_hlo("s", items, mesh, max_workers=1)


def test_from_hlo_pool_path_wraps_per_file_errors(monkeypatch):
    # a synchronous fake pool: exercises the pool-branch error wiring
    # (probe, per-future IngestError) without paying spawn startup
    import concurrent.futures as cf
    from repro.core.session import IngestError

    class FakeFuture:
        def __init__(self, fn, *args):
            self._fn, self._args = fn, args

        def result(self, timeout=None):
            return self._fn(*self._args)

    class FakePool:
        def __init__(self, *a, **k):
            pass

        def submit(self, fn, *args):
            return FakeFuture(fn, *args)

        def shutdown(self, *a, **k):
            pass

    monkeypatch.setattr(cf, "ProcessPoolExecutor", FakePool)
    mesh = MeshSpec((2, 4), ("data", "model"))
    from repro.core.synth import synthetic_hlo
    good = [(f"g{i}", synthetic_hlo(n_sites=30, seed=i)) for i in range(2)]
    sess = TraceSession.from_hlo("s", good, mesh, max_workers=2)
    assert sess.labels() == ["g0", "g1"]
    with pytest.raises(IngestError, match="bad"):
        TraceSession.from_hlo("s", good + [("bad", None)], mesh,
                              max_workers=2)


def test_from_hlo_pool_startup_failure_falls_back_serial(monkeypatch):
    import concurrent.futures as cf

    def no_pool(*a, **k):
        raise OSError("spawn forbidden in this sandbox")

    monkeypatch.setattr(cf, "ProcessPoolExecutor", no_pool)
    mesh = MeshSpec((2, 4), ("data", "model"))
    from repro.core.synth import synthetic_hlo
    items = [(f"g{i}", synthetic_hlo(n_sites=30, seed=i)) for i in range(2)]
    sess = TraceSession.from_hlo("s", items, mesh, max_workers=2)
    assert sess.labels() == ["g0", "g1"]    # ingested serially, not dropped


# -- ingest policy: errors=skip|salvage, retries, report persistence ---------

def test_from_hlo_skip_drops_bad_inputs_and_records_them():
    from repro.core.synth import synthetic_hlo
    mesh = MeshSpec((2, 4), ("data", "model"))
    items = [("good", synthetic_hlo(n_sites=30, seed=1)), ("bad", None)]
    sess = TraceSession.from_hlo("s", items, mesh, max_workers=1,
                                 errors="skip", retries=0, retry_backoff_s=0)
    assert sess.labels() == ["good"]
    rep = sess.ingest_report
    assert rep.errors == "skip" and not rep.ok
    assert [(r.source, r.status) for r in rep.records] \
        == [("good", "ok"), ("bad", "skipped")]
    assert rep.degraded[0].error


def test_from_hlo_salvage_recovers_partial_trace():
    from repro.core.synth import corrupt_hlo, synthetic_hlo
    mesh = MeshSpec((2, 4), ("data", "model"))
    good = synthetic_hlo(n_sites=40, seed=2)
    bad = corrupt_hlo(good, "mangle_rg", seed=1)
    sess = TraceSession.from_hlo("s", [("g", good), ("b", bad)], mesh,
                                 max_workers=1, errors="salvage",
                                 retries=0, retry_backoff_s=0)
    assert sess.labels() == ["g", "b"]              # partial trace retained
    rec = {r.source: r for r in sess.ingest_report.records}["b"]
    assert rec.status == "salvaged" and rec.salvage["dropped"]
    assert 0 < sess.get("b").store.n < sess.get("g").store.n


def test_from_hlo_rejects_unknown_errors_policy():
    mesh = MeshSpec((2, 4), ("data", "model"))
    with pytest.raises(ValueError, match="errors"):
        TraceSession.from_hlo("s", [("a", "")], mesh, errors="ignore")


def test_from_hlo_retry_rereads_flaky_file(tmp_path, monkeypatch):
    """Transient failure (dump still landing): the retry re-reads the
    file and succeeds, recorded as ok with the attempt count."""
    import repro.core.session as sess_mod
    from repro.core.synth import corrupt_hlo, synthetic_hlo
    mesh = MeshSpec((2, 4), ("data", "model"))
    p = tmp_path / "flaky.txt"
    p.write_text("placeholder")
    good = synthetic_hlo(n_sites=30, seed=3)
    bad = corrupt_hlo(good, "mangle_rg", seed=2)
    reads = {"n": 0}

    def fake_read(path):
        reads["n"] += 1
        return bad if reads["n"] == 1 else good

    monkeypatch.setattr(sess_mod, "_read_text", fake_read)
    sess = TraceSession.from_hlo("s", [str(p)], mesh, max_workers=1,
                                 errors="skip", retries=2, retry_backoff_s=0)
    rec = sess.ingest_report.records[0]
    assert rec.status == "ok" and rec.attempts == 2
    assert sess.labels() == ["flaky"]


@pytest.mark.parametrize("ext", ["json", "npz"])
def test_ingest_report_round_trips_through_save(tmp_path, ext):
    from repro.core.synth import synthetic_hlo
    mesh = MeshSpec((2, 4), ("data", "model"))
    items = [("good", synthetic_hlo(n_sites=30, seed=4)), ("bad", None)]
    sess = TraceSession.from_hlo("s", items, mesh, max_workers=1,
                                 errors="skip", retries=0, retry_backoff_s=0)
    path = sess.save(str(tmp_path / f"s.{ext}"))
    loaded = TraceSession.load(path)
    assert loaded.ingest_report is not None
    assert loaded.ingest_report.to_dict() == sess.ingest_report.to_dict()
    # legacy payloads without a report still load
    legacy = TraceSession("legacy", [rand_trace(0, 40)])
    loaded2 = TraceSession.load(legacy.save(str(tmp_path / f"l.{ext}")))
    assert loaded2.ingest_report is None


# -- CLI ingest: the 0 / 3 / 2 exit-code contract ----------------------------

def test_cli_ingest_exit_0_on_full_success(tmp_path, capsys):
    from repro.core.session import _main
    from repro.core.synth import synthetic_hlo
    good = tmp_path / "good.txt"
    good.write_text(synthetic_hlo(n_sites=30, seed=5))
    rc = _main(["ingest", str(tmp_path / "s.json"), str(good),
                "--workers", "1", "--errors", "salvage"])
    capsys.readouterr()
    assert rc == 0


def test_cli_ingest_exit_3_when_degraded(tmp_path, capsys):
    from repro.core.session import _main
    from repro.core.synth import synthetic_hlo
    good = tmp_path / "good.txt"
    good.write_text(synthetic_hlo(n_sites=30, seed=5))
    bad = tmp_path / "bad.txt"
    bad.write_bytes(b"\xff\xfe not a module \xff")
    out = str(tmp_path / "s.json")
    rc = _main(["ingest", out, str(good), str(bad), "--workers", "1",
                "--errors", "skip", "--retries", "0",
                "--retry-backoff", "0"])
    err = capsys.readouterr().err
    assert rc == 3
    assert "quarantined" in err and "bad.txt" in err
    assert TraceSession.load(out).labels() == ["good"]   # still written


def test_cli_ingest_exit_2_in_raise_mode(tmp_path, capsys):
    from repro.core.session import _main
    bad = tmp_path / "bad.txt"
    bad.write_bytes(b"\xff\xfe not a module \xff")
    rc = _main(["ingest", str(tmp_path / "s.json"), str(bad),
                "--workers", "1"])
    capsys.readouterr()
    assert rc == 2


# -- atomic_open: the rename itself is made durable --------------------------

def test_atomic_open_fsyncs_parent_directory(tmp_path, monkeypatch):
    """Pin the durability contract: after os.replace, the parent
    directory fd is fsynced — without it a crash can lose the rename
    even though the data blocks hit disk."""
    import os
    import stat
    from repro.core import persist
    synced_dir_fds = []
    real_fsync = os.fsync

    def spy(fd):
        synced_dir_fds.append(stat.S_ISDIR(os.fstat(fd).st_mode))
        return real_fsync(fd)

    monkeypatch.setattr(os, "fsync", spy)
    with persist.atomic_open(str(tmp_path / "x.json")) as f:
        f.write("{}")
    assert True in synced_dir_fds, \
        "atomic_open must fsync the parent directory after the rename"
    assert (tmp_path / "x.json").read_text() == "{}"
