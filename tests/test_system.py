"""End-to-end system behaviour: distributed trace -> attribution -> report,
dry-run machinery at reduced scale, loss-path equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, smoke_config
from repro.models import api
from repro.models.losses import cross_entropy, fused_lm_head_loss


def test_fused_loss_equals_reference():
    """Fused chunked head+xent == full-logits cross-entropy."""
    from repro.models import transformer
    cfg = smoke_config(ARCHS["chatglm3-6b"])
    params = api.init_params(cfg, 0)
    B, S = 2, 32
    batch = api.demo_batch(cfg, B, S)
    hidden, _aux = transformer.forward_hidden(cfg, params, batch,
                                              attn_impl="naive")
    targets = jnp.roll(batch["tokens"], -1, axis=1)
    mask = jnp.ones((B, S), jnp.float32).at[:, -1].set(0.0)
    fused = fused_lm_head_loss(cfg, params["embed"], hidden, targets, mask,
                               chunk=8)
    from repro.models.layers import logits_head
    logits = logits_head(cfg, params["embed"], hidden)
    ref = cross_entropy(logits, targets, mask)
    np.testing.assert_allclose(float(fused), float(ref), rtol=2e-5)


def test_fused_loss_gradients_match():
    cfg = smoke_config(ARCHS["h2o-danube-3-4b"])
    params = api.init_params(cfg, 0)
    batch = api.demo_batch(cfg, 2, 32)

    def loss_fused(p):
        return api.loss_fn(cfg, p, batch, attn_impl="naive")

    def loss_ref(p):
        from repro.models.losses import lm_loss
        logits, aux = api.forward(cfg, p, batch, attn_impl="naive")
        return lm_loss(cfg, logits, batch, aux)

    lf, gf = jax.value_and_grad(loss_fused)(params)
    lr, gr = jax.value_and_grad(loss_ref)(params)
    np.testing.assert_allclose(float(lf), float(lr), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_traced_train_step_multi_device(subproc):
    """8-device mesh: trace a smoke train step; assert the multi-layer
    attribution pipeline produces grad_sync + module semantics + sane
    roofline terms (the paper's core loop, end to end)."""
    out = subproc("""
import jax
import jax.numpy as jnp
from repro.configs import ARCHS, smoke_config
from repro.core import MeshSpec, roofline, trace_from_hlo
from repro.core.report import top_contenders_table
from repro.distributed import sharding as sh
from repro.distributed.autoshard import activation_sharding
from repro.launch.presets import StepSettings
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import adamw

cfg = smoke_config(ARCHS["chatglm3-6b"]).replace(
    d_model=128, d_ff=256, num_layers=4, vocab_size=512, num_heads=8,
    num_kv_heads=4, head_dim=16)
mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = MeshSpec((2, 4), ("data", "model"))
opt_cfg = adamw.AdamWConfig()
st = StepSettings(accum=2, remat="full")
step = make_train_step(cfg, opt_cfg, st)
params = api.abstract_params(cfg)
f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
opt = {"m": jax.tree.map(f32, params), "v": jax.tree.map(f32, params),
       "count": jax.ShapeDtypeStruct((), jnp.int32)}
shape = type("S", (), {"global_batch": 8, "seq_len": 64, "kind": "train"})()
batch = api.batch_specs(cfg, shape)
pspecs = sh.param_pspecs(cfg, mesh)
in_sh = (sh.named(mesh, pspecs),
         sh.named(mesh, {"m": pspecs, "v": pspecs,
                         "count": jax.sharding.PartitionSpec()}),
         None)
jfn = jax.jit(step, in_shardings=in_sh, donate_argnums=(0, 1))
with activation_sharding(mesh):
    lowered = jfn.lower(params, opt, batch)
compiled = lowered.compile()
tr = trace_from_hlo(compiled.as_text(), spec, label="smoke",
                    cost_analysis=compiled.cost_analysis(),
                    memory_analysis=compiled.memory_analysis())
assert len(tr.events) > 0, "no collectives found"
sems = {e.semantic for e in tr.events}
assert "grad_sync" in sems, sems
kinds = {e.kind for e in tr.events}
assert "all-reduce" in kinds
links = {e.link_class for e in tr.events}
assert any(l.startswith("ici.") for l in links), links
mults = [e.multiplicity for e in tr.events]
assert max(mults) >= 4, mults   # layer scan counted per-iteration
assert tr.hlo_flops > 0 and tr.hlo_bytes > 0
rf = roofline(tr, model_flops=1e9)
assert rf.bound_s > 0 and rf.dominant in ("compute", "memory", "collective")
print(top_contenders_table(tr)[:200])
print("TRACE_OK", len(tr.events), rf.dominant)
""")
    assert "TRACE_OK" in out


def test_dryrun_cell_small_mesh(subproc):
    """The dry-run driver end-to-end on an 8-device mesh (real arch)."""
    out = subproc("""
import jax
from repro.core import MeshSpec
from repro.launch.dryrun import lower_cell
mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = MeshSpec((2, 4), ("data", "model"))
r = lower_cell("hymba-1.5b", "decode_32k", mesh=mesh, mesh_spec=spec)
assert "skipped" not in r, r
assert r["compile_s"] > 0
assert r["n_collectives"] > 0
assert r["dominant"] in ("compute", "memory", "collective")
print("DRYRUN_OK", r["dominant"], r["mem_model_gb"])
""", devices=8)
    assert "DRYRUN_OK" in out


def test_detectors_fire_on_misconfiguration(subproc):
    """Fig 7 analogue: a sharding misconfiguration produces axis-detour
    traffic visible to the detector suite."""
    out = subproc("""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import MeshSpec, trace_from_hlo
from repro.core import detect

mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = MeshSpec((2, 4), ("data", "model"))

def step(w, x):
    h = jnp.einsum("bd,df->bf", x, w)
    return (h.astype(jnp.float32) ** 2).sum()

g = jax.grad(step)
bad = jax.jit(g, in_shardings=(NamedSharding(mesh, P("data", "model")),
                               NamedSharding(mesh, P("model", None))))
with mesh:
    compiled = bad.lower(jax.ShapeDtypeStruct((256, 512), jnp.bfloat16),
                         jax.ShapeDtypeStruct((64, 256), jnp.bfloat16)).compile()
tr = trace_from_hlo(compiled.as_text(), spec, label="bad")
assert len(tr.events) > 0
finds = detect.run_all(tr, expected_axes={"grad_sync": "data"})
print("N_EVENTS", len(tr.events), "FINDINGS", len(finds))
print("MISCONFIG_OK")
""")
    assert "MISCONFIG_OK" in out


def test_input_specs_cover_all_cells():
    """Every runnable (arch x shape) has well-formed ShapeDtypeStruct specs;
    exactly 4 documented skips out of the 40 assigned cells."""
    from repro.configs import (ARCH_ORDER, SHAPE_ORDER, get_config,
                               shape_applicable)
    n_cells = 0
    n_skipped = 0
    for arch in ARCH_ORDER:
        cfg = get_config(arch)
        for sname in SHAPE_ORDER:
            shape = SHAPES[sname]
            ok, reason = shape_applicable(cfg, shape)
            if not ok:
                n_skipped += 1
                assert reason
                continue
            specs = api.input_specs(cfg, shape)
            leaves = jax.tree.leaves(specs)
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
            assert all(all(d > 0 for d in l.shape) for l in leaves)
            n_cells += 1
    assert n_cells + n_skipped == 40
    # long_500k skips: chatglm3/llama3/qwen2-vl/qwen3-moe (pure full
    # attention) + whisper (enc-dec audio)
    assert n_skipped == 5


def test_report_renderers():
    """ASCII/JSON/HTML renderers run on a synthetic trace."""
    from repro.core.events import CollectiveEvent, Trace
    from repro.core.topology import MeshSpec, V5E
    from repro.core import costmodel, attribution, report
    mesh = MeshSpec((2, 4), ("data", "model"))
    evs = []
    for i, kind in enumerate(["all-reduce", "all-gather", "all-to-all"]):
        ev = CollectiveEvent(
            name=f"c{i}", kind=kind, async_start=False,
            operand_bytes=1 << (18 + i), result_bytes=1 << (18 + i),
            dtype="bf16", replica_groups=[[0, 1, 2, 3], [4, 5, 6, 7]],
            group_size=4, num_groups=2,
            op_name=f"jit(f)/layer/attn/prim{i}", computation="main",
            multiplicity=i + 1)
        costmodel.annotate_event(ev, mesh, V5E)
        attribution.attribute_event(ev)
        evs.append(ev)
    tr = Trace("synthetic", mesh.shape, mesh.axes, 8, evs)
    tr.hlo_flops = 1e12
    tr.hlo_bytes = 1e10
    assert "all-reduce" in report.top_contenders_table(tr)
    assert "attention" in report.semantic_table(tr)
    assert "synthetic" in report.summary(tr)
    assert "timeline" not in report.timeline(tr)  # renders rows
    js = report.to_json(tr)
    assert '"kind": "all-reduce"' in js
    html = report.to_html(tr, mesh)
    assert "<h2>" in html and "comm matrix" in html
