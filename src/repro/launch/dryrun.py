import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place that forces 512
# placeholder devices; tests and benchmarks see the real device count.

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, SHAPE_ORDER, get_config, shape_applicable
from repro.core import MeshSpec, roofline, trace_from_hlo
from repro.core.report import to_html, to_json, top_contenders_table, semantic_table
from repro.core.roofline import decode_model_flops, train_model_flops
from repro.distributed import sharding as sh
from repro.distributed.autoshard import activation_sharding
from repro.launch import presets, steps
from repro.launch.mesh import make_mesh_spec, make_production_mesh
from repro.models import api as model_api
from repro.optim import adamw


def analytic_memory_bytes(cfg, shape, st, mesh, rules) -> Dict[str, float]:
    """Per-device HBM model at *declared* dtypes.

    `memory_analysis()` on the CPU host backend over-reports bf16 programs:
    CPU float-normalization upcasts bf16 dots to f32, which drags the saved
    residual stacks (and their loop carries) to f32 — a backend artifact a
    TPU compile does not share (native bf16 MXU).  This estimator prices the
    structural buffers exactly (sharded params / optimizer moments / grad
    accumulators / layer-boundary remat saves / KV caches / batch) and adds
    15% working-set slack.
    """
    import numpy as np
    from repro.models.meta import is_meta

    sizes = sh.mesh_axis_sizes(mesh)
    meta_tree = model_api.model_meta(cfg)
    pspecs = sh.param_pspecs(cfg, mesh, rules)

    def local_count(meta, spec):
        n = int(np.prod(meta.shape))
        div = 1
        for part in spec:
            if part is None:
                continue
            axes = (part,) if isinstance(part, str) else part
            for a in axes:
                div *= sizes[a]
        return n // max(div, 1)

    flat_meta = jax.tree.leaves(meta_tree, is_leaf=is_meta)
    flat_spec = jax.tree.leaves(pspecs,
                                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    param_elems = sum(local_count(m, s) for m, s in zip(flat_meta, flat_spec))

    out: Dict[str, float] = {}
    B, S = shape.global_batch, shape.seq_len
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    if shape.kind == "train":
        pbytes = param_elems * 4                       # fp32 masters
        opt_b = 2 * param_elems * (2 if st.opt_state_dtype == "bfloat16" else 4)
        accum_b = param_elems * (2 if st.accum_dtype == "bfloat16" else 4) \
            if st.accum > 1 else 0
        grad_b = param_elems * 4                       # live grad during update
        tok_local = max(B // dp, 1) * S // max(st.accum, 1)
        saves = cfg.num_layers * tok_local * cfg.d_model * 2
        if st.seq_shard:
            saves //= max(sizes.get("model", 1), 1)
        if cfg.family == "encdec":
            saves += cfg.encoder_layers * max(B // dp, 1) * cfg.source_len \
                * cfg.d_model * 2
        out.update(params=pbytes, opt=opt_b, accum=accum_b, grad=grad_b,
                   saves=saves)
    else:
        out["params"] = param_elems * 2                # bf16 serving weights
        if shape.kind == "decode":
            cache = model_api.cache_specs(cfg, shape)
            cps = sh.cache_pspecs(cfg, shape, mesh)
            centries = [cache] if isinstance(cache, dict) else cache
            cpss = [cps] if isinstance(cps, dict) else cps
            cb = 0
            for entry, especs in zip(centries, cpss):
                for k, sds in entry.items():
                    n = int(np.prod(sds.shape))
                    div = 1
                    for part in especs[k]:
                        if part is None:
                            continue
                        axes = (part,) if isinstance(part, str) else part
                        for a in axes:
                            div *= sizes[a]
                    cb += (n // max(div, 1)) * jnp.dtype(sds.dtype).itemsize
            out["cache"] = float(cb)
        else:  # prefill: caches produced as outputs + activations
            tok_local = max(B // dp, 1) * S
            kvb = cfg.num_layers * tok_local * cfg.kv_dim * 2 * 2
            out["cache"] = kvb / max(sizes.get("model", 1), 1) \
                if cfg.family != "ssm" else 0.0
            out["acts"] = tok_local * cfg.d_model * 2 * 4
    total = sum(out.values())
    out["total_with_slack"] = total * 1.15
    return out


def _serve_rules(cfg, mesh, st):
    if st.serve_fsdp is None:
        return sh.serve_rules_for(cfg, mesh)
    return sh.SERVE_RULES_FSDP if st.serve_fsdp else sh.SERVE_RULES_REPLICATED


def abstract_opt_state(params_abs, state_dtype: str):
    dt = jnp.dtype(state_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {"m": jax.tree.map(z, params_abs),
            "v": jax.tree.map(z, params_abs),
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               settings: Optional[presets.StepSettings] = None,
               mesh=None, mesh_spec: Optional[MeshSpec] = None,
               compile_: bool = True,
               cfg_overrides: Optional[Dict[str, Any]] = None
               ) -> Dict[str, Any]:
    """Lower+compile one (arch x shape x mesh) cell; return artifacts."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    st = settings or presets.settings_for(arch, shape_name)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_spec = make_mesh_spec(multi_pod=multi_pod)
    assert mesh_spec is not None

    # training keeps fp32 masters; serving runs bf16 weights
    params_abs = model_api.abstract_params(
        cfg, "float32" if shape.kind == "train" else "bfloat16")
    t0 = time.perf_counter()

    if shape.kind == "train":
        rules = sh.TRAIN_RULES_HSDP if st.hsdp else sh.TRAIN_RULES
        pspecs = sh.param_pspecs(cfg, mesh, rules)
        opt_cfg = adamw.AdamWConfig(state_dtype=st.opt_state_dtype)
        step = steps.make_train_step(cfg, opt_cfg, st)
        opt_abs = abstract_opt_state(params_abs, st.opt_state_dtype)
        batch_abs = model_api.batch_specs(cfg, shape)
        in_sh = (sh.named(mesh, pspecs),
                 sh.named(mesh, {"m": pspecs, "v": pspecs,
                                 "count": jax.sharding.PartitionSpec()}),
                 sh.named(mesh, sh.batch_pspecs(cfg, shape, mesh)))
        out_sh = (in_sh[0], in_sh[1], None)
        jfn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=(0, 1))
        args = (params_abs, opt_abs, batch_abs)
        n_tokens = shape.global_batch * shape.seq_len
        model_flops = train_model_flops(model_api.flops_param_count(cfg), n_tokens)

    elif shape.kind == "prefill":
        rules = _serve_rules(cfg, mesh, st)
        pspecs = sh.param_pspecs(cfg, mesh, rules)
        step = steps.make_prefill_step(cfg, st)
        batch_abs = model_api.batch_specs(cfg, shape)
        in_sh = (sh.named(mesh, pspecs),
                 sh.named(mesh, sh.batch_pspecs(cfg, shape, mesh)))
        jfn = jax.jit(step, in_shardings=in_sh)
        args = (params_abs, batch_abs)
        n_tokens = shape.global_batch * shape.seq_len
        model_flops = decode_model_flops(model_api.flops_param_count(cfg), n_tokens)

    else:  # decode
        rules = _serve_rules(cfg, mesh, st)
        pspecs = sh.param_pspecs(cfg, mesh, rules)
        step = steps.make_decode_step(cfg, st)
        dspec = model_api.decode_input_specs(cfg, shape)
        cache_ps = sh.cache_pspecs(cfg, shape, mesh)
        P = jax.sharding.PartitionSpec
        in_sh = [sh.named(mesh, pspecs), sh.named(mesh, cache_ps),
                 jax.sharding.NamedSharding(mesh, P(None, None)),
                 jax.sharding.NamedSharding(mesh, P())]
        args = [params_abs, dspec["cache"], dspec["tokens"], dspec["pos"]]
        if cfg.family == "vlm":
            in_sh.append(jax.sharding.NamedSharding(mesh, P(None, None, None)))
            args.append(dspec["positions"])
        jfn = jax.jit(step, in_shardings=tuple(in_sh), donate_argnums=(1,))
        args = tuple(args)
        model_flops = decode_model_flops(model_api.flops_param_count(cfg),
                                         shape.global_batch)

    with activation_sharding(mesh, seq_shard=(st.seq_shard and
                                              shape.kind == "train")):
        lowered = jfn.lower(*args)
    t_lower = time.perf_counter() - t0
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh_spec.shape)),
        "lower_s": round(t_lower, 2),
    }
    if not compile_:
        result["lowered"] = lowered
        return result

    t1 = time.perf_counter()
    compiled = lowered.compile()
    result["compile_s"] = round(time.perf_counter() - t1, 2)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    t2 = time.perf_counter()
    trace = trace_from_hlo(compiled.as_text(), mesh_spec,
                           label=f"{arch}/{shape_name}/{result['mesh']}",
                           cost_analysis=cost, memory_analysis=mem)
    result["parse_s"] = round(time.perf_counter() - t2, 2)
    rf = roofline(trace, model_flops=model_flops)
    result.update(rf.row())
    result["collective_bytes_per_dev"] = trace.total_collective_bytes()
    result["coll_overlap_ms"] = round(trace.overlapped_est_time_s() * 1e3, 3)
    result["n_collectives"] = int(sum(e.multiplicity for e in trace.events))
    mem_model = analytic_memory_bytes(cfg, shape, st, mesh, rules)
    result["mem_model_gb"] = round(mem_model["total_with_slack"] / 1e9, 2)
    # fits: analytic model at TPU dtypes (memory_analysis() on the CPU host
    # backend upcasts bf16 stacks to f32 — see analytic_memory_bytes)
    result["fits_hbm"] = bool(mem_model["total_with_slack"] <= 16e9)
    result["trace"] = trace
    result["compiled"] = compiled
    return result


def run_cli():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="JSON results path (append)")
    ap.add_argument("--html", default=None, help="write HTML trace report dir")
    ap.add_argument("--tables", action="store_true",
                    help="print top-contenders + semantic tables")
    ap.add_argument("--whatif", action="store_true",
                    help="sweep the default what-if scenario grid over each "
                         "compiled trace and print a baseline-vs-best "
                         "roofline overlay (core.whatif, hardwareless)")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--grad-compression", default=None)
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch in (None, "all") else [args.arch]
    shapes = list(SHAPE_ORDER) if args.shape in (None, "all") else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    rows = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                st = presets.settings_for(arch, shape_name)
                if args.accum:
                    st = dataclasses.replace(st, accum=args.accum)
                if args.remat:
                    st = dataclasses.replace(st, remat=args.remat)
                if args.grad_compression:
                    st = dataclasses.replace(st, grad_compression=args.grad_compression)
                try:
                    r = lower_cell(arch, shape_name, multi_pod=mp, settings=st)
                except Exception as e:
                    print(f"FAIL  {arch:24s} {shape_name:12s} "
                          f"{type(e).__name__}: {str(e)[:200]}")
                    rows.append({"arch": arch, "shape": shape_name,
                                 "failed": f"{type(e).__name__}: {str(e)[:300]}"})
                    continue
                if "skipped" in r:
                    print(f"SKIP  {arch:24s} {shape_name:12s} {r['skipped']}")
                    rows.append(r)
                    continue
                tr = r.pop("trace")
                compiled = r.pop("compiled")
                print(f"OK    {arch:24s} {shape_name:12s} mesh={r['mesh']:9s} "
                      f"mem={r['mem_model_gb']:6.2f}GB(model)/"
                      f"{r['mem_gb_per_dev']:7.2f}GB(cpu) "
                      f"fits={'Y' if r['fits_hbm'] else 'N'} "
                      f"comp={r['compute_ms']:9.2f}ms "
                      f"hbm={r['memory_ms']:9.2f}ms "
                      f"coll={r['collective_ms']:9.2f}ms "
                      f"dom={r['dominant']:10s} mfu_bound={r['mfu_bound']:.3f} "
                      f"useful={r['useful_ratio']:.2f} "
                      f"(lower {r['lower_s']}s compile {r['compile_s']}s)")
                print("      memory_analysis:", compiled.memory_analysis())
                if args.tables:
                    print(top_contenders_table(tr))
                    print(semantic_table(tr))
                if args.whatif:
                    from repro.core import whatif
                    from repro.core.roofline import scenario_overlay_table
                    spec = make_mesh_spec(multi_pod=mp)
                    results = whatif.sweep(tr.store, spec)
                    rf = roofline(tr, model_flops=r["model_gflops"] * 1e9)
                    print(scenario_overlay_table(rf, results))
                    best = results[0] if results else None
                    if best is not None and best.saved_s > 0:
                        print(f"      best config: {best.scenario.name} "
                              f"saves {whatif.fmt_time(best.saved_s)}/step "
                              f"({best.speedup:.2f}x collective) — "
                              f"{best.scenario.description}")
                        r["whatif_best"] = best.scenario.name
                        r["whatif_saved_ms"] = round(best.saved_s * 1e3, 3)
                if args.html:
                    os.makedirs(args.html, exist_ok=True)
                    name = f"{arch}_{shape_name}_{r['mesh']}"
                    spec = make_mesh_spec(multi_pod=mp)
                    with open(os.path.join(args.html, name + ".html"), "w") as f:
                        f.write(to_html(tr, spec))
                    with open(os.path.join(args.html, name + ".json"), "w") as f:
                        f.write(to_json(tr))
                rows.append(r)
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        with open(args.out, "w") as f:
            json.dump(existing + rows, f, indent=1, default=str)
    return rows


if __name__ == "__main__":
    run_cli()
