"""Quickstart: trace the communication of a sharded training step.

    PYTHONPATH=src python examples/quickstart.py

Builds an 8-device host mesh, compiles one train step of a reduced dense LM,
and prints the multi-layer trace: top-contenders (Table II analogue),
semantic rollup (MPI-layer analogue), modeled timeline and roofline terms.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.core import MeshSpec, roofline, trace_from_hlo
from repro.core.report import (semantic_table, summary, timeline,
                               top_contenders_table)
from repro.distributed import sharding as sh
from repro.distributed.autoshard import activation_sharding
from repro.launch.presets import StepSettings
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import adamw


def main():
    cfg = smoke_config(ARCHS["chatglm3-6b"]).replace(
        d_model=256, d_ff=512, num_layers=6, vocab_size=1024,
        num_heads=8, num_kv_heads=4, head_dim=32)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    spec = MeshSpec((2, 4), ("data", "model"))

    step = make_train_step(cfg, adamw.AdamWConfig(),
                           StepSettings(accum=2, remat="full"))
    params = api.abstract_params(cfg)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = {"m": jax.tree.map(f32, params), "v": jax.tree.map(f32, params),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    shape = type("S", (), {"global_batch": 8, "seq_len": 256,
                           "kind": "train"})()
    batch = api.batch_specs(cfg, shape)
    pspecs = sh.param_pspecs(cfg, mesh)
    jfn = jax.jit(step, donate_argnums=(0, 1), in_shardings=(
        sh.named(mesh, pspecs),
        sh.named(mesh, {"m": pspecs, "v": pspecs,
                        "count": jax.sharding.PartitionSpec()}),
        sh.named(mesh, sh.batch_pspecs(cfg, shape, mesh))))

    print("lowering + compiling one train step on a 2x4 mesh ...")
    with activation_sharding(mesh):
        compiled = jfn.lower(params, opt, batch).compile()

    trace = trace_from_hlo(compiled.as_text(), spec, label="quickstart",
                           cost_analysis=compiled.cost_analysis(),
                           memory_analysis=compiled.memory_analysis())
    print()
    print(summary(trace))
    print("\n--- top contenders (collective kind x link class) ---")
    print(top_contenders_table(trace))
    print("\n--- semantic rollup (grad_sync / attention / ffn / ...) ---")
    print(semantic_table(trace))
    print("\n--- modeled timeline (heaviest collectives) ---")
    print(timeline(trace, top=10))
    rf = roofline(trace, model_flops=6.0 * api.flops_param_count(cfg)
                  * shape.global_batch * shape.seq_len)
    print(f"\nroofline: compute {rf.compute_s*1e3:.2f} ms | memory "
          f"{rf.memory_s*1e3:.2f} ms | collective {rf.collective_s*1e3:.2f} ms"
          f" -> dominant: {rf.dominant} (mfu bound {rf.model_roofline_fraction:.3f})")


if __name__ == "__main__":
    main()
