"""Fig 6 + Table II analogue: communication graphs + top-contenders of real
model steps (dense vs MoE — the paper's Hook_1498 vs nd24k contrast maps to
few-big-transfers vs many-small-transfers).

Emits the bytes% (count%) per (HLO collective x link class) table — the
direct Table II reproduction — for a dense and a MoE arch train step.
"""
from __future__ import annotations

import json

from _util import run_worker

WORKER = """
import json
import jax
import jax.numpy as jnp
from repro.configs import ARCHS, smoke_config
from repro.core import MeshSpec, trace_from_hlo
from repro.core.report import top_contenders_table, semantic_table
from repro.distributed import sharding as sh
from repro.distributed.autoshard import activation_sharding
from repro.launch.presets import StepSettings
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import adamw

mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = MeshSpec((2, 4), ("data", "model"))
rows = []
for arch in ("chatglm3-6b", "mixtral-8x22b"):
    cfg = smoke_config(ARCHS[arch]).replace(
        d_model=128, d_ff=256, moe_d_ff=256 if ARCHS[arch].num_experts else 0,
        num_layers=4, vocab_size=512, num_heads=8, num_kv_heads=4, head_dim=16)
    st = StepSettings(accum=1, remat="full")
    opt_cfg = adamw.AdamWConfig()
    step = make_train_step(cfg, opt_cfg, st)
    params = api.abstract_params(cfg)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = {"m": jax.tree.map(f32, params), "v": jax.tree.map(f32, params),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    shape = type("S", (), {"global_batch": 8, "seq_len": 128, "kind": "train"})()
    batch = api.batch_specs(cfg, shape)
    pspecs = sh.param_pspecs(cfg, mesh)
    jfn = jax.jit(step, in_shardings=(
        sh.named(mesh, pspecs),
        sh.named(mesh, {"m": pspecs, "v": pspecs,
                        "count": jax.sharding.PartitionSpec()}), None),
        donate_argnums=(0, 1))
    with activation_sharding(mesh):
        compiled = jfn.lower(params, opt, batch).compile()
    tr = trace_from_hlo(compiled.as_text(), spec, label=arch,
                        cost_analysis=compiled.cost_analysis(),
                        memory_analysis=compiled.memory_analysis())
    print(f"=== {arch} top contenders (Table II analogue) ===")
    print(top_contenders_table(tr))
    print(f"=== {arch} semantic (MPI-layer) rollup ===")
    print(semantic_table(tr))
    agg = tr.by_kind_and_link()
    total_b = sum(a["bytes"] for a in agg.values()) or 1.0
    top = max(agg.items(), key=lambda kv: kv[1]["bytes"])
    n_ev = sum(e.multiplicity for e in tr.events)
    a2a = sum(a["bytes"] for k, a in agg.items() if "all-to-all" in k)
    rows.append((f"commgraph/{arch}", float(n_ev),
                 f"top={top[0]}@{100*top[1]['bytes']/total_b:.0f}%|"
                 f"a2a_bytes%={100*a2a/total_b:.1f}|"
                 f"collGB={total_b/1e9:.3f}"))
print("JSON" + json.dumps(rows))
"""


def run():
    out = run_worker(WORKER, devices=8)
    print("\n".join(l for l in out.splitlines() if not l.startswith("JSON")))
    for line in out.splitlines():
        if line.startswith("JSON"):
            return [tuple(r) for r in json.loads(line[4:])]
    raise RuntimeError("no JSON output from worker")
