"""Atomic file persistence — write a same-directory temp file, then
`os.replace` it into place.

Every on-disk artifact this package produces (session saves, report
JSON/HTML, bench payloads, the watch daemon's rolling outputs) may be
read concurrently: the watch daemon re-emits them every poll while CI
artifact collection or a browser reload reads them.  A plain
`open(path, "w")` exposes truncated intermediate states to those
readers; renaming a fully-written sibling is atomic on POSIX, so a
reader sees either the old artifact or the new one — never a torn file.
"""
from __future__ import annotations

import contextlib
import os
import tempfile


def _fsync_dir(dirpath: str) -> None:
    """fsync a directory so a just-renamed entry survives power loss.

    `os.replace` makes the rename atomic for concurrent *readers*, but
    the new directory entry itself lives in the page cache until the
    directory inode is flushed — a crash between the rename and that
    flush can resurrect the old file (or neither).  Checkpoint/resume
    correctness (the watch daemon) needs the rename to be durable, not
    just atomic.  Filesystems that cannot fsync a directory fd (or
    platforms without O_DIRECTORY) are tolerated silently.
    """
    flags = os.O_RDONLY | getattr(os, "O_DIRECTORY", 0)
    try:
        fd = os.open(dirpath, flags)
    except OSError:
        return
    try:
        with contextlib.suppress(OSError):
            os.fsync(fd)
    finally:
        os.close(fd)


@contextlib.contextmanager
def atomic_open(path: str, mode: str = "w"):
    """`open(path, mode)` with atomic-replace semantics.

    Yields a file object over a temp file created in `path`'s directory
    (same filesystem, so the final rename cannot cross a mount).  On
    clean exit the temp file is flushed, fsync'd, renamed over `path`,
    and the parent directory is fsync'd (the rename is durable, not
    just atomic); on any error it is removed and `path` is left
    untouched.  `mode` must be a write mode ("w" or "wb").
    """
    if mode not in ("w", "wb"):
        raise ValueError(f"atomic_open requires a write mode, got {mode!r}")
    target = os.path.abspath(path)
    parent = os.path.dirname(target)
    fd, tmp = tempfile.mkstemp(dir=parent,
                               prefix=os.path.basename(target) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, mode) as f:
            yield f
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, target)
        _fsync_dir(parent)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise
