"""Architecture registry: the 10 assigned configs + shape specs."""
from repro.configs.base import (
    SHAPES,
    SHAPE_ORDER,
    ModelConfig,
    ShapeSpec,
    shape_applicable,
    smoke_config,
)

from repro.configs.whisper_tiny import CONFIG as _whisper_tiny
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba_7b
from repro.configs.mixtral_8x22b import CONFIG as _mixtral_8x22b
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3_moe
from repro.configs.chatglm3_6b import CONFIG as _chatglm3_6b
from repro.configs.llama3_405b import CONFIG as _llama3_405b
from repro.configs.gemma3_4b import CONFIG as _gemma3_4b
from repro.configs.h2o_danube3_4b import CONFIG as _h2o_danube3_4b
from repro.configs.hymba_1_5b import CONFIG as _hymba_1_5b
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2_vl_2b

ARCHS = {
    cfg.name: cfg
    for cfg in (
        _whisper_tiny,
        _falcon_mamba_7b,
        _mixtral_8x22b,
        _qwen3_moe,
        _chatglm3_6b,
        _llama3_405b,
        _gemma3_4b,
        _h2o_danube3_4b,
        _hymba_1_5b,
        _qwen2_vl_2b,
    )
}

ARCH_ORDER = tuple(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ARCHS",
    "ARCH_ORDER",
    "SHAPES",
    "SHAPE_ORDER",
    "ModelConfig",
    "ShapeSpec",
    "get_config",
    "shape_applicable",
    "smoke_config",
]
