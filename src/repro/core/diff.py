"""Trace diffing — the before/after workflow of the paper's case studies.

ucTrace's users compare runs (eager vs rndv configs, NUMA-aware vs not,
OMPI vs MPICH).  `diff_traces` aligns two traces by (kind, link class,
semantic) and reports byte/count/time deltas, new/vanished traffic classes,
and a verdict line per class — so "what did my change do to communication?"
is one function call on two compiled artifacts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.core.events import Trace


@dataclass
class DiffRow:
    key: str
    bytes_a: float
    bytes_b: float
    count_a: float
    count_b: float
    time_a: float
    time_b: float

    @property
    def bytes_ratio(self) -> float:
        if self.bytes_a == 0:
            return float("inf") if self.bytes_b else 1.0
        return self.bytes_b / self.bytes_a

    def verdict(self, threshold: float = 0.1) -> str:
        r = self.bytes_ratio
        if self.bytes_a == 0 and self.bytes_b > 0:
            return "NEW"
        if self.bytes_b == 0 and self.bytes_a > 0:
            return "GONE"
        if r > 1 + threshold:
            return f"GREW {r:.2f}x"
        if r < 1 - threshold:
            return f"SHRANK {1/r:.2f}x"
        return "~same"


def _agg(trace: Trace, by: str) -> Dict[str, Dict[str, float]]:
    if by == "kind_link":
        return trace.by_kind_and_link()
    if by == "semantic":
        return trace.by_semantic()
    return trace.by(lambda e: f"{e.semantic}|{e.kind}|{e.link_class}")


def diff_traces(a: Trace, b: Trace, by: str = "kind_link") -> List[DiffRow]:
    agg_a = _agg(a, by)
    agg_b = _agg(b, by)
    rows = []
    for key in sorted(set(agg_a) | set(agg_b)):
        ra = agg_a.get(key, {"bytes": 0, "count": 0, "time_s": 0})
        rb = agg_b.get(key, {"bytes": 0, "count": 0, "time_s": 0})
        rows.append(DiffRow(key, ra["bytes"], rb["bytes"], ra["count"],
                            rb["count"], ra["time_s"], rb["time_s"]))
    rows.sort(key=lambda r: -(abs(r.bytes_b - r.bytes_a)))
    return rows


def render_diff(a: Trace, b: Trace, by: str = "kind_link") -> str:
    rows = diff_traces(a, b, by)
    lines = [f"trace diff: '{a.label}' -> '{b.label}'  (by {by})",
             f"{'key':42s} {'GB a':>9s} {'GB b':>9s} {'cnt a':>7s} "
             f"{'cnt b':>7s} {'ms a':>8s} {'ms b':>8s}  verdict"]
    for r in rows:
        lines.append(
            f"{r.key:42s} {r.bytes_a/1e9:9.3f} {r.bytes_b/1e9:9.3f} "
            f"{int(r.count_a):7d} {int(r.count_b):7d} "
            f"{r.time_a*1e3:8.2f} {r.time_b*1e3:8.2f}  {r.verdict()}")
    ta, tb = a.total_est_time_s(), b.total_est_time_s()
    lines.append(f"{'TOTAL modeled collective time':42s} "
                 f"{'':9s} {'':9s} {'':7s} {'':7s} "
                 f"{ta*1e3:8.2f} {tb*1e3:8.2f}  "
                 f"{'%.2fx' % (tb/ta) if ta else 'n/a'}")
    return "\n".join(lines)
