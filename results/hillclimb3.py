import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Round 3: chatglm is now collective-bound (FSDP weight-gather x accum);
# SP sharding freed 4 GB of checkpoint memory -> spend it on fewer
# microbatches (prediction: collective term ~ accum, memory +saves).
import json
from hillclimb2 import run_variant
from hillclimb import attn_kernel_bytes

HERE = os.path.dirname(os.path.abspath(__file__))
rows = []
for name, accum in (("H16_sp+flash+accum2", 2), ("H17_sp+flash+accum1", 1)):
    rows.append(run_variant("chatglm3-6b", "train_4k", name, {},
                            {"seq_shard": True, "accum": accum},
                            (r"/attn", attn_kernel_bytes), "train"))
# gemma3: second-worst dense mfu; apply the proven combo
rows.append(run_variant("gemma3-4b", "train_4k", "H18_flash+accum2", {},
                        {"accum": 2}, (r"/attn", attn_kernel_bytes), "train"))
with open(os.path.join(HERE, "hillclimb3.json"), "w") as f:
    json.dump(rows, f, indent=1)
print("wrote results/hillclimb3.json")
