"""Mesh/torus topology model: replica groups -> mesh axes -> link classes.

This is the `UCT transport` resolution layer: where ucTrace maps a UCT send
to (rc_mlx5 | cuda_ipc | sysv | gdr_copy) + a NIC, we map an HLO collective's
replica groups onto the device mesh and classify which interconnect the
traffic rides: intra-pod ICI torus axes vs the inter-pod DCI.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Hardware:
    """TPU v5e-class constants (per chip / per link)."""

    name: str = "tpu-v5e"
    flops_bf16: float = 197e12          # peak bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # HBM bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per ICI link (per direction)
    dci_bw: float = 25e9                # bytes/s per inter-pod link
    ici_latency_s: float = 1e-6         # per-hop collective latency
    dci_latency_s: float = 10e-6
    hbm_per_chip: float = 16e9          # v5e HBM capacity
    vmem_per_core: float = 128 * 2**20  # VMEM bytes
    # eager/rendezvous analogue: below this payload a transfer is
    # latency-dominated ("eager"), above it bandwidth-dominated ("rndv").
    rndv_threshold: int = 1 << 16


V5E = Hardware()


@dataclass(frozen=True)
class MeshSpec:
    """Logical device mesh + interconnect class per axis."""

    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    # axis name -> "ici" | "dci"
    axis_kind: Dict[str, str] = field(default_factory=dict)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes)
        if not self.axis_kind:
            object.__setattr__(
                self, "axis_kind",
                {a: ("dci" if a == "pod" else "ici") for a in self.axes})

    @property
    def num_devices(self) -> int:
        return int(np.prod(self.shape))

    def coords(self, device_id: int) -> Tuple[int, ...]:
        return tuple(int(c) for c in np.unravel_index(device_id, self.shape))

    def coords_array(self, device_ids: Sequence[int]) -> np.ndarray:
        return np.stack(np.unravel_index(np.asarray(device_ids), self.shape),
                        axis=-1)

    @classmethod
    def single_pod(cls) -> "MeshSpec":
        return cls((16, 16), ("data", "model"))

    @classmethod
    def multi_pod(cls) -> "MeshSpec":
        return cls((2, 16, 16), ("pod", "data", "model"))


def varying_axes(mesh: MeshSpec, group: Sequence[int]) -> Tuple[str, ...]:
    """Which mesh axes vary across the devices of one replica group."""
    if len(group) <= 1:
        return ()
    coords = mesh.coords_array(group)
    out = []
    for i, name in enumerate(mesh.axes):
        if len(np.unique(coords[:, i])) > 1:
            out.append(name)
    return tuple(out)


def link_class(mesh: MeshSpec, axes: Tuple[str, ...]) -> str:
    """Transport-class label for a collective spanning `axes`."""
    if not axes:
        return "local"
    if len(axes) == 1:
        a = axes[0]
        return f"{mesh.axis_kind[a]}.{a}"
    kinds = {mesh.axis_kind[a] for a in axes}
    label = "+".join(axes)
    if kinds == {"ici"}:
        return f"ici.mixed({label})"
    if kinds == {"dci"}:
        return f"dci.mixed({label})"
    return f"xpod.mixed({label})"  # crosses both ICI and DCI


def slowest_link_bw(mesh: MeshSpec, axes: Tuple[str, ...], hw: Hardware) -> float:
    """Bottleneck link bandwidth for traffic spanning `axes`."""
    if not axes:
        return hw.hbm_bw
    bws = [hw.dci_bw if mesh.axis_kind[a] == "dci" else hw.ici_bw for a in axes]
    return min(bws)


def hop_latency(mesh: MeshSpec, axes: Tuple[str, ...], hw: Hardware) -> float:
    if not axes:
        return 0.0
    return max(hw.dci_latency_s if mesh.axis_kind[a] == "dci" else hw.ici_latency_s
               for a in axes)


@lru_cache(maxsize=4096)
def _resolve_iota_cached(num_groups: int, group_size: int,
                         reshape_dims: Tuple[int, ...],
                         transpose_perm: Optional[Tuple[int, ...]]
                         ) -> Tuple[Tuple[int, ...], ...]:
    n = int(np.prod(reshape_dims))
    ids = np.arange(n).reshape(reshape_dims)
    if transpose_perm is not None:
        ids = ids.transpose(transpose_perm)
    ids = ids.reshape(num_groups, group_size)
    return tuple(tuple(map(int, row)) for row in ids)


def resolve_iota_groups(num_groups: int, group_size: int,
                        reshape_dims: Sequence[int],
                        transpose_perm: Optional[Sequence[int]]) -> List[List[int]]:
    """Decode HLO iota replica groups `[G,S]<=[dims]T(perm)`.

    Memoized on the raw attribute tuple: unrolled loops stamp the same
    `replica_groups=[G,S]<=[dims]` attr onto thousands of ops, so the
    numpy decode runs once per unique attr; only the (cheap) list
    materialization happens per call, keeping results mutation-safe.

    Raises `ValueError` on a malformed attr (G*S != prod(dims), or a
    transpose perm that is not a permutation of the dims) instead of an
    opaque numpy reshape/transpose error — parser callers catch it and
    fall back to a full-range group.
    """
    dims = tuple(int(d) for d in reshape_dims)
    n = int(np.prod(dims)) if dims else 0
    if int(num_groups) * int(group_size) != n:
        raise ValueError(
            f"iota replica_groups [{num_groups},{group_size}]<={list(dims)}: "
            f"{num_groups}*{group_size} != prod(dims) = {n}")
    if transpose_perm is not None \
            and sorted(int(p) for p in transpose_perm) != list(range(len(dims))):
        raise ValueError(
            f"iota replica_groups transpose T({list(transpose_perm)}) is not "
            f"a permutation of {len(dims)} dims")
    rows = _resolve_iota_cached(
        int(num_groups), int(group_size), tuple(int(d) for d in reshape_dims),
        None if transpose_perm is None else tuple(int(p) for p in transpose_perm))
    return [list(r) for r in rows]


def comm_matrix(mesh: MeshSpec, events, resolution: str = "device") -> np.ndarray:
    """Device x device wire-byte matrix (ring-model neighbor traffic).

    The paper's Fig 3b analogue.  Ring collectives put traffic on ring
    neighbors within each replica group; permutes follow their explicit
    source->target pairs.

    `events` may be a `Trace`, a `TraceStore`, or a plain event iterable.
    The first two scatter a precomputed (src, dst, bytes) edge list with
    one `np.add.at` call instead of walking Python objects.
    """
    n = mesh.num_devices
    mat = np.zeros((n, n))
    store = getattr(events, "store", None)     # Trace -> its columnar store
    if store is None and hasattr(events, "ring_edges"):
        store = events                         # already a TraceStore
    if store is not None:
        src, dst, w = store.ring_edges()
        np.add.at(mat, (src, dst), w)
        return mat
    for e in events:
        mult = e.multiplicity
        if e.source_target_pairs:
            per = e.operand_bytes
            for s, t in e.source_target_pairs:
                mat[s, t] += per * mult
            continue
        for group in e.replica_groups:
            g = len(group)
            if g <= 1:
                continue
            per_link = e.wire_bytes_per_device * mult
            for i, d in enumerate(group):
                nxt = group[(i + 1) % g]
                mat[d, nxt] += per_link
    return mat


def reduce_matrix(mat: np.ndarray, mesh: MeshSpec, axis: str) -> np.ndarray:
    """Aggregate the device matrix to groups along one axis (viz)."""
    ai = mesh.axes.index(axis)
    k = mesh.shape[ai]
    n = mat.shape[0]
    labels = np.unravel_index(np.arange(n), mesh.shape)[ai]
    out = np.zeros((k, k))
    np.add.at(out, (labels[:, None], labels[None, :]), mat)
    return out
