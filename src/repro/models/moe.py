"""Mixture-of-Experts FFN with top-k routing.

Baseline dispatch is GShard/Switch-style dense one-hot einsums over token
*groups* (static shapes, GSPMD-friendly; capacity-factor drop policy).
Groups bound the S_g^2 dispatch-einsum cost.  Expert weights are sharded
on the expert dim over the `model` mesh axis when E divides it (qwen3),
else on the ffn dim (mixtral: 8 experts < 16-way axis => expert-TP).

An optimized sort-based / shard_map ragged dispatch lives in
`repro.distributed.moe_ep` (see EXPERIMENTS.md §Perf) — it removes the
dispatch-einsum FLOPs and turns the combine all-reduce into all-to-alls.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.autoshard import constrain
from repro.models.meta import ParamMeta


def moe_meta(cfg):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    # expert dim shards over `model` iff divisible (checked in sharding rules)
    return {
        "router": ParamMeta((d, e), ("embed", None), scale=0.02),
        "w_gate": ParamMeta((e, d, f), ("expert", "embed", "moe_mlp")),
        "w_up": ParamMeta((e, d, f), ("expert", "embed", "moe_mlp")),
        "w_down": ParamMeta((e, f, d), ("expert", "moe_mlp", "embed")),
    }


def capacity(cfg, group_tokens: int) -> int:
    c = math.ceil(cfg.top_k * group_tokens * cfg.capacity_factor / cfg.num_experts)
    return max(1, c)


def _group(x: jax.Array, group_size: int) -> Tuple[jax.Array, int]:
    """[B,S,D] -> [G, Sg, D]."""
    B, S, D = x.shape
    sg = min(group_size, S)
    while S % sg:
        sg //= 2
    return x.reshape(B * (S // sg), sg, D), sg


def router_dispatch(cfg, probs: jax.Array, cap: int):
    """GShard top-k dispatch. probs [G,Sg,E] fp32.

    Returns (dispatch [G,Sg,E,C] bool-ish, combine [G,Sg,E,C] fp32, aux_loss).
    """
    G, Sg, E = probs.shape
    gates, idx = jax.lax.top_k(probs, cfg.top_k)                 # [G,Sg,k]
    # renormalize chosen gates
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)           # [G,Sg,k,E]
    # priority order: choice rank first, then token order (GShard policy)
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, cfg.top_k * Sg, E)
    pos_flat = jnp.cumsum(flat, axis=1) - flat                   # position within expert
    pos = pos_flat.reshape(G, cfg.top_k, Sg, E).transpose(0, 2, 1, 3)  # [G,Sg,k,E]
    keep = (pos < cap) * onehot                                  # drop overflow
    pos = jnp.minimum(pos, cap - 1).astype(jnp.int32)

    slot = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = slot.sum(axis=2)                                  # [G,Sg,E,C]
    combine = (slot * gates[..., None, None]).sum(axis=2)        # [G,Sg,E,C]

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))                                 # mean router prob
    ce = onehot.sum(axis=2).mean(axis=(0, 1))                    # fraction routed
    aux = cfg.num_experts * jnp.sum(me * ce)
    return dispatch, combine, aux


def apply_moe(cfg, p, x: jax.Array, *, group_size: int = 0):
    """MoE FFN. x [B,S,D] -> ([B,S,D], aux_loss)."""
    if cfg.moe_dispatch == "sort":
        from repro.distributed.autoshard import current_mesh
        mesh = current_mesh()
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            if cfg.num_experts % sizes.get("model", 1) == 0:
                from repro.distributed.moe_ep import apply_moe_sort
                with jax.named_scope("moe"):
                    return apply_moe_sort(cfg, p, x, mesh)
        # no mesh context / indivisible experts: fall through to einsum
    with jax.named_scope("moe"):
        dt = x.dtype
        tdt = jnp.dtype(cfg.moe_table_dtype)
        B, S, D = x.shape
        xg, sg = _group(x, group_size or cfg.moe_group_size)     # [G,Sg,D]
        with jax.named_scope("router"):
            logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                                p["router"].astype(jnp.float32))
            probs = jax.nn.softmax(logits, axis=-1)
            cap = capacity(cfg, sg)
            dispatch, combine, aux = router_dispatch(cfg, probs, cap)
            dispatch = dispatch.astype(tdt)
            combine = combine.astype(tdt)
        with jax.named_scope("dispatch"):
            x_e = jnp.einsum("gsec,gsd->gecd", dispatch.astype(dt), xg)
            # expert dim onto the model axis (EP); falls back to replicated
            # when E doesn't divide it (mixtral: experts TP'd on moe_mlp).
            x_e = constrain(x_e, ("batch", "model", None, None))
        with jax.named_scope("experts"):
            g = jnp.einsum("gecd,edf->gecf", x_e, p["w_gate"].astype(dt))
            u = jnp.einsum("gecd,edf->gecf", x_e, p["w_up"].astype(dt))
            h = jax.nn.silu(g) * u
            y_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
        with jax.named_scope("combine"):
            y = jnp.einsum("gsec,gecd->gsd", combine.astype(dt), y_e)
            y = constrain(y, ("batch", None, None))
        return y.reshape(B, S, D), aux
