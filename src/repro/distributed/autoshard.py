"""Activation sharding constraints with graceful degradation.

Model code calls `constrain(x, roles)` with *roles* ("batch" / "model" /
"seq"), not axis names.  The step driver wraps tracing in
`activation_sharding(mesh)`; outside that context (unit tests on one CPU
device) constraints are no-ops, so the same model code runs everywhere.

Divisibility is checked per dim, so e.g. batch=1 at 500k decode or
whisper's 51865 vocab silently degrade to replicated instead of erroring —
the tracer then *prices* the resulting traffic, which is the tool's job.
"""
from __future__ import annotations

from contextlib import contextmanager
from contextvars import ContextVar
from typing import Dict, Optional, Sequence

import numpy as np

_AXES: ContextVar[Optional[dict]] = ContextVar("repro_axes", default=None)

ROLE_CANDIDATES = {
    "batch": (("pod", "data"), ("data",)),
    "model": (("model",),),
    "seq": (("model",), ("data",)),
    "seq_mp": (("data", "model"), ("model",), ("data",)),
}


@contextmanager
def activation_sharding(mesh, *, seq_shard: bool = False):
    """Enable activation constraints for code traced inside this context.

    `seq_shard=True` turns on Megatron-SP-style sequence sharding of the
    residual stream over the `model` axis: layer-boundary activation
    checkpoints shrink by the TP degree (the all-gather/reduce-scatter
    around each layer is the SP exchange, priced by the tracer).
    """
    sizes = dict(zip(mesh.axis_names, np.shape(mesh.devices)))
    token = _AXES.set({"sizes": sizes, "seq_shard": seq_shard, "mesh": mesh})
    try:
        with mesh:
            yield
    finally:
        _AXES.reset(token)


def current_mesh():
    ctx = _AXES.get()
    return ctx.get("mesh") if ctx else None


def current_axes() -> Optional[Dict[str, int]]:
    ctx = _AXES.get()
    return ctx["sizes"] if ctx else None


def _pick(dim: int, role: Optional[str], sizes: Dict[str, int], used: set):
    if role is None:
        return None
    for cand in ROLE_CANDIDATES.get(role, ()):
        if any(a not in sizes for a in cand) or (used & set(cand)):
            continue
        prod = int(np.prod([sizes[a] for a in cand]))
        if dim % prod == 0 and dim >= prod:
            return cand
    return None


def constrain(x, roles: Sequence[Optional[str]]):
    """Apply a with_sharding_constraint described by per-dim roles."""
    ctx = _AXES.get()
    if not ctx:
        return x
    sizes = ctx["sizes"]
    import jax
    from jax.sharding import PartitionSpec as P

    used: set = set()
    parts = []
    for dim, role in zip(x.shape, roles):
        cand = _pick(dim, role, sizes, used)
        if cand:
            used |= set(cand)
            parts.append(cand[0] if len(cand) == 1 else cand)
        else:
            parts.append(None)
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(x, P(*parts))


def constrain_residual(x):
    """[B, S, D] activations (+ optional SP sequence sharding)."""
    ctx = _AXES.get()
    seq_role = "seq" if (ctx and ctx.get("seq_shard")) else None
    return constrain(x, ("batch", seq_role, None))


def constrain_logits(x):
    """[B, S, V] logits (vocab TP when divisible)."""
    return constrain(x, ("batch", None, "model"))
