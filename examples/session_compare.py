"""Multi-run session workflow: compare one workload across configurations.

    PYTHONPATH=src python examples/session_compare.py

The paper's headline experiment shape — the same step traced under several
mesh layouts (the MPI-library / NUMA-binding analogue) — collected into a
named `TraceSession`, persisted as one artifact, reloaded, and rendered as
an n-way comparison table.  Compiles a real train step per mesh layout;
pass --synthetic to use the seeded synthetic workload instead (no jax).
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

from repro.core import MeshSpec
from repro.core.session import TraceSession


def real_traces():
    import jax

    from repro.configs import ARCHS, smoke_config
    from repro.core import trace_from_hlo
    from repro.distributed import sharding as sh
    from repro.distributed.autoshard import activation_sharding
    from repro.launch.presets import StepSettings
    from repro.launch.steps import make_train_step
    from repro.models import api
    from repro.optim import adamw
    import jax.numpy as jnp

    traces = []
    for label, shape, axes in (
            ("dp8", (8, 1), ("data", "model")),
            ("dp4xtp2", (4, 2), ("data", "model")),
            ("dp2xtp4", (2, 4), ("data", "model"))):
        mesh = jax.make_mesh(shape, axes)
        spec = MeshSpec(shape, axes)
        cfg = smoke_config(ARCHS["chatglm3-6b"]).replace(
            d_model=128, d_ff=256, num_layers=4, vocab_size=512,
            num_heads=8, num_kv_heads=4, head_dim=16)
        step = make_train_step(cfg, adamw.AdamWConfig(),
                               StepSettings(accum=1, remat="full"))
        params = api.abstract_params(cfg)
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        opt = {"m": jax.tree.map(f32, params), "v": jax.tree.map(f32, params),
               "count": jax.ShapeDtypeStruct((), jnp.int32)}
        bshape = type("S", (), {"global_batch": 8, "seq_len": 128,
                                "kind": "train"})()
        batch = api.batch_specs(cfg, bshape)
        pspecs = sh.param_pspecs(cfg, mesh)
        jfn = jax.jit(step, in_shardings=(
            sh.named(mesh, pspecs),
            sh.named(mesh, {"m": pspecs, "v": pspecs,
                            "count": jax.sharding.PartitionSpec()}), None),
            donate_argnums=(0, 1))
        with activation_sharding(mesh):
            compiled = jfn.lower(params, opt, batch).compile()
        traces.append(trace_from_hlo(
            compiled.as_text(), spec, label=label,
            cost_analysis=compiled.cost_analysis(),
            memory_analysis=compiled.memory_analysis()))
    return traces


def synthetic_traces():
    from repro.core.synth import synthetic_trace
    return [
        synthetic_trace("dp8", MeshSpec((8, 1), ("data", "model")),
                        n_sites=2000, seed=0),
        synthetic_trace("dp4xtp2", MeshSpec((4, 2), ("data", "model")),
                        n_sites=2000, seed=0),
        synthetic_trace("dp2xtp4", MeshSpec((2, 4), ("data", "model")),
                        n_sites=2000, seed=0),
    ]


def main():
    synthetic = "--synthetic" in sys.argv
    sess = TraceSession("mesh-layout-sweep")
    for tr in (synthetic_traces() if synthetic else real_traces()):
        sess.add(tr)
    os.makedirs("results", exist_ok=True)
    path = sess.save("results/mesh_layout_sweep.npz")
    sess = TraceSession.load(path)
    print(f"saved + reloaded '{sess.name}' "
          f"({os.path.getsize(path)//1024} KB): {sess.labels()}\n")
    print(sess.table())
    print()
    print(sess.table(by="semantic", metric="time"))
    print()
    print(sess.diff(sess.labels()[0], sess.labels()[-1]))


if __name__ == "__main__":
    main()
