"""Shared model layers: norms, RoPE variants, MLPs, embeddings.

Conventions:
  * residual stream is `compute_dtype` (bf16); norms and softmax in fp32.
  * all learned matrices are declared via `ParamMeta` with logical axes —
    sharding is decided centrally in `repro.distributed.sharding`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.meta import ParamMeta


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def norm_meta(cfg, dim: Optional[int] = None):
    d = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {
            "scale": ParamMeta((d,), (None,), init="ones"),
            "bias": ParamMeta((d,), (None,), init="zeros"),
        }
    return {"scale": ParamMeta((d,), (None,), init="ones")}


def apply_norm(cfg, p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Norm with fp32 *accumulation* but bf16 elementwise math.

    Deliberately avoids `x.astype(f32)` on the full tensor: that convert is
    the first op of every layer body, and XLA hoists it out of the
    remat/backward loop — converting the whole [L, B, S, D] saved-residual
    stack to fp32 in HBM (33.8 GB/device for llama3-405b, measured).
    Reductions accumulate in fp32 via dtype=..., which keeps the statistics
    accurate without materializing an fp32 copy of x.
    """
    dtype = x.dtype
    if cfg.norm == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                       dtype=jnp.float32) - jnp.square(mu)
        inv = jax.lax.rsqrt(var + eps)
        y = (x - mu.astype(dtype)) * inv.astype(dtype)
        y = y * p["scale"].astype(dtype) + p["bias"].astype(dtype)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                      dtype=jnp.float32)
        y = x * jax.lax.rsqrt(ms + eps).astype(dtype) * p["scale"].astype(dtype)
    return y.astype(dtype)


def rms_norm_head(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head q/k RMSNorm (qwen3)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings (standard / partial / m-rope)
# --------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, n_freq: int, theta: float) -> jax.Array:
    """positions [..., S] -> angles [..., S, n_freq] (fp32)."""
    freq = jnp.arange(n_freq, dtype=jnp.float32)
    inv = theta ** (-freq / n_freq)
    return positions.astype(jnp.float32)[..., None] * inv


def _rotate_half(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(cfg, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Apply rotary embedding.

    x:         [B, S, H, Dh]
    positions: [B, S] int32, or [3, B, S] for m-rope.
    """
    if cfg.rope in ("none", "learned"):
        return x
    dh = x.shape[-1]
    if cfg.rope == "mrope":
        n_freq = dh // 2
        sections = cfg.mrope_sections
        assert sum(sections) == n_freq, (sections, n_freq)
        angle_parts = []
        start = 0
        for axis, sec in enumerate(sections):
            freq = jnp.arange(start, start + sec, dtype=jnp.float32)
            inv = cfg.rope_theta ** (-2.0 * freq / dh)
            ang = positions[axis].astype(jnp.float32)[..., None] * inv  # [B,S,sec]
            angle_parts.append(ang)
            start += sec
        angles = jnp.concatenate(angle_parts, axis=-1)  # [B, S, n_freq]
    else:
        rot = int(dh * cfg.rope_fraction)
        rot -= rot % 2
        angles = _rope_angles(positions, rot // 2, cfg.rope_theta)

    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)  # [B,S,1,n_freq]
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    rot = 2 * angles.shape[-1]
    if rot == dh:
        return _rotate_half(x, cos, sin)
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    return jnp.concatenate([_rotate_half(x_rot, cos, sin), x_pass], axis=-1)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def mlp_meta(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.glu:
        return {
            "w_gate": ParamMeta((d, f), ("embed", "mlp")),
            "w_up": ParamMeta((d, f), ("embed", "mlp")),
            "w_down": ParamMeta((f, d), ("mlp", "embed")),
        }
    return {
        "w_up": ParamMeta((d, f), ("embed", "mlp")),
        "w_down": ParamMeta((f, d), ("mlp", "embed")),
    }


def _act(cfg, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def apply_mlp(cfg, p, x: jax.Array) -> jax.Array:
    dt = x.dtype
    with jax.named_scope("mlp"):
        if cfg.glu:
            g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
            u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
            h = _act(cfg, g) * u
        else:
            h = _act(cfg, jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt)))
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))


# --------------------------------------------------------------------------
# embeddings / logits
# --------------------------------------------------------------------------

def embed_meta(cfg):
    # tied tables double as the LM head: scale down so initial logits are O(1)
    scale = cfg.d_model ** -0.5 if cfg.tie_embeddings else 1.0
    m = {"in_table": ParamMeta((cfg.vocab_size, cfg.d_model),
                               ("in_vocab", "embed_tp"), scale=scale)}
    if not cfg.tie_embeddings:
        m["out_head"] = ParamMeta((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.rope == "learned":
        m["pos_table"] = ParamMeta((cfg.source_len + cfg.max_positions, cfg.d_model),
                                   (None, "embed_tp"), scale=0.02)
    return m


def _embed_onehot(table: jax.Array, tokens: jax.Array, out_dtype,
                  chunk: int = 256) -> jax.Array:
    """Chunked one-hot-matmul embedding lookup.

    XLA's SPMD partitioner mis-partitions gathers whose indices arrive
    scan-sliced inside a while loop while the operand is sharded (invalid
    dynamic-slice after spmd-partitioning); einsum partitioning is robust
    everywhere.  FLOP cost is 2·V·D per token — bounded by one extra LM-head
    pass (<=5% of a training step for the assigned archs); the one-hot is
    chunked over sequence and rematerialized in backward.
    """
    from repro.models.attention import largest_divisor_leq
    B, S = tokens.shape
    V, D = table.shape
    chunk = largest_divisor_leq(S, chunk)
    n = S // chunk
    tk = tokens.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(_, t_c):
        oh = jax.nn.one_hot(t_c, V, dtype=out_dtype)
        return None, jnp.einsum("bcv,vd->bcd", oh, table.astype(out_dtype))

    _, xs = jax.lax.scan(body, None, tk)                 # [n, B, chunk, D]
    return xs.swapaxes(0, 1).reshape(B, S, D)


def embed_tokens(cfg, p, tokens: jax.Array, positions=None,
                 impl: str = "gather") -> jax.Array:
    from repro.distributed.autoshard import constrain, constrain_residual
    with jax.named_scope("embed"):
        cdt = jnp.dtype(cfg.compute_dtype)
        if impl == "onehot":
            x = _embed_onehot(p["in_table"], tokens, cdt)
        else:
            tokens = constrain(tokens, (None,) * tokens.ndim)
            x = jnp.take(p["in_table"], tokens, axis=0).astype(cdt)
        if cfg.rope == "learned" and positions is not None:
            positions = constrain(positions, (None,) * positions.ndim)
            pe = jnp.take(p["pos_table"], positions, axis=0)
            x = x + pe.astype(x.dtype)
        return constrain_residual(x)


def logits_head(cfg, p, x: jax.Array) -> jax.Array:
    from repro.distributed.autoshard import constrain_logits
    with jax.named_scope("logits"):
        table = p["in_table"].T if cfg.tie_embeddings else p["out_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, table.astype(x.dtype))
        return constrain_logits(logits)
