"""Batched serving example: continuous-batching-lite over a small LM.

    PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b --requests 6
"""
import argparse

import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=3)
    args = ap.parse_args()

    cfg = smoke_config(ARCHS[args.arch])
    params = api.init_params(cfg, 0)
    server = BatchedServer(cfg, params, max_batch=args.max_batch,
                           cache_len=args.prompt_len + args.max_new + 4)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len,
                                    dtype=np.int32), args.max_new)
            for i in range(args.requests)]
    queue = list(reqs)
    rounds = 0
    while queue or any(server.slots):
        for slot in range(server.max_batch):
            if server.slots[slot] is None and queue:
                r = queue.pop(0)
                print(f"[serve] admitting request {r.rid} into slot {slot}")
                server.prefill_into_slot(slot, r)
        server.decode_round()
        rounds += 1
    print(f"[serve] done in {rounds} decode rounds")
    for r in reqs:
        print(f"  req {r.rid}: prompt {list(r.prompt[:4])}... "
              f"-> generated {r.generated}")


if __name__ == "__main__":
    main()
