"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; `dryrun.py` sets `--xla_force_host_platform_device_count=512`
before any jax import, everything else sees the real device count.
"""
from __future__ import annotations

import jax

from repro.core.topology import MeshSpec


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_spec(*, multi_pod: bool = False) -> MeshSpec:
    return MeshSpec.multi_pod() if multi_pod else MeshSpec.single_pod()


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over forced host devices (tests / examples)."""
    return jax.make_mesh(shape, axes), MeshSpec(tuple(shape), tuple(axes))
