"""Sharded checkpointing: atomic, resumable, elastic.

Layout (one directory per step):

    ckpt_dir/
      step_000120/
        MANIFEST.json        # pytree structure, shapes, dtypes, mesh info
        arr_<idx>.npy        # one file per leaf (gathered)
      LATEST                 # atomically-updated pointer file

Design notes for real fleets (documented trade-offs):
  * Leaves are gathered to host then written — at 1000+ nodes this becomes
    per-host shard files keyed by (leaf, shard_index) via tensorstore; the
    manifest schema already records per-leaf sharding to support that.
  * Writes go to `step_xxx.tmp/` then `os.rename` — a crash mid-write can
    never corrupt LATEST (restart-safety test covers this).
  * **Elastic restore**: arrays are re-device_put against the *current*
    mesh's shardings, so a checkpoint from mesh A restores onto mesh B
    with a different device count (elasticity test covers this).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None) -> str:
    """Atomically write a checkpoint. Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        dtype_str = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in dtype_str:
            # non-native dtypes (bfloat16): persist the raw bytes; the
            # manifest dtype string restores the view on load.
            np.save(os.path.join(tmp, fname),
                    arr.view(np.uint8).reshape(arr.shape + (arr.itemsize,)))
        else:
            np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": dtype_str})
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST pointer
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of `tree_like`.

    `shardings`: optional pytree of NamedShardings (same structure) — the
    elastic-resharding path: arrays are device_put against the *current*
    mesh regardless of the mesh they were saved from.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)

    arrays = {}
    for leaf in manifest["leaves"]:
        arr = np.load(os.path.join(d, leaf["file"]))
        if "bfloat16" in leaf["dtype"] and arr.dtype == np.uint8:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16).reshape(tuple(leaf["shape"]))
        arrays[leaf["key"]] = arr

    flat_like = _flatten_with_paths(tree_like)
    flat_sh = _flatten_with_paths(shardings) if shardings is not None else None
    leaves_out = []
    for i, (key, like) in enumerate(flat_like):
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        want_shape = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != {want_shape}")
        if flat_sh is not None:
            leaves_out.append(jax.device_put(arr, flat_sh[i][1]))
        else:
            leaves_out.append(jax.numpy.asarray(
                arr, dtype=getattr(like, "dtype", arr.dtype)))
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves_out), manifest["extra"]


class AsyncCheckpointer:
    """Double-buffered async writes: device arrays are snapshotted to host
    synchronously (cheap), serialization runs on a worker thread so the
    train loop never blocks on disk.  `wait()` before exit / next save."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        import threading
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread = None
        self._error = None
        self._threading = threading

    def save(self, step: int, tree, extra=None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra=extra)
                prune_old(self.ckpt_dir, keep=self.keep)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = self._threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(n for n in os.listdir(ckpt_dir)
                   if n.startswith("step_") and not n.endswith(".tmp"))
    for name in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
