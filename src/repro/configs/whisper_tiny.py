"""whisper-tiny — enc-dec audio transformer backbone. [arXiv:2212.04356]

Conv frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings (batch, 1500, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,              # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    glu=False,
    rope="learned",            # whisper uses learned absolute positions
    source_len=1500,           # 30 s of audio at 50 frames/s
    tie_embeddings=True,       # whisper ties decoder embed and output head
    notes="conv frontend stubbed: precomputed frame embeddings as input; "
          "position table sized for the assigned decode_32k shape "
          "(real whisper caps targets at 448)",
)
