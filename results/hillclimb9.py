import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Round 9: H30 — retest SP residual sharding at the multi-pod accum-8
# config (it was refuted single-pod at accum 16; the saves term now
# dominates memory again, and the exchange cost structure differs at dp=32)
import dataclasses, json
from hillclimb7 import run, rows, st0, HERE

run("H30_mp_fsdp_flash_acc8_sp", True,
    dataclasses.replace(st0, accum=8, seq_shard=True), kernel_dp=32)
with open(os.path.join(HERE, "hillclimb9.json"), "w") as f:
    json.dump(rows, f, indent=1)
