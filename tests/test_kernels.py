"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention as fa_kernel
from repro.kernels.mamba_scan import mamba_scan as ms_kernel
from repro.kernels.ref import flash_attention_ref, mamba_scan_ref

RNG = np.random.default_rng(0)


def _qkv(B, H, K, S, D, dtype):
    q = jnp.asarray(RNG.standard_normal((B, H, S, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, K, S, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, K, S, D)), dtype)
    return q, k, v


FLASH_CASES = [
    # B, H, K, S, D, causal, window, dtype, tol
    (1, 2, 2, 256, 128, True, 0, jnp.float32, 2e-5),
    (2, 4, 2, 256, 128, True, 64, jnp.float32, 2e-5),
    (1, 2, 1, 512, 128, False, 0, jnp.float32, 2e-5),
    (1, 6, 3, 256, 256, True, 0, jnp.float32, 2e-5),
    (1, 4, 4, 128, 128, True, 0, jnp.bfloat16, 3e-2),
    (1, 2, 2, 384, 128, True, 128, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("B,H,K,S,D,causal,window,dtype,tol", FLASH_CASES)
def test_flash_attention_sweep(B, H, K, S, D, causal, window, dtype, tol):
    q, k, v = _qkv(B, H, K, S, D, dtype)
    out = fa_kernel(q, k, v, causal=causal, window=window, interpret=True,
                    bq=128, bk=128)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, err


def test_flash_attention_q_offset_matches_decode_semantics():
    """q_offset shifts the causal diagonal (decode against a prefix cache)."""
    B, H, S, D = 1, 2, 256, 128
    q, k, v = _qkv(B, H, H, S, D, jnp.float32)
    out = fa_kernel(q[:, :, :128], k, v, causal=True, q_offset=128,
                    interpret=True)
    ref = flash_attention_ref(q[:, :, :128], k, v, causal=True, q_offset=128)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_flash_ops_wrapper_pads_head_dim():
    """h2o-danube head_dim=120 -> padded to 128 inside the wrapper."""
    B, S, H, K, Dh = 1, 128, 4, 2, 120
    q = jnp.asarray(RNG.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, K, Dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, K, Dh)), jnp.float32)
    out = ops.flash_attention(None, q, k, v, causal=True, interpret=True)
    from repro.models.attention import attend_naive
    from repro.configs import get_config
    cfg = get_config("h2o-danube-3-4b")
    ref = attend_naive(cfg, q, k, v, causal=True, window=0)
    assert out.shape == (B, S, H, Dh)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


MAMBA_CASES = [
    (1, 128, 64, 8, 64, 64),
    (2, 256, 128, 16, 64, 64),
    (1, 512, 256, 16, 128, 128),
    (1, 96, 64, 4, 32, 64),      # non-pow2 seq -> divisor chunking
]


@pytest.mark.parametrize("B,S,Di,N,chunk,di_block", MAMBA_CASES)
def test_mamba_scan_sweep(B, S, Di, N, chunk, di_block):
    a = jnp.asarray(np.exp(-np.abs(RNG.standard_normal((B, S, Di, N)))),
                    jnp.float32)
    bx = jnp.asarray(RNG.standard_normal((B, S, Di, N)) * 0.1, jnp.float32)
    c = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    y = ms_kernel(a, bx, c, chunk=chunk, di_block=di_block, interpret=True)
    ref = mamba_scan_ref(a, bx, c)
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-4


@given(S=st.sampled_from([64, 128, 192, 256]),
       Di=st.sampled_from([32, 64, 128]),
       N=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_mamba_scan_property(S, Di, N, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(np.exp(-np.abs(rng.standard_normal((1, S, Di, N)))),
                    jnp.float32)
    bx = jnp.asarray(rng.standard_normal((1, S, Di, N)) * 0.1, jnp.float32)
    c = jnp.asarray(rng.standard_normal((1, S, N)), jnp.float32)
    y = ms_kernel(a, bx, c, chunk=64, di_block=32, interpret=True)
    ref = mamba_scan_ref(a, bx, c)
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-4


def test_model_blocked_vs_naive_attention():
    """The XLA online-softmax path agrees with the naive path."""
    from repro.configs import ARCHS, smoke_config
    from repro.models.attention import attend_blocked, attend_naive
    cfg = smoke_config(ARCHS["chatglm3-6b"])
    B, S, H, K, Dh = 2, 128, 4, 2, 16
    q = jnp.asarray(RNG.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, K, Dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, K, Dh)), jnp.float32)
    for window in (0, 32):
        a = attend_naive(cfg, q, k, v, causal=True, window=window)
        b = attend_blocked(cfg, q, k, v, causal=True, window=window,
                           kv_chunk=32)
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5
