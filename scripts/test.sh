#!/usr/bin/env sh
# Tier-1 test gate: run from the repo root.  Extra args pass through to
# pytest (e.g. `scripts/test.sh tests/test_session.py -k roundtrip`).
#
#   TIER=smoke scripts/test.sh    # reproduce the CI jobs in one command:
#                                 # analysis-layer tests, the ingest/render/
#                                 # shard/append/persist smoke benches, a
#                                 # `session watch --once` smoke, the chaos
#                                 # gate (corrupt-dump matrix), the warehouse
#                                 # smoke (200-host fleet ingest, mmap query,
#                                 # fleet diff, merge/mmap benches), and the
#                                 # bench-trajectory gate (no jax compilation)
set -u
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export PYTHONPATH

if [ "${TIER:-full}" = "smoke" ]; then
    python -m pytest -x -q \
        tests/test_ingest.py tests/test_render.py tests/test_report.py \
        tests/test_session.py tests/test_detect.py tests/test_tracer.py \
        tests/test_shard.py tests/test_commcheck.py tests/test_append.py \
        tests/test_watch.py tests/test_chaos.py tests/test_whatif.py \
        tests/test_cli_help.py tests/test_warehouse.py \
        "$@"
    rc=$?
    if [ "$rc" -ne 0 ]; then
        exit "$rc"
    fi
    python -m repro.core.session lint examples/hlo/*.txt \
        --mesh 2,4 --axes data,model --fail-on critical || exit $?
    # what-if smoke: hardwareless config sweep over an example dump
    python -m repro.core.session whatif examples/hlo/mlp_sweep_a.txt \
        --mesh 2,4 --axes data,model || exit $?
    # docs gate: markdown links resolve, USAGE.md examples execute
    python scripts/docs_check.py || exit $?
    # live-profiling smoke: drain a synthetic dump dir in --once mode
    rm -rf results/watch_smoke
    python -c "import sys; sys.path.insert(0, 'src'); \
from repro.core.synth import write_hlo_dump; \
write_hlo_dump('results/watch_smoke/dump', n_files=2, \
sites_per_file=400, seed=0)" || exit $?
    python -m repro.core.session watch results/watch_smoke/dump --once \
        --settle 0 --interval 0.05 --quiet \
        --summary results/watch_smoke/summary.json \
        --report-json results/watch_smoke/report.json || exit $?
    # chaos gate: corrupt-dump matrix through ingest + the watch daemon —
    # controlled exit codes, quarantine provenance, zero-re-parse resume
    python scripts/chaos_smoke.py || exit $?
    # warehouse smoke (mirrors the CI `warehouse` job one-to-one):
    # 200-host fleet dump -> uncompressed ingest -> mmap query/diff
    rm -rf results/warehouse
    python -c "import sys; sys.path.insert(0, 'src'); \
from repro.core.synth import write_fleet_dump; \
write_fleet_dump('results/warehouse/dump', n_hosts=200, \
steps=1, sites_per_file=40, seed=0)" || exit $?
    python -m repro.core.session ingest results/warehouse/fleet.npz \
        results/warehouse/dump/*.txt --mesh 2,4 --axes data,model \
        --no-compress || exit $?
    python -m repro.core.session query results/warehouse/fleet.npz \
        --host '00*' --mmap --json \
        > results/warehouse/query_00x.json || exit $?
    python -m repro.core.session query results/warehouse/fleet.npz \
        --kind 'all-reduce*' --by semantic --mmap || exit $?
    python -m repro.core.session diff results/warehouse/fleet.npz \
        'host=00*' 'host=01*' --mmap --json \
        > results/warehouse/diff_00x_01x.json || exit $?
    python benchmarks/bench_overhead.py --ingest-only --sites 20000 || exit $?
    python benchmarks/bench_overhead.py --render-only --sites 20000 || exit $?
    python benchmarks/bench_overhead.py --shard-only --sites 50000 || exit $?
    python benchmarks/bench_overhead.py --append-only --sites 20000 || exit $?
    python benchmarks/bench_overhead.py --persist-only --sites 20000 || exit $?
    python benchmarks/bench_overhead.py --merge-only --sites 25600 || exit $?
    python benchmarks/bench_overhead.py --mmapload-only --sites 50000 \
        || exit $?
    python scripts/bench_gate.py \
        results/BENCH_ingest_smoke.json:BENCH_ingest.json \
        results/BENCH_render_smoke.json:BENCH_render.json \
        results/BENCH_shard_smoke.json:BENCH_shard.json:0.5 \
        results/BENCH_append_smoke.json:BENCH_append.json:0.5 \
        results/BENCH_persist_smoke.json:BENCH_persist.json:0.55 \
        results/BENCH_merge_smoke.json:BENCH_merge.json:0.4 \
        results/BENCH_mmapload_smoke.json:BENCH_mmapload.json:0.4
    exit $?
fi

# propagate pytest's exit code explicitly (no `exec`: wrappers that spawn
# a subshell would otherwise swallow the status `exec` hands off)
python -m pytest -x -q "$@"
exit $?
