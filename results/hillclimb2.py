import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Perf hillclimb round 2: combos + accum tradeoffs + serving cell.
# Driven by round-1 scope breakdowns (see hillclimb.py / EXPERIMENTS.md §Perf).

import dataclasses
import json

from repro.configs import get_config
from repro.core.roofline import kernel_adjusted, roofline, train_model_flops, decode_model_flops
from repro.launch import presets
from repro.launch.dryrun import lower_cell
from repro.models import api as model_api

from hillclimb import attn_kernel_bytes, ssm_kernel_bytes, TOKENS  # noqa

HERE = os.path.dirname(os.path.abspath(__file__))


def run_variant(arch, shape, name, cfg_over, set_over, kernel=None,
                kind="train"):
    st = presets.settings_for(arch, shape)
    if set_over:
        st = dataclasses.replace(st, **set_over)
    r = lower_cell(arch, shape, settings=st, cfg_overrides=cfg_over or None)
    tr = r["trace"]
    n = model_api.flops_param_count(get_config(arch))
    if kind == "train":
        model_flops = train_model_flops(n, TOKENS)
    else:
        model_flops = decode_model_flops(n, 32 * 32768)
    rf = roofline(tr, model_flops=model_flops)
    if kernel:
        scope_pat, bytes_fn = kernel
        rf = kernel_adjusted(rf, tr, scope_pat, bytes_fn(arch, st))
    row = {"cell": f"{arch}/{shape}", "variant": name,
           "compute_s": rf.compute_s, "memory_s": rf.memory_s,
           "collective_s": rf.collective_s, "dominant": rf.dominant,
           "mfu_bound": rf.model_roofline_fraction,
           "mem_model_gb": r["mem_model_gb"]}
    print(f"{arch:22s} {name:30s} comp={rf.compute_s:8.2f}s "
          f"hbm={rf.memory_s:8.2f}s coll={rf.collective_s:8.2f}s "
          f"dom={rf.dominant:10s} mfu={rf.model_roofline_fraction:.3f} "
          f"mem={r['mem_model_gb']:.1f}GB")
    return row


def attn_kernel_bytes_prefill(arch, st):
    """Flash-kernel traffic for the prefill shape (32 x 32768 tokens)."""
    cfg = get_config(arch)
    tok_loc = 32 * 32768 // 16
    q_loc = tok_loc * cfg.q_dim // 16 * 2
    kv_loc = tok_loc * cfg.kv_dim // 16 * 2
    return (2 * q_loc + 4 * kv_loc) * cfg.num_layers * 1.0


VARIANTS = [
    # chatglm: stack the round-1 winners
    ("chatglm3-6b", "train_4k", "H10_spshard+flash",
     {}, {"seq_shard": True}, (r"/attn", attn_kernel_bytes), "train"),
    ("chatglm3-6b", "train_4k", "H11_sp+flash+dots",
     {}, {"seq_shard": True, "remat": "dots"},
     (r"/attn", attn_kernel_bytes), "train"),
    # qwen3: halve the FSDP weight-gather traffic by halving accumulation
    # (prediction: collective term ~ -45%, memory model +~4 GB)
    ("qwen3-moe-235b-a22b", "train_4k", "H12_accum8+combo",
     {"moe_group_size": 256, "moe_table_dtype": "bfloat16"},
     {"accum": 8}, (r"/attn", attn_kernel_bytes), "train"),
    ("qwen3-moe-235b-a22b", "train_4k", "H12b_accum4+combo",
     {"moe_group_size": 256, "moe_table_dtype": "bfloat16"},
     {"accum": 4}, (r"/attn", attn_kernel_bytes), "train"),
    # falcon: lighter remat on top of the mamba kernel
    ("falcon-mamba-7b", "train_4k", "H14_kernel+dots",
     {"ssm_inloop": True}, {"remat": "dots"},
     (r"/ssm", ssm_kernel_bytes), "train"),
    # llama3 serving: flash kernel on the prefill cell
    ("llama3-405b", "prefill_32k", "H15_prefill_flash",
     {}, {}, (r"/attn", attn_kernel_bytes_prefill), "prefill"),
]


def main():
    rows = []
    for arch, shape, name, cfg_over, set_over, kernel, kind in VARIANTS:
        try:
            rows.append(run_variant(arch, shape, name, cfg_over, set_over,
                                    kernel, kind))
        except Exception as e:
            print(f"{name}: FAILED {type(e).__name__}: {str(e)[:200]}")
            rows.append({"variant": name, "failed": str(e)[:300]})
    with open(os.path.join(HERE, "hillclimb2.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print("wrote results/hillclimb2.json")


if __name__ == "__main__":
    main()
