"""What-if engine: hardwareless counterfactual sweeps over an annotated trace.

ucTrace's headline experiments re-run the same workload under different UCX
settings (rendezvous thresholds, transports) and compare transfer behavior
to recommend a configuration.  Our vectorized cost model makes the same
counterfactuals nearly free *without re-running anything*: a parsed
`TraceStore` keeps the raw facts (payload bytes, replica groups, op
identity) separate from the derived annotation (link class, protocol,
wire bytes, `est_time_s`), so asking "what would this trace cost on a
different mesh / protocol regime / link tier?" is one re-annotation pass
over an `annotation_clone` of the store — no re-parse, no hardware.

Core pieces:

  * `Scenario` — a named annotation override: an alternate mesh, an axis
    reordering of the baseline mesh (`axis_order`), per-axis interconnect
    remaps (`axis_kind`), a full `Hardware` swap, or field-level hardware
    overrides (`hw_overrides`, e.g. `{"rndv_threshold": 1 << 13}`).
  * `reannotate(store, scenario, mesh, hw)` — price a shared-data clone of
    the store under the scenario.  The baseline store is never mutated
    (`costmodel.annotate_store` rebinds annotation columns, it does not
    write into them); the identity scenario reproduces the baseline
    annotation byte-for-byte (pinned by tests/test_whatif.py).
  * `compare` / `sweep` — diff `est_time_s` and wire bytes per site and
    per rollup key against the baseline and rank scenarios by time saved.
  * `default_scenarios` — the standard grid: every axis reordering of the
    baseline mesh, rendezvous-threshold tiers, and link bandwidth/latency
    tiers (the all-ICI remap is deliberately *not* in the grid — it would
    exactly tie, and thus mask, every realizable mesh refactorization).
  * `dci_saving` / `axis_reprice` — per-finding counterfactuals the
    detectors use to attach a quantified `recommendation` to findings.

Surfaced as `session whatif` (ranked table / `--json`), as the
`recommendation` field on detector findings (reports, watch summary), and
as roofline scenario overlays in `launch/dryrun.py --whatif`.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.costmodel import annotate_store
from repro.core.store import TraceStore
from repro.core.topology import (Hardware, MeshSpec, V5E, hop_latency,
                                 slowest_link_bw)


def fmt_time(t: float) -> str:
    """Human-scaled duration ("3.20 ms"), shared by CLI tables and findings."""
    t = float(t)
    if abs(t) >= 1.0:
        return f"{t:.2f} s"
    if abs(t) >= 1e-3:
        return f"{t * 1e3:.2f} ms"
    return f"{t * 1e6:.0f} us"


# --------------------------------------------------------------------------
# scenarios
# --------------------------------------------------------------------------

@dataclass
class Scenario:
    """One counterfactual annotation configuration.

    All fields compose: `mesh` (or `axis_order` applied to the baseline
    mesh) picks the topology, `axis_kind` then remaps per-axis
    interconnect classes, and `hw` / `hw_overrides` pick the hardware
    constants.  An empty scenario is the identity.
    """

    name: str
    description: str = ""
    mesh: Optional[MeshSpec] = None             # replace the topology outright
    axis_order: Optional[Tuple[str, ...]] = None  # reorder baseline mesh axes
    axis_kind: Mapping[str, str] = field(default_factory=dict)  # -> ici | dci
    hw: Optional[Hardware] = None               # replace the hardware outright
    hw_overrides: Mapping[str, float] = field(default_factory=dict)

    def resolve(self, mesh: MeshSpec, hw: Hardware) -> Tuple[MeshSpec, Hardware]:
        """The concrete (mesh, hardware) this scenario prices against."""
        m = self.mesh if self.mesh is not None else mesh
        if self.axis_order is not None:
            idx = [m.axes.index(a) for a in self.axis_order]
            m = MeshSpec(tuple(m.shape[i] for i in idx),
                         tuple(m.axes[i] for i in idx), dict(m.axis_kind))
        if self.axis_kind:
            ak = dict(m.axis_kind)
            ak.update(self.axis_kind)
            m = MeshSpec(m.shape, m.axes, ak)
        h = self.hw if self.hw is not None else hw
        if self.hw_overrides:
            h = replace(h, **dict(self.hw_overrides))
        return m, h


IDENTITY = Scenario("baseline", "the trace's own mesh and hardware")


def reannotate(store: TraceStore, scenario: Scenario, mesh: MeshSpec,
               hw: Hardware = V5E) -> TraceStore:
    """Price `store` under `scenario` without touching the baseline.

    Returns a new `TraceStore` sharing the row data (payload bytes,
    groups, op identity) with `store` by reference; only the annotation
    columns differ.  One vectorized `annotate_store` pass per call.
    """
    m, h = scenario.resolve(mesh, hw)
    alt = store.annotation_clone()
    annotate_store(alt, m, h)
    return alt


def default_scenarios(mesh: MeshSpec, hw: Hardware = V5E,
                      rndv_tiers: Sequence[int] = (1 << 13, 1 << 18),
                      max_mesh_perms: int = 6) -> List[Scenario]:
    """The standard sweep grid for a trace annotated on (mesh, hw)."""
    out: List[Scenario] = []
    rank = len(mesh.axes)
    if rank >= 2:
        perms = [p for p in itertools.permutations(range(rank))
                 if p != tuple(range(rank))][:max_mesh_perms]
        for p in perms:
            axes = tuple(mesh.axes[i] for i in p)
            shape = tuple(mesh.shape[i] for i in p)
            out.append(Scenario(
                f"mesh:{','.join(axes)}",
                f"refactor the device mesh to {shape} {axes} "
                f"(same devices, different id->coordinate mapping)",
                axis_order=axes))
    for t in rndv_tiers:
        if int(t) != int(hw.rndv_threshold):
            out.append(Scenario(
                f"rndv:{t >> 10}KiB",
                f"rendezvous threshold {t} B/shard — shifts the "
                f"eager/rndv protocol split (labels only; est_time is "
                f"protocol-independent in this model)",
                hw_overrides={"rndv_threshold": int(t)}))
    out.append(Scenario("ici-2x", "double per-link ICI bandwidth",
                        hw_overrides={"ici_bw": hw.ici_bw * 2}))
    out.append(Scenario("lat-half", "halve per-hop collective latencies",
                        hw_overrides={"ici_latency_s": hw.ici_latency_s / 2,
                                      "dci_latency_s": hw.dci_latency_s / 2}))
    if any(k == "dci" for k in mesh.axis_kind.values()):
        out.append(Scenario("dci-2x", "double inter-pod DCI bandwidth",
                            hw_overrides={"dci_bw": hw.dci_bw * 2}))
    # note: the all-ICI remap (`dci_saving`) is deliberately absent — it
    # upper-bounds every mesh refactorization by construction, so ranking
    # it alongside realizable configurations would only ever tie or beat
    # them; it quantifies `cross_pod_bulk` findings instead
    return out


# --------------------------------------------------------------------------
# diffs
# --------------------------------------------------------------------------

def _site_codes(store: TraceStore) -> Tuple[np.ndarray, List[str]]:
    # the what-if site key is op_name x kind — deliberately *excluding*
    # the axes label the report rollups use, because axes are part of the
    # annotation a scenario changes; this key is identical across every
    # re-annotation of the same rows
    return store._join_codes((store.op_name, store.kind))


def site_deltas(base: TraceStore, alt: TraceStore) -> Dict[str, float]:
    """Per-site `est_time_s` change (alt - base), multiplicity-weighted.

    `alt` must be a re-annotation of `base`'s rows (same row order).
    Antisymmetric by construction: `site_deltas(a, b)[k] ==
    -site_deltas(b, a)[k]` for every site key `k`.
    """
    if base.n == 0:
        return {}
    codes, labels = _site_codes(base)
    d = (alt.est_time_s - base.est_time_s) * base.weights
    sums = np.bincount(codes, weights=d, minlength=len(labels))
    return {lab: float(sums[i]) for i, lab in enumerate(labels)}


def _site_times(store: TraceStore) -> Tuple[List[str], np.ndarray]:
    codes, labels = _site_codes(store)
    t = np.bincount(codes, weights=store.est_time_s * store.weights,
                    minlength=len(labels))
    return labels, t


@dataclass
class ScenarioResult:
    """One scenario's diff against the baseline annotation."""

    scenario: Scenario
    mesh: MeshSpec
    hw: Hardware
    baseline_s: float
    est_s: float
    baseline_wire: float
    wire: float
    baseline_eager: int         # weighted eager-protocol executions
    eager: int
    by_key: Dict[str, Tuple[float, float]]      # label -> (base_s, alt_s)
    top_sites: List[Dict[str, object]]          # largest per-site savings

    @property
    def saved_s(self) -> float:
        return self.baseline_s - self.est_s

    @property
    def speedup(self) -> float:
        return self.baseline_s / self.est_s if self.est_s > 0 else float("inf")

    def to_dict(self) -> Dict[str, object]:
        return {
            "name": self.scenario.name,
            "description": self.scenario.description,
            "mesh": {"shape": list(self.mesh.shape),
                     "axes": list(self.mesh.axes),
                     "axis_kind": dict(self.mesh.axis_kind)},
            "est_time_s": self.est_s,
            "baseline_s": self.baseline_s,
            "saved_s": self.saved_s,
            "speedup": self.speedup,
            "wire_bytes": self.wire,
            "wire_saved_bytes": self.baseline_wire - self.wire,
            "eager_sites": self.eager,
            "baseline_eager_sites": self.baseline_eager,
            "by_key": {k: {"baseline_s": b, "est_time_s": a}
                       for k, (b, a) in self.by_key.items()},
            "top_sites": self.top_sites,
        }


def _weighted_eager(store: TraceStore) -> int:
    mask = store.protocol.mask_of("eager") if store.n else \
        np.zeros(0, dtype=bool)
    return int(store.multiplicity[mask].sum()) if store.n else 0


def compare(base: TraceStore, scenario: Scenario, mesh: MeshSpec,
            hw: Hardware = V5E, top: int = 5) -> ScenarioResult:
    """Re-annotate under `scenario` and diff against the baseline."""
    m, h = scenario.resolve(mesh, hw)
    alt = reannotate(base, scenario, mesh, hw)
    w = base.weights
    base_t = float(np.dot(base.est_time_s, w))
    alt_t = float(np.dot(alt.est_time_s, w))
    by_key: Dict[str, Tuple[float, float]] = {}
    if base.n:
        labels, mat = base.rollup("kind_link")
        for i, lab in enumerate(labels):
            by_key[lab] = (float(mat[3, i]), 0.0)
        labels, mat = alt.rollup("kind_link")
        for i, lab in enumerate(labels):
            b, _a = by_key.get(lab, (0.0, 0.0))
            by_key[lab] = (b, float(mat[3, i]))
    top_sites: List[Dict[str, object]] = []
    if base.n:
        labels, bt = _site_times(base)
        _, at = _site_times(alt)
        saved = bt - at
        order = np.argsort(-saved, kind="stable")[:top]
        for i in order:
            if saved[i] == 0.0:
                continue
            top_sites.append({
                "site": labels[i],
                "baseline_s": float(bt[i]),
                "est_time_s": float(at[i]),
                "saved_s": float(saved[i]),
                "speedup": float(bt[i] / at[i]) if at[i] > 0 else float("inf"),
            })
    return ScenarioResult(
        scenario=scenario, mesh=m, hw=h,
        baseline_s=base_t, est_s=alt_t,
        baseline_wire=float(np.dot(base.wire_total, w)),
        wire=float(np.dot(alt.wire_total, w)),
        baseline_eager=_weighted_eager(base), eager=_weighted_eager(alt),
        by_key=by_key, top_sites=top_sites)


def sweep(store: TraceStore, mesh: MeshSpec, hw: Hardware = V5E,
          scenarios: Optional[Sequence[Scenario]] = None,
          top: int = 5) -> List[ScenarioResult]:
    """Price every scenario and rank by time saved (largest first)."""
    if scenarios is None:
        scenarios = default_scenarios(mesh, hw)
    results = [compare(store, sc, mesh, hw, top=top) for sc in scenarios]
    results.sort(key=lambda r: -r.saved_s)
    return results


def sweep_to_dict(results: Sequence[ScenarioResult], label: str,
                  mesh: MeshSpec) -> Dict[str, object]:
    """The stable `session whatif --json` schema."""
    base = results[0] if results else None
    return {
        "label": label,
        "mesh": {"shape": list(mesh.shape), "axes": list(mesh.axes),
                 "axis_kind": dict(mesh.axis_kind)},
        "baseline": {
            "est_time_s": base.baseline_s if base else 0.0,
            "wire_bytes": base.baseline_wire if base else 0.0,
            "eager_sites": base.baseline_eager if base else 0,
        },
        "scenarios": [r.to_dict() for r in results],
    }


def render_sweep(results: Sequence[ScenarioResult], label: str,
                 top_sites: int = 3) -> str:
    """Ranked human-readable table for `session whatif`."""
    lines = [f"what-if sweep: {label}"]
    if not results:
        return lines[0] + "\n  (no scenarios)"
    lines.append(f"  baseline est {fmt_time(results[0].baseline_s)} / step")
    lines.append(f"  {'scenario':<22} {'est/step':>10} {'saved':>10} "
                 f"{'speedup':>8}  note")
    for r in results:
        note = ""
        if r.eager != r.baseline_eager:
            note = f"eager sites {r.baseline_eager} -> {r.eager}"
        lines.append(f"  {r.scenario.name:<22} {fmt_time(r.est_s):>10} "
                     f"{fmt_time(r.saved_s):>10} {r.speedup:>7.2f}x  {note}")
    best = results[0]
    if best.saved_s > 0:
        lines.append(f"  best: {best.scenario.name} — "
                     f"{best.scenario.description}")
        for s in best.top_sites[:top_sites]:
            lines.append(f"    {s['site']}: {fmt_time(s['baseline_s'])} -> "
                         f"{fmt_time(s['est_time_s'])} "
                         f"({s['speedup']:.2f}x)")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# per-finding counterfactuals (detector recommendation quantifiers)
# --------------------------------------------------------------------------

def dci_saving(store: TraceStore, mesh: MeshSpec, hw: Hardware = V5E) -> float:
    """Time/step saved by the all-ICI scenario (hierarchical-reduction cap).

    Re-prices the store with every axis classed as ICI and returns the
    weighted `est_time_s` drop — the ceiling on what keeping cross-pod
    bulk traffic intra-pod could recover.  Rows that never touch the DCI
    price identically, so the delta is exactly the DCI rows' share.
    """
    if store.n == 0:
        return 0.0
    sc = Scenario("ici-everywhere", axis_kind={a: "ici" for a in mesh.axes})
    alt = reannotate(store, sc, mesh, hw)
    d = (store.est_time_s - alt.est_time_s) * store.weights
    return float(d.sum())


def axis_reprice(store: TraceStore, row: int, want_axis: str, mesh: MeshSpec,
                 hw: Hardware = V5E) -> float:
    """Time/exec saved if row `row` rode only `want_axis` (axis-detour fix).

    Keeps the row's wire bytes and hop count and re-prices them at the
    expected axis's link bandwidth and latency — the counterfactual for
    "this grad-sync should have stayed on the data axis".  Returns 0 when
    the expected axis is unknown or the row carries no annotation.
    """
    if want_axis not in mesh.axes:
        return 0.0
    axes = store.axes_tables[store.axes_code[row]]
    if not axes:
        return 0.0
    est = float(store.est_time_s[row])
    wire = float(store.wire_bytes_per_device[row])
    bw0 = slowest_link_bw(mesh, axes, hw)
    lat0 = hop_latency(mesh, axes, hw)
    t_bw0 = wire / (2.0 * bw0)
    hops = (est - t_bw0) / lat0 if lat0 > 0 else 0.0
    want = (want_axis,)
    alt = hops * hop_latency(mesh, want, hw) \
        + wire / (2.0 * slowest_link_bw(mesh, want, hw))
    return max(0.0, est - alt)
