# Development entry points.  The tier-1 gate is `make test`.

PY ?= python

.PHONY: test test-fast bench session-demo

# tier-1: all 12+ test modules must collect and pass (hypothesis optional —
# tests/_hypothesis_compat.py degrades @given to fixed examples without it)
test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q

# analysis-layer tests only (no jax compilation; seconds, not minutes)
test-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q \
		tests/test_tracer.py tests/test_detect.py tests/test_report.py \
		tests/test_session.py tests/test_pipeline.py

bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.run

# end-to-end multi-trace session workflow (build/save/load/compare)
session-demo:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.core.session demo
