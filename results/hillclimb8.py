import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Round 8: H29 — at 512 chips the microbatch must divide 32 DP ways;
# accum 16 -> 8 (prediction: per-device compute finally halves vs
# single-pod, memory per device drops, mfu recovers past H27).
import dataclasses, json
from hillclimb7 import run, rows, st0, HERE

run("H29_mp_fsdp_flash_accum8", True,
    dataclasses.replace(st0, accum=8), kernel_dp=32)
run("H29b_mp_hsdp_flash_accum8", True,
    dataclasses.replace(st0, accum=8, hsdp=True), kernel_dp=32)
with open(os.path.join(HERE, "hillclimb8.json"), "w") as f:
    json.dump(rows, f, indent=1)
