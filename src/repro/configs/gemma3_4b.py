"""gemma3-4b — dense decoder, 5:1 local:global attention. [hf:google/gemma-3]"""
from repro.configs.base import ModelConfig

_LOCAL_WINDOW = 1024
# 5 local layers then 1 global, repeating (global at layers 5, 11, 17, 23, 29).
_PATTERN = tuple(0 if (i % 6) == 5 else _LOCAL_WINDOW for i in range(34))

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,              # q_dim 2048 != d_model (gemma style)
    d_ff=10240,
    vocab_size=262144,
    act="gelu",
    sandwich_norm=True,
    rope_theta=1_000_000.0,
    window_pattern=_PATTERN,
    notes="5:1 local:global; long_500k retains windowed KV on local layers, "
          "full (sharded) KV on the 5 global layers",
)
