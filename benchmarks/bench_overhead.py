"""Table III analogue: tracer overhead.

ucTrace interposes at runtime (1.3x-25x slowdown, GB-scale logs).  Our trace
is compile-time: the overhead is pure offline analysis (HLO parse + assembly)
on top of an unavoidable lower+compile, with zero runtime cost.  We measure
lower/compile/parse wall time and trace size for a dense and a MoE step.
"""
from __future__ import annotations

import json

from _util import run_worker

WORKER = """
import json, time
import jax, jax.numpy as jnp
from repro.configs import ARCHS, smoke_config
from repro.core import MeshSpec, trace_from_hlo
from repro.core.report import to_json
from repro.distributed import sharding as sh
from repro.distributed.autoshard import activation_sharding
from repro.launch.presets import StepSettings
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import adamw

mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = MeshSpec((2, 4), ("data", "model"))
rows = []
for arch in ("chatglm3-6b", "qwen3-moe-235b-a22b"):
    cfg = smoke_config(ARCHS[arch]).replace(
        d_model=128, d_ff=256, moe_d_ff=256 if ARCHS[arch].num_experts else 0,
        num_layers=8, vocab_size=512, num_heads=8, num_kv_heads=4, head_dim=16)
    st = StepSettings(accum=2, remat="full")
    step = make_train_step(cfg, adamw.AdamWConfig(), st)
    params = api.abstract_params(cfg)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = {"m": jax.tree.map(f32, params), "v": jax.tree.map(f32, params),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    shape = type("S", (), {"global_batch": 8, "seq_len": 128, "kind": "train"})()
    batch = api.batch_specs(cfg, shape)
    pspecs = sh.param_pspecs(cfg, mesh)
    jfn = jax.jit(step, in_shardings=(
        sh.named(mesh, pspecs),
        sh.named(mesh, {"m": pspecs, "v": pspecs,
                        "count": jax.sharding.PartitionSpec()}), None),
        donate_argnums=(0, 1))
    t0 = time.perf_counter()
    with activation_sharding(mesh):
        lowered = jfn.lower(params, opt, batch)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    text = compiled.as_text()
    tr = trace_from_hlo(text, spec, label=arch,
                        cost_analysis=compiled.cost_analysis(),
                        memory_analysis=compiled.memory_analysis())
    t3 = time.perf_counter()
    js = to_json(tr)
    rows.append((f"overhead/{arch}/lower", (t1 - t0) * 1e6, "baseline-cost"))
    rows.append((f"overhead/{arch}/compile", (t2 - t1) * 1e6, "baseline-cost"))
    rows.append((f"overhead/{arch}/trace_parse", (t3 - t2) * 1e6,
                 f"overhead_ratio={(t3-t2)/max(t2-t0,1e-9):.3f}|"
                 f"hlo_KB={len(text)//1024}|trace_KB={len(js)//1024}|"
                 f"runtime_overhead=0x (compile-time tool)"))
print("JSON" + json.dumps(rows))
"""


def run():
    out = run_worker(WORKER, devices=8)
    for line in out.splitlines():
        if line.startswith("JSON"):
            return [tuple(r) for r in json.loads(line[4:])]
    raise RuntimeError("no JSON output from worker")
