"""Live profiling — tail an HLO-dump directory, ingest deltas, keep
rolling aggregates fresh.

The batch workflow (dump the module, `session ingest`, `session report`)
answers "what did that run do?".  This module answers the live question
— "what is the run doing *now*?" — the way the paper's daemon mode does:
a poller watches the directory a compiler dumps modules into, ingests
each file once it has settled, and folds it into streaming state:

  * a rolling `TraceStore` grown in place with `TraceStore.append`,
  * `IncrementalRollup`s for the Table II traffic-class aggregates,
  * `detect.DetectorState` (dynamic detectors) sufficient statistics,
  * per-file `commcheck` findings (channel ids are *module*-scoped, so
    the static analyzer runs per dump file — folding all files into one
    `CommcheckState` would invent cross-module channel collisions; that
    streaming state is for chunks of a single module),

so every poll re-renders fresh reports in O(delta) work and O(unique
keys) memory, never re-parsing old files.  Outputs (session save,
report JSON/HTML, summary JSON) are all written through
`persist.atomic_open`, so the consumers the daemon exists for — a
browser auto-reloading the HTML, CI collecting artifacts mid-run —
never observe a torn file.

A file is re-ingested when its (size, mtime) signature changes; since
streaming state cannot *subtract* a stale contribution, a changed file
triggers a rebuild from the retained per-file traces (rare; new files
are the hot path and stay incremental).

`run(once=True)` ingests until the directory is quiescent and exits —
the CI/testing mode; the equivalence contract is that its report output
is byte-identical to `session ingest` + `session report` over the final
directory contents.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.core import commcheck, detect
from repro.core.events import HloOpStats, Trace
from repro.core.persist import atomic_open
from repro.core.store import IncrementalRollup, TraceStore
from repro.core.topology import Hardware, MeshSpec, V5E

Sig = Tuple[int, float]     # (size, mtime) file signature


class DirWatcher:
    """Poll-based new/changed-file detection over one dump directory.

    A file is *ready* when its (size, mtime) signature is unchanged
    across two consecutive polls AND its mtime is at least `settle_s`
    old — a writer mid-stream (a compiler still dumping the module)
    fails both tests, so partially-written files are never handed to
    the parser.  A previously-ingested path whose signature changes
    later becomes ready again (changed-file re-ingest).
    """

    def __init__(self, root: str, pattern: str = "*.txt",
                 settle_s: float = 0.25):
        self.root = root
        self.pattern = pattern
        self.settle_s = settle_s
        self._last: Dict[str, Sig] = {}
        self._ingested: Dict[str, Sig] = {}

    def _scan(self) -> Dict[str, Sig]:
        sigs: Dict[str, Sig] = {}
        for path in sorted(glob.glob(os.path.join(self.root, self.pattern))):
            try:
                st = os.stat(path)
            except OSError:
                continue    # deleted between glob and stat
            sigs[path] = (int(st.st_size), float(st.st_mtime))
        return sigs

    def poll(self, now: Optional[float] = None
             ) -> Tuple[List[str], int]:
        """One poll: -> (paths ready to ingest, count still pending).

        Pending counts files that are present but not yet stable —
        first-seen this poll, signature still moving, or settling.
        """
        if now is None:
            now = time.time()
        sigs = self._scan()
        ready: List[str] = []
        pending = 0
        for path, sig in sigs.items():
            if self._ingested.get(path) == sig:
                continue
            if self._last.get(path) == sig and now - sig[1] >= self.settle_s:
                ready.append(path)
            else:
                pending += 1
        self._last = sigs
        return ready, pending

    def mark_ingested(self, path: str) -> None:
        sig = self._last.get(path)
        if sig is not None:
            self._ingested[path] = sig


@dataclasses.dataclass
class WatchConfig:
    root: str
    mesh: MeshSpec
    pattern: str = "*.txt"
    hw: Hardware = V5E
    out: Optional[str] = None           # rolling session save (.json/.npz)
    report_json: Optional[str] = None
    report_html: Optional[str] = None
    summary: Optional[str] = None
    settle_s: float = 0.25
    interval_s: float = 1.0
    once: bool = False
    fail_on: str = "never"
    shards: Optional[int] = None
    max_rounds: Optional[int] = None
    expected_axes: Optional[Dict[str, str]] = None
    quiet: bool = False


class WatchDaemon:
    """The streaming-ingest loop behind `session watch`.

    Drives a `DirWatcher`, parses each ready file through the same
    per-file pipeline batch ingest uses (`tracer.trace_from_hlo`), and
    folds the resulting trace into the rolling aggregates.  `poll_once`
    is the unit tests drive directly; `run` wraps it in the sleep loop
    with `--once` quiescence detection.
    """

    def __init__(self, cfg: WatchConfig):
        self.cfg = cfg
        self.watcher = DirWatcher(cfg.root, cfg.pattern, cfg.settle_s)
        self._traces: Dict[str, Trace] = {}     # path -> per-file trace
        self._lint: Dict[str, List[detect.Finding]] = {}    # path -> findings
        self.rounds = 0
        self._reset_rolling()

    # -- streaming state -----------------------------------------------------

    def _reset_rolling(self) -> None:
        self.rolling = TraceStore.empty()
        self.rollups = {"kind_link": IncrementalRollup("kind_link"),
                        "semantic": IncrementalRollup("semantic")}
        self.detector = detect.DetectorState(
            expected_axes=self.cfg.expected_axes, hw=self.cfg.hw)
        self.op_stats = HloOpStats()

    def _fold(self, trace: Trace) -> None:
        self.rolling.append(trace.store)
        for roll in self.rollups.values():
            roll.update(trace.store)
        self.detector.update(trace)
        self.op_stats = HloOpStats.merged([self.op_stats, trace.op_stats])

    def _rebuild(self) -> None:
        # streaming state cannot subtract a stale file's contribution;
        # re-fold the retained per-file traces (no re-parse)
        self._reset_rolling()
        for path in sorted(self._traces):
            self._fold(self._traces[path])

    def ingest(self, path: str) -> Trace:
        from repro.core.tracer import trace_from_hlo
        with open(path) as f:
            text = f.read()
        label = os.path.splitext(os.path.basename(path))[0]
        changed = path in self._traces
        trace = trace_from_hlo(text, self.cfg.mesh, label=label,
                               hw=self.cfg.hw, shards=self.cfg.shards)
        self._traces[path] = trace
        # static analysis is per module: one CommcheckState per file,
        # findings cached until the file itself changes
        st = commcheck.CommcheckState(self.cfg.mesh)
        st.update(trace.store)
        self._lint[path] = st.findings()
        if changed:
            self._rebuild()
        else:
            self._fold(trace)
        return trace

    def poll_once(self, now: Optional[float] = None) -> Tuple[List[str], int]:
        """One watcher poll + ingest of everything ready."""
        ready, pending = self.watcher.poll(now)
        for path in ready:
            self.ingest(path)
            self.watcher.mark_ingested(path)
        self.rounds += 1
        return ready, pending

    # -- derived views -------------------------------------------------------

    def session(self):
        from repro.core.session import TraceSession
        name = os.path.basename(os.path.abspath(self.cfg.root)) or "watch"
        return TraceSession(name,
                            [self._traces[p] for p in sorted(self._traces)])

    def findings(self) -> List[detect.Finding]:
        """Static (per-module commcheck) + dynamic (detector) findings."""
        out: List[detect.Finding] = []
        for path in sorted(self._lint):
            out.extend(self._lint[path])
        out.extend(self.detector.findings())
        return detect.rank_findings(out)

    def alerts(self) -> List[detect.Finding]:
        if self.cfg.fail_on == "never":
            return []
        rank = detect.SEVERITY_RANK
        return [f for f in self.findings()
                if rank.get(f.severity, 99) <= rank[self.cfg.fail_on]]

    def summary(self) -> Dict[str, object]:
        return {
            "root": self.cfg.root,
            "files": len(self._traces),
            "sites": int(self.rolling.n),
            "rounds": self.rounds,
            "by_kind_link": self.rollups["kind_link"].as_dict(),
            "by_semantic": self.rollups["semantic"].as_dict(),
            "findings": [f.to_dict() for f in self.findings()],
        }

    # -- output --------------------------------------------------------------

    def emit(self) -> None:
        """Re-write every configured artifact (all atomic replaces)."""
        cfg = self.cfg
        sess = self.session() if (cfg.out or cfg.report_json
                                  or cfg.report_html) else None
        if cfg.out:
            sess.save(cfg.out)
        for path, fmt in ((cfg.report_json, "json"),
                          (cfg.report_html, "html")):
            if path and len(sess):
                with atomic_open(path, "w") as fp:
                    sess.report(fmt=fmt, fp=fp)
        if cfg.summary:
            with atomic_open(cfg.summary, "w") as fp:
                json.dump(self.summary(), fp, indent=1)
                fp.write("\n")

    def _log(self, msg: str) -> None:
        if not self.cfg.quiet:
            print(msg, flush=True)

    # -- the loop ------------------------------------------------------------

    def run(self) -> int:
        """Poll until interrupted (daemon) or quiescent (`once`).

        `once` exits after a poll that found nothing ready *and*
        nothing pending, with at least two polls total (a pre-existing
        file needs two polls to prove stability).  Returns 1 when any
        finding reached `fail_on` severity, else 0.
        """
        cfg = self.cfg
        emitted = False
        try:
            while True:
                ready, pending = self.poll_once()
                if ready or not emitted:
                    self.emit()
                    emitted = True
                    self._log(f"[watch] round {self.rounds}: "
                              f"+{len(ready)} file(s), "
                              f"{len(self._traces)} total, "
                              f"{self.rolling.n} sites, "
                              f"{pending} pending")
                if cfg.once and not ready and not pending \
                        and self.rounds >= 2:
                    break
                if cfg.max_rounds is not None \
                        and self.rounds >= cfg.max_rounds:
                    break
                time.sleep(cfg.interval_s)
        except KeyboardInterrupt:
            self._log("[watch] interrupted")
        self.emit()
        alerts = self.alerts()
        for f in alerts:
            where = f" @ {f.site}" if f.site else ""
            print(f"[watch] ALERT [{f.severity}] {f.detector}{where}: "
                  f"{f.message}", file=sys.stderr)
        return 1 if alerts else 0
