"""Sharding-rule properties: validity + divisibility for all archs x meshes.

Pure-function tests (no devices needed): the rules engine takes axis-size
dicts, so we exercise the exact production mesh shapes without 512 devices.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import ARCH_ORDER, SHAPES, get_config, shape_applicable
from repro.distributed import sharding as sh
from repro.models import api
from repro.models.meta import is_meta, tree_map_meta

SINGLE = {"data": 16, "model": 16}
MULTI = {"pod": 2, "data": 16, "model": 16}


def _all_param_specs(cfg, sizes, rules):
    meta = api.model_meta(cfg)
    return tree_map_meta(
        lambda _p, m: (m, sh.spec_for(m.shape, m.logical, rules, sizes)), meta)


def _leaves(tree):
    out = []
    def rec(n):
        if isinstance(n, tuple) and is_meta(n[0]):
            out.append(n)
        elif isinstance(n, dict):
            for v in n.values():
                rec(v)
    rec(tree)
    return out


@pytest.mark.parametrize("arch", ARCH_ORDER)
@pytest.mark.parametrize("sizes", [SINGLE, MULTI], ids=["1pod", "2pod"])
def test_param_specs_divide_evenly(arch, sizes):
    cfg = get_config(arch)
    for rules in (sh.TRAIN_RULES, sh.SERVE_RULES_REPLICATED):
        for m, spec in _leaves(_all_param_specs(cfg, sizes, rules)):
            assert len(spec) <= len(m.shape)
            used = []
            for dim, part in zip(m.shape, tuple(spec) + (None,) * 9):
                if part is None:
                    continue
                axes = (part,) if isinstance(part, str) else part
                prod = int(np.prod([sizes[a] for a in axes]))
                assert dim % prod == 0, (arch, m.shape, spec)
                used += list(axes)
            assert len(used) == len(set(used)), (arch, spec)  # axis used once


@pytest.mark.parametrize("arch", ARCH_ORDER)
def test_fsdp_shards_big_params(arch):
    """Every >=8M-element matmul param must be sharded under TRAIN rules."""
    cfg = get_config(arch)
    for m, spec in _leaves(_all_param_specs(cfg, SINGLE, sh.TRAIN_RULES)):
        n = int(np.prod(m.shape))
        if n >= (1 << 23) and len(m.shape) >= 2:
            assert any(p is not None for p in spec), (arch, m.shape, m.logical)


def test_expert_sharding_modes():
    """qwen3: expert dim on `model` (EP); mixtral: 8 experts < 16 => ffn TP."""
    q = get_config("qwen3-moe-235b-a22b")
    mix = get_config("mixtral-8x22b")
    for m, spec in _leaves(_all_param_specs(q, SINGLE, sh.TRAIN_RULES)):
        if m.logical[:1] == ("layers",) and "expert" in m.logical:
            i = m.logical.index("expert")
            assert spec[i] == "model", (m.logical, spec)
    for m, spec in _leaves(_all_param_specs(mix, SINGLE, sh.TRAIN_RULES)):
        if "expert" in m.logical and "moe_mlp" in m.logical:
            ei = m.logical.index("expert")
            fi = m.logical.index("moe_mlp")
            assert (len(spec) <= ei or spec[ei] is None)   # 8 % 16 != 0
            assert spec[fi] == "model"


@given(dim=st.integers(1, 4096), axis=st.sampled_from(["model", "data"]))
@settings(max_examples=50, deadline=None)
def test_spec_for_never_invalid(dim, axis):
    spec = sh.spec_for((dim,), (axis if axis == "model" else "embed",),
                       sh.TRAIN_RULES, SINGLE)
    for part in spec:
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else part
        prod = int(np.prod([SINGLE[a] for a in axes]))
        assert dim % prod == 0


@pytest.mark.parametrize("arch", ARCH_ORDER)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_cache_specs_structural(arch, shape_name):
    """Cache specs match cache structure; dims divide for sharded axes."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, _ = shape_applicable(cfg, shape)
    if not ok or shape.kind != "decode":
        pytest.skip("not a decode cell")
    specs = api.cache_specs(cfg, shape)
    if isinstance(specs, dict):
        assert all(s.shape[0] == cfg.num_layers for s in specs.values())
    else:
        assert len(specs) == cfg.num_layers


def test_hsdp_rules_shard_intra_pod_only():
    """HSDP: params shard over `data` only; `pod` replicates (the per-layer
    weight gathers stay on intra-pod ICI)."""
    cfg = get_config("mixtral-8x22b")
    for m, spec in _leaves(_all_param_specs(cfg, MULTI, sh.TRAIN_RULES_HSDP)):
        for part in spec:
            if part is None:
                continue
            axes = (part,) if isinstance(part, str) else part
            assert "pod" not in axes, (m.logical, spec)
    # plain FSDP does use the pod axis for big embed dims
    uses_pod = False
    for m, spec in _leaves(_all_param_specs(cfg, MULTI, sh.TRAIN_RULES)):
        for part in spec:
            axes = (part,) if isinstance(part, str) else (part,) if part else ()
            if part is not None and "pod" in ((part,) if isinstance(part, str)
                                              else part):
                uses_pod = True
    assert uses_pod


def test_serve_rules_adaptive():
    # big model -> FSDP serving; small -> replicated over data
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), object)
    big = sh.serve_rules_for(get_config("llama3-405b"), FakeMesh())
    small = sh.serve_rules_for(get_config("hymba-1.5b"), FakeMesh())
    assert big["embed"] != ()
    assert small["embed"] == ()
