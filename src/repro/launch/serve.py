"""Batched serving driver: continuous-batching-lite prefill/decode loop.

Requests arrive with prompts; the scheduler packs up to `max_batch` active
sequences, prefills new arrivals, and steps decode for the whole batch.
The decode step is compiled once (static cache length); finished sequences
free their slot for waiting requests.

    PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import api as model_api
from repro.models import transformer


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [P] int32
    max_new: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Slot-based batched decoder (static shapes, compiled once)."""

    def __init__(self, cfg, params, *, max_batch=8, cache_len=512):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.cache = transformer.init_cache(cfg, max_batch, cache_len,
                                            windowed=False)
        self.pos = np.zeros(max_batch, np.int64)
        self.slots: List[Optional[Request]] = [None] * max_batch

        def step(params, cache, tokens, pos_vec):
            # per-slot positions: decode uses the max (cache written per-slot
            # via the shared scalar path; slots are kept position-aligned by
            # the scheduler for this lite implementation)
            return model_api.decode_step(cfg, params, cache, tokens,
                                         pos_vec)
        self._decode = jax.jit(step, donate_argnums=(1,))

    def prefill_into_slot(self, slot: int, req: Request):
        """Run the prompt through decode steps (aligned-batch lite path)."""
        self.slots[slot] = req
        self.pos[slot] = 0
        for t in req.prompt:
            tok = np.zeros((self.max_batch, 1), np.int32)
            tok[slot, 0] = t
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tok),
                jnp.int32(int(self.pos[slot])))
            self.pos[slot] += 1
        req._last_logits = np.asarray(logits[slot, 0])

    def decode_round(self) -> None:
        active = [i for i, r in enumerate(self.slots) if r and not r.done]
        if not active:
            return
        tok = np.zeros((self.max_batch, 1), np.int32)
        for i in active:
            r = self.slots[i]
            last = r.generated[-1] if r.generated else int(
                np.argmax(r._last_logits))
            tok[i, 0] = last
        pos = int(max(self.pos[i] for i in active))
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tok), jnp.int32(pos))
        lg = np.asarray(logits[:, 0])
        for i in active:
            r = self.slots[i]
            nxt = int(np.argmax(lg[i]))
            r.generated.append(nxt)
            self.pos[i] += 1
            if len(r.generated) >= r.max_new or self.pos[i] >= self.cache_len - 1:
                r.done = True
                self.slots[i] = None if r.done else r


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if cfg.family == "encdec":
        raise SystemExit("serve driver targets decoder-only families")
    params = model_api.init_params(cfg, 0)
    server = BatchedServer(cfg, params, max_batch=args.max_batch,
                           cache_len=max(64, args.prompt_len + args.max_new + 2))

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len,
                                    dtype=np.int32), args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    queue = list(reqs)
    done: List[Request] = []
    while queue or any(server.slots):
        for slot in range(server.max_batch):
            if server.slots[slot] is None and queue:
                server.prefill_into_slot(slot, queue.pop(0))
        server.decode_round()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for r in reqs[:2]:
        print(f"  req {r.rid}: {list(r.prompt[:4])}... -> {r.generated[:8]}")


if __name__ == "__main__":
    main()
