"""MPI/UCP attribution analogue: op_name metadata -> scope + semantic class.

ucTrace captures a call stack per UCT/UCP event and walks it upward until it
finds an MPI function.  On TPU the compiler bakes the "call stack" into each
HLO op as `metadata={op_name="jit(fn)/scope1/scope2/.../primitive"}` — our
`jax.named_scope` annotations plus the originating jax primitive.  This
module recovers:

  * `scope`     — the named_scope path (e.g. `layer/attn`),
  * `jax_prim`  — the UCP-operation analogue (psum / all_gather / dot_general
                  for GSPMD-inserted collectives),
  * `semantic`  — the MPI-function analogue (grad_sync / attention / moe / ...).
"""
from __future__ import annotations

import re
from typing import Iterable, List, Tuple

import numpy as np

from repro.core.events import CollectiveEvent

# transformations wrappers that appear as path components but are not scopes
_TRANSFORM_RE = re.compile(
    r"^(jit|pjit|jvp|transpose|vmap|remat|checkpoint|custom_vjp|shard_map|"
    r"named_computation)\b")

# ordered semantic rules: (regex on scope path, collective kind or None, label)
SEMANTIC_RULES: List[Tuple[str, str, str]] = [
    (r"moe/(dispatch|router)", "", "moe_dispatch"),
    (r"moe", "all-to-all", "moe_dispatch"),
    (r"moe/combine", "", "moe_combine"),
    (r"moe", "", "moe_combine"),
    (r"(attn|cross_attn|self_attn)", "", "attention"),
    (r"ssm", "", "ssm"),
    (r"mlp", "", "ffn"),
    (r"(embed|logits|vision_stub)", "", "embed_logits"),
    (r"loss", "", "loss"),
    (r"(grad_sync|optimizer|adamw|opt_update)", "", "grad_sync"),
    (r"(data|batch)_shard", "", "data_pipeline"),
    (r"(pipeline|ppermute_ring)", "", "pipeline"),
]


def split_op_name(op_name: str) -> Tuple[str, str]:
    """op_name -> (scope_path, primitive)."""
    if not op_name:
        return "", ""
    parts = op_name.split("/")
    prim = parts[-1] if parts else ""
    scopes = []
    for part in parts[:-1]:
        if _TRANSFORM_RE.match(part):
            # keep the innermost name of wrappers like `transpose(jvp(mlp))`
            inner = re.findall(r"\(([\w\-\. ]+)\)", part)
            if inner and not _TRANSFORM_RE.match(inner[-1]):
                scopes.append(inner[-1])
            continue
        scopes.append(part)
    return "/".join(scopes), prim


DP_AXES = ("data", "pod", "fsdp", "batch", "dp", "replica")


def classify(scope: str, prim: str, kind: str, *, in_backward: bool,
             axes=(), dp_axes=DP_AXES) -> str:
    # GSPMD gradient sync: a backward-pass reduction that spans only
    # data-parallel axes is parameter-gradient synchronization no matter
    # which module's dot it was attributed to.
    if (kind in ("all-reduce", "reduce-scatter") and in_backward
            and axes and all(a in dp_axes for a in axes)):
        return "grad_sync"
    text = scope + "/" + prim
    for pattern, kind_filter, label in SEMANTIC_RULES:
        if kind_filter and kind_filter != kind:
            continue
        if re.search(pattern, text):
            return label
    if kind in ("all-reduce", "reduce-scatter") and in_backward and not scope:
        return "grad_sync"
    return "other"


def is_backward(op_name: str) -> bool:
    return "transpose(" in op_name or "/transpose" in op_name


def attribute_event(ev: CollectiveEvent, dp_axes=DP_AXES) -> None:
    scope, prim = split_op_name(ev.op_name)
    ev.scope = scope
    ev.jax_prim = prim
    ev.semantic = classify(scope, prim, ev.kind,
                           in_backward=is_backward(ev.op_name),
                           axes=ev.axes, dp_axes=dp_axes)


def attribute_all(events: Iterable[CollectiveEvent], dp_axes=DP_AXES) -> None:
    for ev in events:
        attribute_event(ev, dp_axes)


# --------------------------------------------------------------------------
# batched path: run the regex cascade once per unique vocab entry
# --------------------------------------------------------------------------

def attribute_store(store, dp_axes=DP_AXES) -> None:
    """Columnar `attribute_event`: fill scope/jax_prim/semantic in place.

    `op_name` strings are heavily repeated (one per HLO op site, but drawn
    from a small set of named-scope paths), so `split_op_name` and
    `is_backward` run once per *vocab entry* of the interned `op_name`
    column.  The semantic cascade additionally depends on (kind, axes) —
    it runs once per unique (op_name, kind, axes) code triple and
    broadcasts through the composite codes.  Field-for-field equivalent to
    `attribute_all(store.rows())` — pinned by tests/test_ingest.py.
    """
    from repro.core.store import Categorical, build_remap

    n = store.n
    if n == 0:
        store.scope = Categorical.constant(0)
        store.jax_prim = Categorical.constant(0)
        store.semantic = Categorical.constant(0)
        return

    on_vocab = store.op_name.vocab
    split = [split_op_name(name) for name in on_vocab]
    backward = [is_backward(name) for name in on_vocab]
    store.scope = store.op_name.remap_table([s for s, _ in split])
    store.jax_prim = store.op_name.remap_table([p for _, p in split])

    # semantic: unique (op_name, kind, axes) triples
    nk = max(len(store.kind.vocab), 1)
    na = max(len(store.axes_tables), 1)
    combo = (store.op_name.codes.astype(np.int64) * nk
             + store.kind.codes) * na + store.axes_code
    uniq, inv = np.unique(combo, return_inverse=True)
    labels = []
    for code in uniq:
        oc, r = divmod(int(code), nk * na)
        kc, ac = divmod(r, na)
        labels.append(classify(
            split[oc][0], split[oc][1], store.kind.vocab[kc],
            in_backward=backward[oc], axes=store.axes_tables[ac],
            dp_axes=dp_axes))
    sem_map, sem_vocab = build_remap(labels)
    store.semantic = Categorical(sem_map[inv], sem_vocab)
