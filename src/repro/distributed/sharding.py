"""Logical-axis -> mesh-axis sharding rules (DP/FSDP/TP/EP/SP).

Every parameter declares *logical* axes (`embed`, `heads`, `mlp`, `expert`,
...).  Rules map each logical axis to an ordered list of candidate mesh-axis
tuples; the first candidate whose axes (a) exist in the mesh, (b) are not
already used by another dim of the same tensor, and (c) divide the dimension
evenly, wins.  This gives:

  * FSDP/ZeRO-3: `embed`/`in_vocab` sharded over (pod, data),
  * TP:          `heads`/`kv_heads`/`mlp`/`vocab`/`inner` over `model`,
  * EP:          `expert` over `model` when E divides it (qwen3: 128/16),
                 falling back to ffn-TP inside experts (mixtral: 8 < 16),
  * SP:          long-context KV/state sharded over leftover axes.

Archs whose dims don't divide an axis degrade gracefully to replication —
the tracer prices the resulting traffic, which is the whole point.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import api as model_api
from repro.models.meta import tree_map_meta

Rules = Dict[str, Tuple[Tuple[str, ...], ...]]

# DP/FSDP axis preference: pod+data jointly, else data alone.
_FSDP = (("pod", "data"), ("data",))
# HSDP: shard within the pod, replicate across pods — per-layer weight
# gathers stay on intra-pod ICI; the cross-pod DCI carries one gradient
# all-reduce per step instead of per-layer-per-microbatch gathers.
_FSDP_HIER = (("data",), ("pod", "data"))
_TP = (("model",),)

TRAIN_RULES: Rules = {
    "embed": _FSDP,
    # the input table shards along d_model (embed_tp) only: XLA's SPMD
    # partitioner cannot partition gathers along the indexed (vocab) dim
    # (invalid dynamic-slice after spmd-partitioning), and a D-sharded
    # table makes the lookup comm-free anyway.
    "in_vocab": (),
    "heads": _TP,
    "kv_heads": _TP,
    "mlp": _TP,
    "moe_mlp": _TP,
    "inner": _TP,
    "vocab": _TP,
    "embed_tp": _TP,
    "expert": _TP,
    "layers": (),
}

# Serving: weights stay FSDP-sharded for frontier configs (weight-gather
# amortized over the batch); small models replicate over data.
SERVE_RULES_FSDP: Rules = TRAIN_RULES
SERVE_RULES_REPLICATED: Rules = {**TRAIN_RULES, "embed": ()}

TRAIN_RULES_HSDP: Rules = {**TRAIN_RULES, "embed": _FSDP_HIER}

BATCH_AXES = (("pod", "data"), ("data",))


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, np.shape(mesh.devices)))


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             rules: Rules, axis_sizes: Dict[str, int]) -> P:
    parts = []
    used: set = set()
    for dim, name in zip(shape, logical):
        chosen: Optional[Tuple[str, ...]] = None
        if name is not None:
            for cand in rules.get(name, ()):
                if not cand:
                    continue
                if any(a not in axis_sizes for a in cand):
                    continue
                if used & set(cand):
                    continue
                prod = int(np.prod([axis_sizes[a] for a in cand]))
                if dim % prod == 0:
                    chosen = cand
                    break
        if chosen:
            used |= set(chosen)
            parts.append(chosen[0] if len(chosen) == 1 else chosen)
        else:
            parts.append(None)
    return P(*parts)


def shard_dim(dim: int, candidates, axis_sizes: Dict[str, int],
              used: set) -> Optional[Tuple[str, ...]]:
    for cand in candidates:
        if not cand or any(a not in axis_sizes for a in cand) or (used & set(cand)):
            continue
        prod = int(np.prod([axis_sizes[a] for a in cand]))
        if dim % prod == 0:
            return cand
    return None


# --------------------------------------------------------------------------
# model-level sharding trees
# --------------------------------------------------------------------------

def param_pspecs(cfg, mesh, rules: Rules = TRAIN_RULES):
    sizes = mesh_axis_sizes(mesh)
    meta_tree = model_api.model_meta(cfg)
    return tree_map_meta(
        lambda _p, m: spec_for(m.shape, m.logical, rules, sizes), meta_tree)


def param_shardings(cfg, mesh, rules: Rules = TRAIN_RULES):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(cfg, mesh, rules),
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_pspecs(cfg, mesh, rules: Rules = TRAIN_RULES):
    ps = param_pspecs(cfg, mesh, rules)
    return {"m": ps, "v": ps, "count": P()}


def batch_pspecs(cfg, shape, mesh):
    """PartitionSpecs for the train/prefill batch dict."""
    sizes = mesh_axis_sizes(mesh)
    B = shape.global_batch
    used: set = set()
    b_axes = shard_dim(B, BATCH_AXES, sizes, used)
    bspec = (b_axes[0] if len(b_axes) == 1 else b_axes) if b_axes else None
    out = {}
    for key, sds in model_api.batch_specs(cfg, shape).items():
        if key == "positions":            # [3, B, S]
            out[key] = P(None, bspec, None)
        else:
            out[key] = P(*([bspec] + [None] * (len(sds.shape) - 1)))
    return out


def _cache_entry_pspecs(entry, B, sizes, stacked: bool):
    """PartitionSpecs for one cache entry (leading L dim when stacked)."""
    lead = (None,) if stacked else ()
    e: Dict[str, P] = {}
    used: set = set()
    b_axes = shard_dim(B, BATCH_AXES, sizes, used)
    if b_axes:
        used |= set(b_axes)
    bspec = (b_axes[0] if len(b_axes) == 1 else b_axes) if b_axes else None
    off = 1 if stacked else 0
    for key, sds in entry.items():
        if key in ("k", "v", "cross_k", "cross_v"):
            sc = sds.shape[1 + off]
            s_cands = (("model",),) if b_axes else \
                (("data", "model"), ("model",), ("data",))
            s_axes = shard_dim(sc, s_cands, sizes, used)
            sspec = None
            if s_axes:
                sspec = s_axes[0] if len(s_axes) == 1 else s_axes
            e[key] = P(*lead, bspec, sspec, None, None)
        elif key == "conv":           # [B, dc-1, di]
            di_axes = shard_dim(sds.shape[2 + off], _TP, sizes, used)
            e[key] = P(*lead, bspec, None, di_axes[0] if di_axes else None)
        elif key == "ssm":            # [B, di, N]
            di_axes = shard_dim(sds.shape[1 + off], _TP, sizes, used)
            e[key] = P(*lead, bspec, di_axes[0] if di_axes else None, None)
        else:
            e[key] = P(*([None] * len(sds.shape)))
    return e


def cache_pspecs(cfg, shape, mesh):
    """Decode-cache PartitionSpecs (stacked dict or per-layer list).

    Prefers batch-sharding over (pod, data) and sequence-sharding over
    `model`; at 500k ctx with batch 1 the sequence takes every available
    axis (SP).  SSM state shards its channel dim over `model`.
    """
    sizes = mesh_axis_sizes(mesh)
    B = shape.global_batch
    specs_in = model_api.cache_specs(cfg, shape)
    if isinstance(specs_in, dict):
        return _cache_entry_pspecs(specs_in, B, sizes, stacked=True)
    return [_cache_entry_pspecs(entry, B, sizes, stacked=False)
            for entry in specs_in]


def lint_sharding(cfg, mesh, rules: Rules = TRAIN_RULES, shape=None):
    """Static pre-trace lint of a model's sharding plan on a mesh.

    Runs `commcheck.lint_pspecs` over the `param_pspecs` tree (with the
    real parameter shapes from the meta tree, so divisibility and
    unsharded-dominant-dim checks apply) and, when a `shape` is given,
    over `batch_pspecs` too.  Returns findings ranked by severity then
    tensor bytes at stake — catch a bad spec before compiling anything.
    """
    from repro.core import commcheck
    from repro.core.detect import rank_findings

    sizes = mesh_axis_sizes(mesh)
    meta_tree = model_api.model_meta(cfg)
    shapes = tree_map_meta(lambda _p, m: tuple(m.shape), meta_tree)
    out = commcheck.lint_pspecs(param_pspecs(cfg, mesh, rules), sizes,
                                shapes=shapes, prefix="params")
    if shape is not None:
        out += commcheck.lint_pspecs(batch_pspecs(cfg, shape, mesh), sizes,
                                     prefix="batch")
    return rank_findings(out)


def serve_rules_for(cfg, mesh) -> Rules:
    """Replicate weights over DP axes only when they comfortably fit."""
    sizes = mesh_axis_sizes(mesh)
    tp = sizes.get("model", 1)
    bytes_per_dev = model_api.param_count(cfg) * 2 / tp   # bf16 serving
    return SERVE_RULES_REPLICATED if bytes_per_dev < 4e9 else SERVE_RULES_FSDP


def named(mesh, tree_of_pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
