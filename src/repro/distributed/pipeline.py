"""Pipeline parallelism: GPipe-style microbatch streaming over a mesh axis.

Each pipeline stage owns a contiguous slice of layers; microbatches stream
stage-to-stage via neighbor `ppermute` (the `collective-permute` chains the
tracer classifies as `pipeline` traffic).  The schedule runs M + P - 1
ticks; bubble fraction (P-1)/(M+P-1) is the textbook GPipe overhead.

This is the optional PP building block: the assigned shapes are covered by
FSDP x TP (+2 pods), but at >4 pods the cross-pod DCI makes FSDP gathers
expensive and stage-parallelism over `pod` becomes the right trade — the
cost model prices both so the choice is quantitative.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, mesh,
                   axis: str = "model"):
    """Run microbatches through P pipeline stages.

    stage_fn(params_slice, h) -> h       (one stage's layers)
    stage_params: pytree whose leaves have leading dim P (one slice/stage)
    x_micro:      [M, mb, ...] microbatches
    Returns y [M, mb, ...] after all P stages.
    """
    p_size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    M = x_micro.shape[0]
    ticks = M + p_size - 1
    fwd_perm = [(i, i + 1) for i in range(p_size - 1)]

    def run(params_loc, x_loc):
        # params_loc: this stage's slice (leading dim 1); x_loc: full [M,...]
        params_me = jax.tree.map(lambda a: a[0], params_loc)
        idx = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(x_loc[0])                  # stage input register
        out = jnp.zeros_like(x_loc)
        for t in range(ticks):
            # stage 0 injects microbatch t; others use the received buffer
            mb = min(t, M - 1)
            inject = x_loc[mb]
            h_in = jnp.where(idx == 0, inject, buf)
            with jax.named_scope("pipeline_stage"):
                h_out = stage_fn(params_me, h_in)
            # last stage retires microbatch (t - (P-1)) at tick t
            retire = t - (p_size - 1)
            if 0 <= retire < M:
                out = out.at[retire].set(
                    jnp.where(idx == p_size - 1, h_out, out[retire]))
            with jax.named_scope("pipeline_hop"):
                buf = jax.lax.ppermute(h_out, axis, fwd_perm)
        # results live on the last stage; broadcast to all for the caller
        out = jax.lax.psum(
            jnp.where(idx == p_size - 1, out, jnp.zeros_like(out)), axis)
        return out

    mapped = shard_map(
        run, mesh=mesh,
        in_specs=(P(axis), P()),     # params split by stage; micros replicated
        out_specs=P(),
        check_rep=False)
    return mapped(stage_params, x_micro)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
