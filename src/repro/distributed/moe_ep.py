"""Sort-based expert-parallel MoE dispatch (beyond-paper optimization).

The baseline GShard-style dispatch builds [G, Sg, E, C] one-hot tensors and
pays ~Sg^2-scaled einsum FLOPs for dispatch+combine.  This path instead:

  * runs per data-shard under shard_map (tokens stay local),
  * top-k routes, sorts token-slots by expert id, applies a global capacity,
  * scatters tokens into each *local* expert's [E_loc, C, D] buffer
    (experts sharded over the `model` axis: each shard computes its E/TP
    experts on its replicated token set — no all-to-all needed on this
    mesh layout; the only collective is the same [T, D] psum over `model`
    the einsum path pays for combine),
  * gathers + weight-combines with a scatter-add.

Dispatch/combine become O(T·k) gather/scatter instead of O(T·E·C) einsums.
Requires E % model_axis == 0 (qwen3: 128/16; mixtral's 8 experts fall back
to the einsum path, which expert-TPs them instead).

Capacity semantics differ slightly from the grouped baseline (global per
shard vs per routing group); with a no-drop capacity factor the two paths
agree numerically (tests/test_moe_ep.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _moe_shard(x_loc, router_w, wg, wu, wd, *, cfg, e_loc: int):
    """Per-(data x model)-shard MoE. x_loc [b,S,D] (replicated over model);
    wg/wu/wd hold this model-shard's E_loc experts."""
    B, S, D = x_loc.shape
    dt = x_loc.dtype
    k = cfg.top_k
    E = cfg.num_experts
    T = B * S
    xf = x_loc.reshape(T, D)
    m_idx = jax.lax.axis_index("model")

    with jax.named_scope("router"):
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)                 # [T,k]
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        # aux load-balance loss (Switch; same normalization as the einsum
        # path: ce sums to k over experts)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / T
        aux = E * jnp.sum(me * ce)

    with jax.named_scope("dispatch"):
        cap = max(1, math.ceil(k * T * cfg.capacity_factor / E))
        flat_e = idx.reshape(-1)                             # [T*k]
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = flat_e[order]
        tok_sorted = order // k
        gate_sorted = gates.reshape(-1)[order]
        # position within each expert's run of the sorted array
        first = jnp.searchsorted(e_sorted, e_sorted, side="left")
        pos = jnp.arange(T * k, dtype=jnp.int32) - first.astype(jnp.int32)
        keep = pos < cap
        lo = m_idx * e_loc
        local = keep & (e_sorted >= lo) & (e_sorted < lo + e_loc)
        dump = e_loc * cap                                   # overflow row
        dest = jnp.where(local, (e_sorted - lo) * cap + pos, dump)
        vals = jnp.where(local[:, None], xf[tok_sorted], 0).astype(dt)
        buf = jnp.zeros((e_loc * cap + 1, D), dt).at[dest].add(vals)
        x_e = buf[:e_loc * cap].reshape(e_loc, cap, D)

    with jax.named_scope("experts"):
        g = jnp.einsum("ecd,edf->ecf", x_e, wg.astype(dt))
        u = jnp.einsum("ecd,edf->ecf", x_e, wu.astype(dt))
        y_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(dt))

    with jax.named_scope("combine"):
        flat_y = jnp.concatenate(
            [y_e.reshape(e_loc * cap, D), jnp.zeros((1, D), dt)], axis=0)
        y_slot = flat_y[dest] * gate_sorted[:, None].astype(dt)
        y_tok = jnp.zeros((T, D), jnp.float32).at[tok_sorted].add(
            jnp.where(local[:, None], y_slot, 0).astype(jnp.float32))
        y = jax.lax.psum(y_tok, "model").astype(dt)
    # aux is identical on every model shard (router is replicated)
    return y.reshape(B, S, D), aux


def apply_moe_sort(cfg, p, x, mesh):
    """shard_map-wrapped sort-based MoE. Requires E % model == 0."""
    model_size = dict(zip(mesh.axis_names,
                          jnp.shape(mesh.devices))).get("model", 1)
    assert cfg.num_experts % model_size == 0, (cfg.num_experts, model_size)
    e_loc = cfg.num_experts // model_size
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = data_axes[0] if len(data_axes) == 1 else data_axes

    fn = functools.partial(_moe_shard, cfg=cfg, e_loc=e_loc)
    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, None, None),        # x: tokens over data
                  P(None, None),               # router replicated
                  P("model", None, None),      # experts over model
                  P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_rep=False)
    y, aux = mapped(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
