"""Sharded single-module ingest: splitter + `TraceStore.merge` equivalence.

The shard path (`hlo_parser.split_hlo_module` -> per-chunk
`parse_hlo_store(shard_ctx=...)` -> `TraceStore.merge` +
`HloOpStats.merged`) must be *byte-identical* to a serial
`parse_hlo_store` of the whole module — same row order, same interned
vocab/table order, same codes — across shard counts, multi-computation
layouts, empty shards, and schema round-trips.
"""
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core import hlo_parser
from repro.core.events import HloOpStats
from repro.core.store import TraceStore
from repro.core.synth import synthetic_hlo
from repro.core.topology import MeshSpec
from repro.core.tracer import trace_from_hlo

MESH = MeshSpec((2, 4), ("data", "model"))


def parse_shards(text: str, k: int):
    """(serial parse, merged shard parse) of the same module text."""
    serial_store, serial_stats = hlo_parser.parse_hlo_store(text, 8)
    chunks, ctx = hlo_parser.split_hlo_module(text, k)
    parsed = [hlo_parser.parse_hlo_store(c, 8, shard_ctx=ctx)
              for c in chunks]
    merged = TraceStore.merge([s for s, _ in parsed])
    mstats = HloOpStats.merged([s for _, s in parsed])
    return (serial_store, serial_stats), (merged, mstats), chunks


def assert_stores_identical(a: TraceStore, b: TraceStore):
    """`identical` plus field-level asserts so a failure names the field."""
    assert a.n == b.n
    assert a.names == b.names
    from repro.core.store import _CAT_COLS, _NUM_COLS
    for col, _dt in _NUM_COLS:
        np.testing.assert_array_equal(getattr(a, col), getattr(b, col),
                                      err_msg=col)
    for col in _CAT_COLS:
        ca, cb = getattr(a, col), getattr(b, col)
        assert ca.vocab == cb.vocab, col
        np.testing.assert_array_equal(ca.codes, cb.codes, err_msg=col)
    assert [tuple(map(tuple, t)) for t in a.group_tables] == \
           [tuple(map(tuple, t)) for t in b.group_tables]
    np.testing.assert_array_equal(a.group_code, b.group_code)
    assert [tuple(map(tuple, t)) for t in a.stp_tables] == \
           [tuple(map(tuple, t)) for t in b.stp_tables]
    np.testing.assert_array_equal(a.stp_code, b.stp_code)
    assert [tuple(t) for t in a.axes_tables] == \
           [tuple(t) for t in b.axes_tables]
    np.testing.assert_array_equal(a.axes_code, b.axes_code)
    assert a.identical(b)


# -- merge(shards) == serial parse, property-style over seeds x layouts ------

@given(seed=st.integers(0, 1000))
@settings(max_examples=6, deadline=None)
def test_merge_equals_serial_parse(seed):
    text = synthetic_hlo(n_sites=300, seed=seed, n_computations=5)
    for k in (2, 3, 7):
        (s_store, s_stats), (m_store, m_stats), chunks = parse_shards(text, k)
        assert len(chunks) > 1
        assert_stores_identical(m_store, s_store)
        assert dataclasses.asdict(m_stats) == dataclasses.asdict(s_stats)


@pytest.mark.parametrize("n_computations", [1, 4])
@pytest.mark.parametrize("k", [1, 2, 5])
def test_merge_equals_serial_across_layouts(n_computations, k):
    text = synthetic_hlo(n_sites=250, seed=2, n_computations=n_computations)
    (s_store, s_stats), (m_store, m_stats), _ = parse_shards(text, k)
    assert_stores_identical(m_store, s_store)
    assert dataclasses.asdict(m_stats) == dataclasses.asdict(s_stats)


def test_split_preserves_while_multiplicity_across_chunks():
    """Trip counts apply even when body/cond land in another chunk than
    the entry: the shared context carries the multiplicity fixpoint."""
    text = synthetic_hlo(n_sites=200, seed=5, trip_count=9,
                         n_computations=6)
    (s_store, _), (m_store, _), chunks = parse_shards(text, 4)
    assert len(chunks) >= 3
    assert int(m_store.multiplicity.max()) == 9
    assert_stores_identical(m_store, s_store)


def test_split_while_behind_call_chain():
    """A while reached only through `call(...) to_apply=` chains still
    gets its trip count (the splitter's backward-activation scan)."""
    text = "\n".join([
        "HloModule nested",
        "",
        "%cond (p: (s32[], f32[4])) -> pred[] {",
        "  %p = (s32[], f32[4]) parameter(0)",
        "  %i = s32[] get-tuple-element(%p), index=0",
        "  %n = s32[] constant(7)",
        "  ROOT %lt = pred[] compare(%i, %n), direction=LT",
        "}",
        "",
        "%loop_body (p: (s32[], f32[4])) -> (s32[], f32[4]) {",
        "  %p = (s32[], f32[4]) parameter(0)",
        "  %i = s32[] get-tuple-element(%p), index=0",
        "  %x = f32[4] get-tuple-element(%p), index=1",
        "  %ar = f32[4] all-reduce(%x), channel_id=1, "
        "replica_groups=[2,4]<=[8], "
        'metadata={op_name="jit(f)/loop/psum"}',
        "  %one = s32[] constant(1)",
        "  %i2 = s32[] add(%i, %one)",
        "  ROOT %t = (s32[], f32[4]) tuple(%i2, %x)",
        "}",
        "",
        "%middle (q: f32[4]) -> f32[4] {",
        "  %x = f32[4] parameter(0)",
        "  %zero = s32[] constant(0)",
        "  %init = (s32[], f32[4]) tuple(%zero, %x)",
        "  %w = (s32[], f32[4]) while(%init), condition=%cond, "
        "body=%loop_body",
        "  ROOT %out = f32[4] get-tuple-element(%w), index=1",
        "}",
        "",
        "ENTRY %main (x: f32[4]) -> f32[4] {",
        "  %x = f32[4] parameter(0)",
        "  ROOT %c = f32[4] call(%x), to_apply=%middle",
        "}",
        "",
    ])
    (s_store, s_stats), (m_store, m_stats), _ = parse_shards(text, 3)
    assert s_store.n == 1
    assert int(s_store.multiplicity[0]) == 7
    assert_stores_identical(m_store, s_store)
    assert dataclasses.asdict(m_stats) == dataclasses.asdict(s_stats)


def test_split_duplicate_computation_names():
    """The serial parser's dict overwrite keeps the *last* definition's
    content at the *first* occurrence's position; the splitter must
    reproduce both, or merged row/vocab order diverges."""
    def comp(name, kind, i):
        return [
            f"%{name} (p: f32[8]) -> f32[8] {{",
            "  %x = f32[8] parameter(0)",
            f"  %c.{i} = f32[8] {kind}(%x), channel_id={i}, "
            "replica_groups=[2,4]<=[8], "
            f'metadata={{op_name="jit(f)/{name}/op"}}',
            "}",
            "",
        ]
    text = "\n".join(
        ["HloModule dup", ""]
        + comp("f", "reduce-scatter", 1)      # shadowed definition
        + comp("g", "all-gather", 2)
        + comp("f", "all-reduce", 3)          # wins, at %f's first position
        + [
            "ENTRY %main (x: f32[8]) -> f32[8] {",
            "  %x = f32[8] parameter(0)",
            "  %a = f32[8] call(%x), to_apply=%f",
            "  ROOT %b = f32[8] call(%a), to_apply=%g",
            "}",
            "",
        ])
    serial, sstats = hlo_parser.parse_hlo_store(text, 8)
    assert serial.kind.vocab == ["all-reduce", "all-gather"]
    for k in (1, 2, 3):
        chunks, ctx = hlo_parser.split_hlo_module(text, k)
        parsed = [hlo_parser.parse_hlo_store(c, 8, shard_ctx=ctx)
                  for c in chunks]
        merged = TraceStore.merge([s for s, _ in parsed])
        assert_stores_identical(merged, serial)
        assert dataclasses.asdict(HloOpStats.merged([s for _, s in parsed])) \
            == dataclasses.asdict(sstats)


def test_split_many_call_chain_whiles():
    """>4 while-containing computations flips the splitter's backward
    activation onto the single global reference pass; multiplicities and
    the merged store must still match serial exactly."""
    def while_comp(i):
        return [
            f"%cond{i} (p: (s32[], f32[4])) -> pred[] {{",
            "  %p = (s32[], f32[4]) parameter(0)",
            "  %i = s32[] get-tuple-element(%p), index=0",
            f"  %n = s32[] constant({i + 2})",
            "  ROOT %lt = pred[] compare(%i, %n), direction=LT",
            "}", "",
            f"%body{i} (p: (s32[], f32[4])) -> (s32[], f32[4]) {{",
            "  %p = (s32[], f32[4]) parameter(0)",
            "  %x = f32[4] get-tuple-element(%p), index=1",
            f"  %ar{i} = f32[4] all-reduce(%x), channel_id={i + 1}, "
            "replica_groups=[2,4]<=[8], "
            'metadata={op_name="jit(f)/l/psum"}',
            "  %i0 = s32[] get-tuple-element(%p), index=0",
            "  %one = s32[] constant(1)",
            "  %i2 = s32[] add(%i0, %one)",
            "  ROOT %t = (s32[], f32[4]) tuple(%i2, %x)",
            "}", "",
            f"%wrap{i} (q: f32[4]) -> f32[4] {{",
            "  %x = f32[4] parameter(0)",
            "  %zero = s32[] constant(0)",
            "  %init = (s32[], f32[4]) tuple(%zero, %x)",
            f"  %w = (s32[], f32[4]) while(%init), condition=%cond{i}, "
            f"body=%body{i}",
            "  ROOT %out = f32[4] get-tuple-element(%w), index=1",
            "}", "",
        ]
    lines = ["HloModule manywhiles", ""]
    for i in range(8):
        lines += while_comp(i)
    lines += ["ENTRY %main (x: f32[4]) -> f32[4] {",
              "  %x = f32[4] parameter(0)"]
    for i in range(8):
        lines.append(f"  %c{i} = f32[4] call(%x), to_apply=%wrap{i}")
    lines += ["  ROOT %r = f32[4] copy(%x)", "}", ""]
    text = "\n".join(lines)
    (s_store, s_stats), (m_store, m_stats), _ = parse_shards(text, 5)
    assert sorted(int(m) for m in s_store.multiplicity) == \
        sorted(range(2, 10))
    assert_stores_identical(m_store, s_store)
    assert dataclasses.asdict(m_stats) == dataclasses.asdict(s_stats)


# -- merge edge cases --------------------------------------------------------

def test_merge_empty_and_single():
    empty = TraceStore.merge([])
    assert empty.n == 0 and empty.rows() == []
    text = synthetic_hlo(n_sites=120, seed=1, n_computations=3)
    store, _ = hlo_parser.parse_hlo_store(text, 8)
    assert TraceStore.merge([store]) is store
    # interleaved zero-row stores are identity elements
    merged = TraceStore.merge(
        [TraceStore.empty(), store, TraceStore.empty()])
    assert_stores_identical(merged, store)


def test_merge_stats_empty_and_single():
    assert dataclasses.asdict(HloOpStats.merged([])) == \
           dataclasses.asdict(HloOpStats())
    _, stats = hlo_parser.parse_hlo_store(
        synthetic_hlo(n_sites=50, seed=0), 8)
    assert dataclasses.asdict(HloOpStats.merged([stats])) == \
           dataclasses.asdict(stats)


def test_merge_after_schema_roundtrips():
    """Shards round-tripped through the v2 dict and the v1 (per-row)
    layout still merge identically to the serial parse."""
    text = synthetic_hlo(n_sites=180, seed=4, n_computations=4)
    (s_store, _), _, chunks = parse_shards(text, 3)
    ctx = hlo_parser.split_hlo_module(text, 3)[1]
    shard_stores = [hlo_parser.parse_hlo_store(c, 8, shard_ctx=ctx)[0]
                    for c in chunks]

    v2 = [TraceStore.from_dict(s.to_dict()) for s in shard_stores]
    assert_stores_identical(TraceStore.merge(v2), s_store)

    def to_v1(store):
        d = store.to_dict()
        v1 = {k: d[k] for k in ("n", "num")}
        v1["version"] = 1
        v1["cat"] = {k: v for k, v in d["cat"].items() if k != "op_name"}
        v1["names"] = store.names
        v1["op_names"] = store.op_names
        v1["axes"] = [list(a) for a in store.axes]
        v1["replica_groups"] = store.replica_groups
        v1["source_target_pairs"] = [
            None if p is None else [list(pair) for pair in p]
            for p in store.source_target_pairs]
        return v1

    v1 = [TraceStore.from_dict(to_v1(s)) for s in shard_stores]
    assert_stores_identical(TraceStore.merge(v1), s_store)


# -- splitter invariants -----------------------------------------------------

def test_split_chunks_cover_all_computations():
    text = synthetic_hlo(n_sites=150, seed=3, n_computations=6)
    comps = {n for n in hlo_parser._split_computations(text)
             if n != "__entry__"}
    chunks, ctx = hlo_parser.split_hlo_module(text, 4)
    seen = set()
    for c in chunks:
        seen |= {n for n in hlo_parser._split_computations(c)
                 if n != "__entry__"}
    assert seen == comps
    assert set(ctx["mult"]) <= comps
    # fewer computations than shards: one chunk per computation, no empties
    many, _ = hlo_parser.split_hlo_module(text, 100)
    assert 1 < len(many) <= len(comps)


def test_auto_shards_thresholds():
    assert hlo_parser.auto_shards(1 << 20, cpus=8) == 1
    assert hlo_parser.auto_shards(hlo_parser.AUTO_SHARD_BYTES, cpus=1) == 1
    assert hlo_parser.auto_shards(64 << 20, cpus=4) >= 8


# -- end-to-end sharded ingest ----------------------------------------------

def test_trace_from_hlo_sharded_identical():
    text = synthetic_hlo(n_sites=400, seed=6, n_computations=8)
    serial = trace_from_hlo(text, MESH, label="t", shards=1)
    sharded = trace_from_hlo(text, MESH, label="t", shards=3,
                             shard_workers=0)
    assert sharded.store.identical(serial.store)
    assert dataclasses.asdict(sharded.op_stats) == \
           dataclasses.asdict(serial.op_stats)
    assert sharded.by_kind_and_link() == serial.by_kind_and_link()
    assert sharded.total_est_time_s() == serial.total_est_time_s()
    from repro.core.report import to_json
    assert to_json(sharded) == to_json(serial)


def test_trace_from_hlo_sharded_pool():
    """The real process-pool path (fork or spawn) matches too."""
    text = synthetic_hlo(n_sites=300, seed=7, n_computations=6)
    serial = trace_from_hlo(text, MESH, shards=1)
    pooled = trace_from_hlo(text, MESH, shards=2)
    assert pooled.store.identical(serial.store)


def test_session_ingest_cli_shards(tmp_path, capsys):
    from repro.core.session import TraceSession, _main
    p = tmp_path / "big.hlo"
    p.write_text(synthetic_hlo(n_sites=150, seed=8, n_computations=4))
    out = str(tmp_path / "sharded.json")
    assert _main(["ingest", out, str(p), "--mesh", "2,4",
                  "--axes", "data,model", "--shards", "2"]) == 0
    assert "ingested 1 traces" in capsys.readouterr().out
    loaded = TraceSession.load(out)
    ref = trace_from_hlo(p.read_text(), MESH, shards=1)
    assert loaded.get("big").by_kind_and_link() == ref.by_kind_and_link()
