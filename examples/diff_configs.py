"""Before/after workflow: diff the communication of two configurations.

    PYTHONPATH=src python examples/diff_configs.py

Traces the same arch x shape under two serving weight placements (FSDP-
sharded vs replicated-over-data) and prints the per-class traffic diff —
the paper's case-study loop ("change a UCX setting, compare the graphs")
as one function call on two compiled artifacts: the per-layer weight
all-gathers vanish under replication, traded for per-device memory.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax

from repro.core import MeshSpec
from repro.core.diff import render_diff
from repro.launch import presets
from repro.launch.dryrun import lower_cell


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    spec = MeshSpec((2, 4), ("data", "model"))
    arch, shape = "mixtral-8x22b", "decode_32k"

    st = presets.settings_for(arch, shape)
    base = lower_cell(arch, shape, mesh=mesh, mesh_spec=spec,
                      settings=dataclasses.replace(st, serve_fsdp=True))
    opt = lower_cell(arch, shape, mesh=mesh, mesh_spec=spec,
                     settings=dataclasses.replace(st, serve_fsdp=False))
    a, b = base["trace"], opt["trace"]
    a.label, b.label = "fsdp-weights", "replicated-weights"
    print(f"per-device memory (analytic): {base['mem_model_gb']} GB -> "
          f"{opt['mem_model_gb']} GB")
    print(render_diff(a, b))
    print()
    print(render_diff(a, b, by="semantic"))


if __name__ == "__main__":
    main()
