"""Append-mode TraceStore: incremental growth == batch, byte-for-byte.

The streaming-ingest contract (PR invariant): a store grown by N
`TraceStore.append` calls over the chunks of a module is *identical*
(`TraceStore.identical` — codes, vocab order, payload tables, caches
rebuilt on demand) to one batch `parse_hlo_store` over the whole text,
exactly as PR 5 pinned for `merge`.  Plus the streaming aggregate
state: `IncrementalRollup`, `detect.DetectorState`, and
`commcheck.CommcheckState` fed per-chunk must reproduce their batch
siblings over the union.
"""
import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core import attribution, commcheck, costmodel, detect, hlo_parser
from repro.core.store import IncrementalRollup, TraceStore, union_rollup
from repro.core.synth import inject_comm_bugs, synthetic_hlo, synthetic_trace
from repro.core.topology import MeshSpec, V5E
from repro.core.tracer import trace_from_hlo

MESH = MeshSpec((2, 4), ("data", "model"))


def chunk_stores(seed: int, n_sites: int = 400, n_chunks: int = 4,
                 n_computations: int = 6):
    text = synthetic_hlo(n_sites=n_sites, seed=seed,
                         n_computations=n_computations)
    chunks, ctx = hlo_parser.split_hlo_module(text, n_chunks)
    stores = [hlo_parser.parse_hlo_store(c, MESH.num_devices,
                                         shard_ctx=ctx)[0]
              for c in chunks]
    batch, _ = hlo_parser.parse_hlo_store(text, MESH.num_devices)
    return stores, batch


# -- the core byte-identity invariant ----------------------------------------

@given(seed=st.integers(0, 1000))
@settings(max_examples=6, deadline=None)
def test_append_identical_to_batch_parse(seed):
    stores, batch = chunk_stores(seed)
    acc = TraceStore.empty()
    for s in stores:
        assert acc.append(s) is acc
    assert acc.identical(batch)
    # appended store keeps working as a store: caches rebuilt on demand
    assert acc.rows() == batch.rows()


def test_append_matches_merge_any_order():
    stores, _ = chunk_stores(11)
    order = [2, 0, 3, 1]     # out-of-order file arrival
    acc = TraceStore.empty()
    for i in order:
        acc.append(stores[i])
    assert acc.identical(TraceStore.merge([stores[i] for i in order]))


def test_append_empty_is_noop_both_ways():
    stores, _ = chunk_stores(3, n_sites=120, n_chunks=2)
    acc = TraceStore.empty()
    acc.append(TraceStore.empty())
    assert acc.n == 0
    acc.append(stores[0])
    before = acc.to_dict()
    acc.append(TraceStore.empty())
    assert acc.identical(stores[0])
    assert acc.to_dict() == before


def test_append_single_chunk_identical():
    stores, _ = chunk_stores(5, n_sites=100, n_chunks=1, n_computations=1)
    acc = TraceStore.empty()
    acc.append(stores[0])
    assert acc.identical(stores[0])


def test_append_self_raises():
    tr = synthetic_trace("s", MESH, n_sites=30, seed=0)
    with pytest.raises(ValueError):
        tr.store.append(tr.store)


def test_append_annotated_chunks_matches_merge():
    # annotate_store orders derived axes tables per store, so annotated
    # appends are pinned against merge of the same annotated chunks (the
    # raw-parse invariant above is where batch byte-identity lives)
    stores, _ = chunk_stores(17)
    for s in stores:
        costmodel.annotate_store(s, MESH, V5E)
        attribution.attribute_store(s)
    acc = TraceStore.empty()
    for s in stores:
        acc.append(s)
    assert acc.identical(TraceStore.merge(stores))
    ref = TraceStore.merge(stores)
    assert acc.by_kind_and_link() == ref.by_kind_and_link()


def test_append_after_wholesale_column_replacement():
    # annotate_store replaces numeric columns wholesale (est_time_s etc.
    # are fresh arrays, not views of the append buffers); the next append
    # must re-adopt them instead of scribbling over stale buffers
    stores, _ = chunk_stores(23, n_sites=200, n_chunks=3)
    acc = TraceStore.empty()
    acc.append(stores[0])
    costmodel.annotate_store(acc, MESH, V5E)
    t0 = acc.est_time_s.copy()
    acc.append(stores[1])
    np.testing.assert_array_equal(acc.est_time_s[:len(t0)], t0)
    assert acc.n == stores[0].n + stores[1].n


# -- persistence of appended stores ------------------------------------------

def test_appended_store_v2_roundtrip(tmp_path):
    stores, batch = chunk_stores(29, n_sites=150, n_chunks=3)
    acc = TraceStore.empty()
    for s in stores:
        acc.append(s)
    d = json.loads(json.dumps(acc.to_dict()))
    assert d["version"] == 2
    assert TraceStore.from_dict(d).identical(batch)
    arrs = dict(acc.npz_arrays(prefix="a_"))
    path = tmp_path / "acc.npz"
    np.savez_compressed(path, **arrs)
    with np.load(path) as loaded:
        back = TraceStore.from_npz_arrays(loaded, prefix="a_")
    assert back.identical(batch)


def test_appended_store_v1_dict_roundtrip():
    a = synthetic_trace("a", MESH, n_sites=40, seed=1).store
    b = synthetic_trace("b", MESH, n_sites=40, seed=2).store
    acc = TraceStore.empty()
    acc.append(a)
    acc.append(b)
    d = acc.to_dict()
    v1 = {"version": 1, "n": d["n"], "num": d["num"],
          "cat": {k: v for k, v in d["cat"].items() if k != "op_name"},
          "names": acc.names, "op_names": acc.op_names,
          "axes": [list(x) for x in acc.axes],
          "replica_groups": acc.replica_groups,
          "source_target_pairs": [
              None if p is None else [list(pair) for pair in p]
              for p in acc.source_target_pairs]}
    assert TraceStore.from_dict(v1).rows() == acc.rows()


# -- streaming aggregates == batch over the union ----------------------------

def test_incremental_rollup_matches_union_and_batch():
    stores, batch = chunk_stores(31)
    for by in ("kind_link", "semantic", "site"):
        inc = IncrementalRollup(by)
        for s in stores:
            inc.update(s)
        labels, mat = union_rollup(stores, by)
        assert inc.labels == labels
        np.testing.assert_allclose(inc.matrix, mat.sum(axis=2), rtol=1e-12)
        blabels, bmat = batch.rollup(by)
        assert inc.labels == blabels
        np.testing.assert_allclose(inc.matrix, bmat, rtol=1e-12)


def finding_key(f):
    return (f.detector, f.severity, f.site, f.message)


def test_detector_state_matches_run_all():
    mesh = MeshSpec((2, 4), ("data", "model"))
    full = synthetic_trace("full", mesh, n_sites=3000, seed=4)
    expected = {"grad_sync": "data"}
    batch = detect.run_all(full, expected_axes=expected)
    st_ = detect.DetectorState(expected_axes=expected)
    evs = full.events
    step = (len(evs) + 4) // 5
    from repro.core.events import Trace
    for i in range(0, len(evs), step):
        st_.update(Trace(label=f"c{i}", mesh_shape=mesh.shape,
                         mesh_axes=mesh.axes, num_devices=mesh.num_devices,
                         events=evs[i:i + step]))
    inc = st_.findings()
    assert sorted(map(finding_key, inc)) == sorted(map(finding_key, batch))


@given(seed=st.integers(0, 200))
@settings(max_examples=6, deadline=None)
def test_commcheck_state_matches_batch_on_buggy_traces(seed):
    trace, _labels = inject_comm_bugs(MESH, n_sites=120, seed=seed)
    batch = commcheck.check_trace(trace, MESH)
    st_ = commcheck.CommcheckState(MESH)
    evs = trace.events
    step = (len(evs) + 4) // 5
    for i in range(0, len(evs), step):
        st_.update(TraceStore.from_events(evs[i:i + step]))
    inc = st_.findings()
    assert list(map(finding_key, inc)) == list(map(finding_key, batch))


def test_commcheck_state_clean_chunked_hlo_quiet():
    text = synthetic_hlo(n_sites=400, seed=9, n_computations=5)
    full = trace_from_hlo(text, MESH, label="f", shards=1)
    batch = commcheck.check_trace(full, MESH)
    chunks, ctx = hlo_parser.split_hlo_module(text, 3)
    st_ = commcheck.CommcheckState(MESH)
    for c in chunks:
        store, _ = hlo_parser.parse_hlo_store(c, MESH.num_devices,
                                              shard_ctx=ctx)
        costmodel.annotate_store(store, MESH, V5E)
        attribution.attribute_store(store)
        st_.update(store)
    assert list(map(finding_key, st_.findings())) \
        == list(map(finding_key, batch))
