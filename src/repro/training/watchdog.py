"""Straggler / hang mitigation.

Synchronous SPMD means one slow worker stalls the fleet.  The watchdog
tracks per-step wall times, flags statistical outliers, and exposes a
hang deadline; the trainer's response at scale is checkpoint-and-evict
(here: flag + callback, unit-tested directly since this container has a
single worker).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, List, Optional


@dataclass
class StepStats:
    step: int
    duration_s: float
    flagged: bool


class StragglerWatchdog:
    def __init__(self, window: int = 50, sigma: float = 4.0,
                 hang_factor: float = 10.0,
                 on_flag: Optional[Callable[[StepStats], None]] = None):
        self.window: Deque[float] = deque(maxlen=window)
        self.sigma = sigma
        self.hang_factor = hang_factor
        self.on_flag = on_flag
        self.flagged: List[StepStats] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = time.perf_counter()

    def end_step(self) -> StepStats:
        assert self._t0 is not None
        dur = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(self._step, dur)

    def observe(self, step: int, duration_s: float) -> StepStats:
        flagged = False
        if len(self.window) >= 10:
            mean = sum(self.window) / len(self.window)
            var = sum((x - mean) ** 2 for x in self.window) / len(self.window)
            std = max(var ** 0.5, 1e-6 * mean, 1e-9)
            if duration_s > mean + self.sigma * std and duration_s > 1.5 * mean:
                flagged = True
        self.window.append(duration_s)
        st = StepStats(step, duration_s, flagged)
        if flagged:
            self.flagged.append(st)
            if self.on_flag:
                self.on_flag(st)
        return st

    def hang_deadline_s(self) -> float:
        """Abort threshold for a wedged collective (checkpoint-and-evict)."""
        if not self.window:
            return 3600.0
        return max(self.window) * self.hang_factor
