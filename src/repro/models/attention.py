"""GQA attention: projections, full/blocked softmax paths, KV-cache decode.

Three compute paths:
  * ``naive``   — materialize [.., S, S] scores (small seqs / smoke tests)
  * ``blocked`` — online-softmax over KV chunks in pure XLA (lax.scan);
                  the portable memory-bounded path used for 32k prefill.
  * ``pallas``  — TPU flash-attention kernel (repro.kernels.flash_attention);
                  numerically validated against ``naive`` in interpret mode.

Keys are cached *post-RoPE*; windowed (ring-buffer) caches rely on attention
being permutation-invariant over keys.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.meta import ParamMeta
from repro.models.layers import apply_rope, rms_norm_head

NEG_INF = -1e30


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (chunking non-power-of-2 seqs)."""
    cap = max(1, min(cap, n))
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def attention_meta(cfg, cross: bool = False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    m = {
        "wq": ParamMeta((d, qd), ("embed", "heads")),
        "wk": ParamMeta((d, kvd), ("embed", "kv_heads")),
        "wv": ParamMeta((d, kvd), ("embed", "kv_heads")),
        "wo": ParamMeta((qd, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        m["q_norm"] = ParamMeta((cfg.head_dim,), (None,), init="ones")
        m["k_norm"] = ParamMeta((cfg.head_dim,), (None,), init="ones")
    return m


def project_qkv(cfg, p, x_q, x_kv, positions_q, positions_kv):
    """Project and rope. x_q [B,Sq,D], x_kv [B,Skv,D] -> q[B,Sq,H,Dh], k/v[B,Skv,K,Dh]."""
    dt = x_q.dtype
    B, Sq, _ = x_q.shape
    Skv = x_kv.shape[1]
    H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x_q, p["wq"].astype(dt)).reshape(B, Sq, H, Dh)
    k = jnp.einsum("bsd,dh->bsh", x_kv, p["wk"].astype(dt)).reshape(B, Skv, K, Dh)
    v = jnp.einsum("bsd,dh->bsh", x_kv, p["wv"].astype(dt)).reshape(B, Skv, K, Dh)
    if cfg.qk_norm:
        q = rms_norm_head(q, p["q_norm"])
        k = rms_norm_head(k, p["k_norm"])
    if positions_q is not None:
        q = apply_rope(cfg, q, positions_q)
    if positions_kv is not None:
        k = apply_rope(cfg, k, positions_kv)
    return q, k, v


def _mask_bias(q_idx, k_idx, *, causal: bool, window) -> jax.Array:
    """Additive bias [.., Sq, Skv] from index grids (fp32)."""
    ok = jnp.ones(jnp.broadcast_shapes(q_idx.shape, k_idx.shape), bool)
    if causal:
        ok &= k_idx <= q_idx
    if window is not None:
        # traced or static window; 0 = full attention
        w = jnp.asarray(window, jnp.int32)
        ok &= jnp.where(w > 0, (q_idx - k_idx) < w, True)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attend_naive(cfg, q, k, v, *, causal=True, window=0, q_offset=0,
                 kv_valid_len: Optional[jax.Array] = None):
    """q [B,Sq,H,Dh], k/v [B,Skv,K,Dh] -> [B,Sq,H,Dh]."""
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, Dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (cfg.head_dim ** -0.5)
    q_idx = (jnp.arange(Sq, dtype=jnp.int32) + q_offset)[:, None]
    k_idx = jnp.arange(Skv, dtype=jnp.int32)[None, :]
    bias = _mask_bias(q_idx, k_idx, causal=causal, window=window)
    if kv_valid_len is not None:
        valid = k_idx < jnp.asarray(kv_valid_len, jnp.int32)
        bias = bias + jnp.where(valid, 0.0, NEG_INF)
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, Dh)


def attend_blocked(cfg, q, k, v, *, causal=True, window=0, q_offset=0,
                   kv_chunk=1024):
    """Online-softmax over KV chunks (pure XLA, memory-bounded).

    Computes all (q, kv-chunk) pairs with masking; the Pallas kernel skips
    fully-masked blocks (see kernels/flash_attention.py).
    """
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    K = k.shape[2]
    G = H // K
    kv_chunk = largest_divisor_leq(Skv, min(kv_chunk, Skv))
    n_chunks = Skv // kv_chunk
    qg = q.reshape(B, Sq, K, G, Dh)
    kc = k.reshape(B, n_chunks, kv_chunk, K, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, K, Dh).transpose(1, 0, 2, 3, 4)
    q_idx = (jnp.arange(Sq, dtype=jnp.int32) + q_offset)[:, None]
    scale = cfg.head_dim ** -0.5

    def step(carry, chunk):
        m, l, acc = carry
        kj, vj, j = chunk
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, kj,
                            preferred_element_type=jnp.float32) * scale
        k_idx = (jnp.arange(kv_chunk, dtype=jnp.int32) + j * kv_chunk)[None, :]
        scores = scores + _mask_bias(q_idx, k_idx, causal=causal, window=window)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(vj.dtype), vj).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, K, G, Sq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kc, vc, jnp.arange(n_chunks, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dh).astype(q.dtype)


def attend(cfg, q, k, v, *, causal=True, window=0, q_offset=0, impl="auto",
           kv_valid_len=None):
    if impl == "auto":
        big = q.shape[1] * k.shape[1] > (1 << 22) or k.shape[1] > 2048
        impl = "blocked" if big and kv_valid_len is None else "naive"
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(cfg, q, k, v, causal=causal, window=window,
                                    q_offset=q_offset)
    if impl == "blocked":
        return attend_blocked(cfg, q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    return attend_naive(cfg, q, k, v, causal=causal, window=window,
                        q_offset=q_offset, kv_valid_len=kv_valid_len)


def apply_attention(cfg, p, x, positions, *, causal=True, window=0, impl="auto"):
    """Self-attention over x [B,S,D]."""
    with jax.named_scope("attn"):
        q, k, v = project_qkv(cfg, p, x, x, positions, positions)
        out = attend(cfg, q, k, v, causal=causal, window=window, impl=impl)
        dt = x.dtype
        return jnp.einsum("bsz,zd->bsd",
                          out.reshape(*out.shape[:2], -1), p["wo"].astype(dt))


def apply_cross_attention(cfg, p, x, memory_kv):
    """Cross-attention: queries from x, cached (k, v) from encoder memory."""
    with jax.named_scope("cross_attn"):
        dt = x.dtype
        B, Sq, _ = x.shape
        H, Dh = cfg.num_heads, cfg.head_dim
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt)).reshape(B, Sq, H, Dh)
        k, v = memory_kv
        out = attend(cfg, q, k, v, causal=False, window=0, impl="auto")
        return jnp.einsum("bsz,zd->bsd", out.reshape(B, Sq, -1), p["wo"].astype(dt))


def encode_memory_kv(cfg, p, memory):
    """Precompute cross-attention K/V from encoder output [B,Sm,D]."""
    dt = memory.dtype
    B, Sm, _ = memory.shape
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dh->bsh", memory, p["wk"].astype(dt)).reshape(B, Sm, K, Dh)
    v = jnp.einsum("bsd,dh->bsh", memory, p["wv"].astype(dt)).reshape(B, Sm, K, Dh)
    return k, v


# --------------------------------------------------------------------------
# decode (single new token against a cache)
# --------------------------------------------------------------------------

def decode_attention(cfg, p, x, cache_k, cache_v, pos, *, window=0,
                     windowed_cache=False, positions=None):
    """One-token self-attention against a KV cache.

    x        [B, 1, D]; pos scalar int32 (current position)
    cache_k/v [B, Sc, K, Dh]  (Sc = full seq or window size)
    positions: rope ids override ([B,1], or [3,B,1] m-rope)
    Returns (out [B,1,D], new_cache_k, new_cache_v).
    """
    with jax.named_scope("attn_decode"):
        dt = x.dtype
        B = x.shape[0]
        H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        Sc = cache_k.shape[1]
        if positions is None:
            positions = jnp.full((B, 1), pos, jnp.int32)
        q, k_new, v_new = project_qkv(cfg, p, x, x, positions, positions)
        slot = jnp.mod(pos, Sc) if windowed_cache else pos
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), slot, axis=1)
        if windowed_cache:
            # ring buffer: every slot holds a key within the window (or the
            # slot was just written); all valid once warm.  RoPE was applied
            # at write time so ordering does not matter.  Cold-start slots
            # (pos < Sc) are masked by validity.
            out = attend_naive(cfg, q, cache_k.astype(dt), cache_v.astype(dt),
                               causal=False, window=None,
                               kv_valid_len=jnp.minimum(pos + 1, Sc))
        else:
            # full cache: slot index == absolute position, so causal + window
            # masking with q_offset=pos covers validity too (k_idx <= pos).
            out = attend_naive(cfg, q, cache_k.astype(dt), cache_v.astype(dt),
                               causal=True, window=window, q_offset=pos)
        y = jnp.einsum("bsz,zd->bsd", out.reshape(B, 1, -1), p["wo"].astype(dt))
        return y, cache_k, cache_v
