"""Tracer orchestration: compile (or accept compiled) -> assemble a Trace.

Pipeline (the paper's Fig 2, compile-time edition):
  (1) lower + partition the step           (jit .lower().compile())
  (2) parse collectives out of the HLO     (hlo_parser  — "recording UCT")
  (3) resolve groups onto the mesh         (topology    — transport/NIC attribution)
  (4) model completions                    (costmodel   — completion tracking)
  (5) attribute scopes/semantics           (attribution — UCP/MPI attribution)
  (6) aggregate + render                   (report      — log processing + viz)
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

from repro.core import attribution, costmodel, hlo_parser
from repro.core.events import Trace
from repro.core.topology import Hardware, MeshSpec, V5E


def trace_from_hlo(hlo_text: str, mesh: MeshSpec, *, label: str = "step",
                   hw: Hardware = V5E,
                   cost_analysis: Optional[Dict[str, float]] = None,
                   memory_analysis: Any = None,
                   engine: str = "columnar",
                   shards: Optional[int] = None,
                   shard_workers: Optional[int] = None,
                   recover: bool = False) -> Trace:
    """Assemble a multi-layer trace from compiled HLO text.

    `engine` selects the ingest pipeline:
      * `"columnar"` (default) — single-pass parse straight into
        `TraceStore` columns, batched cost model + vocab-level attribution
        (`annotate_store` / `attribute_store`); event rows stay lazy.
      * `"rows"` — the per-event reference path (dataclass per site,
        `annotate_event` / `attribute_event` per event).  Kept as the
        equivalence baseline; see tests/test_ingest.py.

    `shards` (columnar only) splits one giant module per-computation
    across worker processes (`hlo_parser.parse_hlo_store_sharded`), with
    the shard stores merged back byte-identically to a serial parse.
    `None` auto-shards above `hlo_parser.AUTO_SHARD_BYTES`; `1` forces
    the serial path.  `shard_workers` caps the pool (0 = in-process).

    `recover=True` (columnar only) ingests a damaged module through
    salvage parsing (`parse_hlo_store(recover=True)`): instead of
    raising on truncated/corrupted input, the intact computations are
    kept and `trace.salvage` carries the `SalvageReport` of what was
    dropped.  Salvage always parses serially — a damaged module must
    not be sharded across workers on unverified boundaries.
    """
    salvage = None
    if engine == "columnar":
        n_shards = shards if shards is not None \
            else hlo_parser.auto_shards(len(hlo_text))
        if recover:
            store, stats, salvage = hlo_parser.parse_hlo_store(
                hlo_text, mesh.num_devices, recover=True)
        elif n_shards > 1:
            store, stats = hlo_parser.parse_hlo_store_sharded(
                hlo_text, mesh.num_devices, n_shards,
                max_workers=shard_workers)
        else:
            store, stats = hlo_parser.parse_hlo_store(
                hlo_text, mesh.num_devices)
        costmodel.annotate_store(store, mesh, hw)
        attribution.attribute_store(store)
        tr = Trace.from_store(label, mesh.shape, mesh.axes, mesh.num_devices,
                              store, op_stats=stats)
        tr.salvage = salvage
    elif engine == "rows":
        events, stats = hlo_parser.parse_hlo(hlo_text, mesh.num_devices)
        for ev in events:
            costmodel.annotate_event(ev, mesh, hw)
        attribution.attribute_all(events)
        tr = Trace(label=label, mesh_shape=mesh.shape, mesh_axes=mesh.axes,
                   num_devices=mesh.num_devices, events=events, op_stats=stats)
    else:
        raise ValueError(f"unknown ingest engine: {engine!r}")
    # loop-aware parsed totals are authoritative (cost_analysis counts while
    # bodies once); fall back to cost_analysis when parsing finds nothing.
    tr.hlo_flops = float(stats.flops)
    tr.hlo_bytes = float(stats.bytes_accessed)
    if isinstance(cost_analysis, (list, tuple)):
        # older jax: Compiled.cost_analysis() returns [per-module dict]
        cost_analysis = cost_analysis[0] if cost_analysis else None
    if cost_analysis:
        ca_flops = float(cost_analysis.get("flops", 0.0))
        ca_bytes = float(cost_analysis.get("bytes accessed", 0.0))
        tr.hlo_flops = max(tr.hlo_flops, ca_flops)
        tr.hlo_bytes = max(tr.hlo_bytes, ca_bytes)
    if memory_analysis is not None:
        tr.per_device_memory_bytes = float(
            getattr(memory_analysis, "temp_size_in_bytes", 0)
            + getattr(memory_analysis, "argument_size_in_bytes", 0)
            + getattr(memory_analysis, "output_size_in_bytes", 0)
            - getattr(memory_analysis, "alias_size_in_bytes", 0))
        tr.argument_bytes = float(
            getattr(memory_analysis, "argument_size_in_bytes", 0))
        tr.output_bytes = float(
            getattr(memory_analysis, "output_size_in_bytes", 0))
    return tr


@dataclass
class TraceResult:
    trace: Trace
    compiled: Any
    lowered: Any
    lower_s: float
    compile_s: float
    parse_s: float
    hlo_chars: int


def trace_compiled(compiled, mesh: MeshSpec, *, label: str = "step",
                   hw: Hardware = V5E) -> Trace:
    """Trace an already-compiled step (jax Compiled object)."""
    text = compiled.as_text()
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    tr = trace_from_hlo(text, mesh, label=label, hw=hw,
                        cost_analysis=ca, memory_analysis=ma)
    return tr


def trace_step(fn: Callable, args_specs, mesh_jax, mesh_spec: MeshSpec, *,
               in_shardings=None, out_shardings=None, label="step",
               hw: Hardware = V5E, donate_argnums=()) -> TraceResult:
    """Lower + compile `fn` on `mesh_jax` and assemble the trace."""
    import jax

    t0 = time.perf_counter()
    jfn = jax.jit(fn, in_shardings=in_shardings, out_shardings=out_shardings,
                  donate_argnums=donate_argnums)
    with mesh_jax:
        lowered = jfn.lower(*args_specs)
        t1 = time.perf_counter()
        compiled = lowered.compile()
    t2 = time.perf_counter()
    text = compiled.as_text()
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    tr = trace_from_hlo(text, mesh_spec, label=label, hw=hw,
                        cost_analysis=ca, memory_analysis=ma)
    t3 = time.perf_counter()
    return TraceResult(trace=tr, compiled=compiled, lowered=lowered,
                       lower_s=t1 - t0, compile_s=t2 - t1, parse_s=t3 - t2,
                       hlo_chars=len(text))
