"""Live profiling — tail an HLO-dump directory, ingest deltas, keep
rolling aggregates fresh.

The batch workflow (dump the module, `session ingest`, `session report`)
answers "what did that run do?".  This module answers the live question
— "what is the run doing *now*?" — the way the paper's daemon mode does:
a poller watches the directory a compiler dumps modules into, ingests
each file once it has settled, and folds it into streaming state:

  * a rolling `TraceStore` grown in place with `TraceStore.append`,
  * `IncrementalRollup`s for the Table II traffic-class aggregates,
  * `detect.DetectorState` (dynamic detectors) sufficient statistics,
  * per-file `commcheck` findings (channel ids are *module*-scoped, so
    the static analyzer runs per dump file — folding all files into one
    `CommcheckState` would invent cross-module channel collisions; that
    streaming state is for chunks of a single module),

so every poll re-renders fresh reports in O(delta) work and O(unique
keys) memory, never re-parsing old files.  Outputs (session save,
report JSON/HTML, summary JSON) are all written through
`persist.atomic_open`, so the consumers the daemon exists for — a
browser auto-reloading the HTML, CI collecting artifacts mid-run —
never observe a torn file.

A file is re-ingested when its (size, mtime) signature changes; since
streaming state cannot *subtract* a stale contribution, a changed file
triggers a rebuild from the retained per-file traces (rare; new files
are the hot path and stay incremental).

`run(once=True)` ingests until the directory is quiescent and exits —
the CI/testing mode; the equivalence contract is that its report output
is byte-identical to `session ingest` + `session report` over the final
directory contents.

Fault tolerance (see DESIGN.md "Fault tolerance & salvage ingest"): a
fleet's dump directory contains partially-written, truncated and
corrupted modules as a matter of course, so the daemon never lets one
bad file kill the loop.  A failed ingest is quarantined with
backoff-limited same-signature retries (sealed until the file changes
once exhausted); under the default `errors="salvage"` policy a damaged
module's intact computations are recovered as a partial trace first.
Every outcome lands in a provenance ledger surfaced through
`summary()["ingest"]` and `session().ingest_report`.  With
`WatchConfig.checkpoint` set, the full fold state (retained traces,
watcher signatures, quarantine, ledger) is atomically re-persisted
after every state-changing poll, and a daemon restarted on the same
checkpoint resumes without re-parsing already-ingested files — kill -9
at any instant loses at most the poll in flight.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.core import commcheck, detect
from repro.core.events import HloOpStats, Trace
from repro.core.persist import atomic_open
from repro.core.store import IncrementalRollup, TraceStore
from repro.core.topology import Hardware, MeshSpec, V5E

Sig = Tuple[int, float]     # (size, mtime) file signature


class DirWatcher:
    """Poll-based new/changed-file detection over one dump directory.

    A file is *ready* when its (size, mtime) signature is unchanged
    across two consecutive polls AND its mtime is at least `settle_s`
    old — a writer mid-stream (a compiler still dumping the module)
    fails both tests, so partially-written files are never handed to
    the parser.  A previously-ingested path whose signature changes
    later becomes ready again (changed-file re-ingest).
    """

    def __init__(self, root: str, pattern: str = "*.txt",
                 settle_s: float = 0.25):
        self.root = root
        self.pattern = pattern
        self.settle_s = settle_s
        self._last: Dict[str, Sig] = {}
        self._ingested: Dict[str, Sig] = {}
        # settle clock per signature: the raw mtime, clamped to the poll
        # time that first observed the current signature.  NFS clock
        # skew / touched-into-the-future files would otherwise never
        # settle (now - mtime stays negative); clamping once per
        # signature keeps the readiness test a pure stability judgment
        # without destabilizing the signature itself.
        self._eff_mtime: Dict[str, float] = {}

    def _scan(self) -> Dict[str, Sig]:
        sigs: Dict[str, Sig] = {}
        for path in sorted(glob.glob(os.path.join(self.root, self.pattern))):
            try:
                st = os.stat(path)
            except OSError:
                continue    # deleted between glob and stat
            sigs[path] = (int(st.st_size), float(st.st_mtime))
        return sigs

    def poll(self, now: Optional[float] = None
             ) -> Tuple[List[str], int]:
        """One poll: -> (paths ready to ingest, count still pending).

        Pending counts files that are present but not yet stable —
        first-seen this poll, signature still moving, or settling.
        """
        if now is None:
            now = time.time()
        sigs = self._scan()
        ready: List[str] = []
        pending = 0
        for path, sig in sigs.items():
            if self._last.get(path) != sig:
                self._eff_mtime[path] = min(sig[1], now)
            if self._ingested.get(path) == sig:
                continue
            if self._last.get(path) == sig \
                    and now - self._eff_mtime[path] >= self.settle_s:
                ready.append(path)
            else:
                pending += 1
        self._last = sigs
        self._eff_mtime = {p: m for p, m in self._eff_mtime.items()
                           if p in sigs}
        return ready, pending

    def sig(self, path: str) -> Optional[Sig]:
        """Last-scanned signature of `path` (None if not seen)."""
        return self._last.get(path)

    def mark_ingested(self, path: str) -> None:
        sig = self._last.get(path)
        if sig is not None:
            self._ingested[path] = sig

    def ingested_sigs(self) -> Dict[str, Sig]:
        """Snapshot of the ingested-signature map (checkpointing)."""
        return dict(self._ingested)

    def restore_ingested(self, sigs: Dict[str, Sig]) -> None:
        """Adopt a checkpointed ingested-signature map: files whose
        on-disk signature still matches are never re-offered (and so
        never re-parsed) after a resume."""
        self._ingested = {p: (int(s[0]), float(s[1]))
                          for p, s in sigs.items()}


@dataclasses.dataclass
class WatchConfig:
    root: str
    mesh: MeshSpec
    pattern: str = "*.txt"
    hw: Hardware = V5E
    out: Optional[str] = None           # rolling session save (.json/.npz)
    report_json: Optional[str] = None
    report_html: Optional[str] = None
    summary: Optional[str] = None
    settle_s: float = 0.25
    interval_s: float = 1.0
    once: bool = False
    fail_on: str = "never"
    shards: Optional[int] = None
    max_rounds: Optional[int] = None
    expected_axes: Optional[Dict[str, str]] = None
    quiet: bool = False
    # fault tolerance: per-file failure policy ("salvage" recovers the
    # intact computations of a damaged dump, "skip" quarantines it
    # whole, "raise" crashes the daemon — strict mode), bounded by
    # `max_retries` same-signature re-attempts with exponential backoff
    # before the quarantine seals until the file changes
    errors: str = "salvage"
    max_retries: int = 3
    retry_backoff_s: float = 0.5
    # crash-resume checkpoint (.npz): retained per-file traces + watcher
    # signatures + quarantine/ingest records, atomically rewritten after
    # every state-changing poll; a daemon restarted on the same
    # checkpoint resumes without re-parsing already-ingested files
    checkpoint: Optional[str] = None


class WatchDaemon:
    """The streaming-ingest loop behind `session watch`.

    Drives a `DirWatcher`, parses each ready file through the same
    per-file pipeline batch ingest uses (`tracer.trace_from_hlo`), and
    folds the resulting trace into the rolling aggregates.  `poll_once`
    is the unit tests drive directly; `run` wraps it in the sleep loop
    with `--once` quiescence detection.
    """

    CHECKPOINT_VERSION = 1

    def __init__(self, cfg: WatchConfig):
        if cfg.errors not in ("raise", "skip", "salvage"):
            raise ValueError(f"errors must be 'raise', 'skip' or 'salvage', "
                             f"got {cfg.errors!r}")
        self.cfg = cfg
        self.watcher = DirWatcher(cfg.root, cfg.pattern, cfg.settle_s)
        self._traces: Dict[str, Trace] = {}     # path -> per-file trace
        self._lint: Dict[str, List[detect.Finding]] = {}    # path -> findings
        # path -> IngestRecord-shaped dict (ok/salvaged/quarantined) —
        # the daemon's provenance ledger, mirrored into summary(),
        # session().ingest_report and the checkpoint
        self._records: Dict[str, Dict[str, object]] = {}
        # path -> {"sig": [size, mtime], "failures": n, "error": str,
        #          "retry_at": t}; sealed entries (failures >= max
        # retries) are also marked ingested so they stop being offered
        # until the file's signature changes
        self._quarantine: Dict[str, Dict[str, object]] = {}
        # files actually parsed this process (resume tests assert a
        # restored daemon re-parses nothing)
        self.parse_count = 0
        self.rounds = 0
        self._dirty = False     # state changed since last checkpoint write
        self._changed = False   # state changed since last emit (run loop)
        self._reset_rolling()
        if cfg.checkpoint and os.path.exists(cfg.checkpoint):
            self._load_checkpoint(cfg.checkpoint)

    # -- streaming state -----------------------------------------------------

    def _reset_rolling(self) -> None:
        self.rolling = TraceStore.empty()
        self.rollups = {"kind_link": IncrementalRollup("kind_link"),
                        "semantic": IncrementalRollup("semantic")}
        self.detector = detect.DetectorState(
            expected_axes=self.cfg.expected_axes, hw=self.cfg.hw)
        self.op_stats = HloOpStats()

    def _fold(self, trace: Trace) -> None:
        self.rolling.append(trace.store)
        for roll in self.rollups.values():
            roll.update(trace.store)
        self.detector.update(trace)
        self.op_stats = HloOpStats.merged([self.op_stats, trace.op_stats])

    def _rebuild(self) -> None:
        # streaming state cannot subtract a stale file's contribution;
        # re-fold the retained per-file traces (no re-parse)
        self._reset_rolling()
        for path in sorted(self._traces):
            self._fold(self._traces[path])

    def ingest(self, path: str, attempts: int = 1) -> Trace:
        """Parse one settled file and fold it into the rolling state.

        Strict parse first; under `errors="salvage"` a parse failure
        falls back to salvage recovery (`trace_from_hlo(recover=True)`)
        and the record carries the `SalvageReport`.  Any exception that
        escapes (read failure, strict-mode parse failure, salvage that
        found nothing) is the caller's quarantine signal.
        """
        from repro.core.tracer import trace_from_hlo
        with open(path) as f:
            text = f.read()
        label = os.path.splitext(os.path.basename(path))[0]
        changed = path in self._traces
        self.parse_count += 1
        rec = {"source": path, "label": label, "status": "ok",
               "attempts": attempts, "error": "", "salvage": None}
        try:
            trace = trace_from_hlo(text, self.cfg.mesh, label=label,
                                   hw=self.cfg.hw, shards=self.cfg.shards)
        except Exception as e:
            if self.cfg.errors != "salvage":
                raise
            trace = trace_from_hlo(text, self.cfg.mesh, label=label,
                                   hw=self.cfg.hw, recover=True)
            rec["status"] = "salvaged"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["salvage"] = trace.salvage.to_dict() \
                if trace.salvage is not None else None
        self._traces[path] = trace
        self._records[path] = rec
        self._quarantine.pop(path, None)
        # static analysis is per module: one CommcheckState per file,
        # findings cached until the file itself changes
        st = commcheck.CommcheckState(self.cfg.mesh)
        st.update(trace.store)
        self._lint[path] = st.findings()
        if changed:
            self._rebuild()
        else:
            self._fold(trace)
        self._dirty = self._changed = True
        return trace

    def _quarantine_file(self, path: str, err: BaseException,
                         now: float) -> None:
        """Record a failed ingest: backoff-limited same-signature
        retries, sealed (until the signature changes) once exhausted."""
        sig = self.watcher.sig(path)
        q = self._quarantine.get(path)
        failures = (int(q["failures"]) if q else 0) + 1
        self._quarantine[path] = {
            "sig": list(sig) if sig is not None else None,
            "failures": failures,
            "error": f"{type(err).__name__}: {err}",
            "retry_at": now + self.cfg.retry_backoff_s * (1 << (failures - 1)),
        }
        label = os.path.splitext(os.path.basename(path))[0]
        self._records[path] = {
            "source": path, "label": label, "status": "quarantined",
            "attempts": failures, "error": f"{type(err).__name__}: {err}",
            "salvage": None}
        # a changed file that now fails loses its stale contribution —
        # batch ingest over the final directory would not have it either
        if path in self._traces:
            del self._traces[path]
            self._lint.pop(path, None)
            self._rebuild()
        if failures >= self.cfg.max_retries:
            # sealed: stop re-offering this signature; a new signature
            # (the writer finishing / a fixed dump) re-opens it
            self.watcher.mark_ingested(path)
        self._dirty = self._changed = True

    def poll_once(self, now: Optional[float] = None) -> Tuple[List[str], int]:
        """One watcher poll + ingest of everything ready.

        Quarantined files gate on their retry backoff (counted as
        pending while waiting); any per-file exception quarantines that
        file instead of killing the loop — unless `errors="raise"`.
        The checkpoint (when configured) is rewritten atomically after
        every state-changing poll.
        """
        if now is None:
            now = time.time()
        ready, pending = self.watcher.poll(now)
        ingested: List[str] = []
        for path in ready:
            q = self._quarantine.get(path)
            if q is not None and q.get("sig") is not None \
                    and tuple(q["sig"]) == self.watcher.sig(path):
                if now < float(q["retry_at"]):
                    pending += 1    # backoff not elapsed: try next poll
                    continue
            elif q is not None:
                q["failures"] = 0   # signature changed: fresh start
            attempts = (int(q["failures"]) if q else 0) + 1
            try:
                self.ingest(path, attempts=attempts)
                self.watcher.mark_ingested(path)
                ingested.append(path)
            except Exception as e:
                if self.cfg.errors == "raise":
                    raise
                self._quarantine_file(path, e, now)
                sealed = self.watcher.ingested_sigs().get(path) \
                    == self.watcher.sig(path)
                if not sealed:
                    pending += 1    # retry still scheduled
        self.rounds += 1
        if self.cfg.checkpoint and self._dirty:
            self.save_checkpoint(self.cfg.checkpoint)
        return ingested, pending

    # -- derived views -------------------------------------------------------

    def session(self):
        from repro.core.session import TraceSession
        name = os.path.basename(os.path.abspath(self.cfg.root)) or "watch"
        sess = TraceSession(name,
                            [self._traces[p] for p in sorted(self._traces)])
        sess.ingest_report = self.ingest_report()
        return sess

    def ingest_report(self):
        """The daemon's provenance ledger as a `session.IngestReport`."""
        from repro.core.session import IngestRecord, IngestReport
        return IngestReport(
            errors=self.cfg.errors,
            records=[IngestRecord.from_dict(self._records[p])
                     for p in sorted(self._records)])

    def degraded(self) -> List[str]:
        """Paths whose latest outcome is not a clean parse."""
        return [p for p in sorted(self._records)
                if self._records[p]["status"] != "ok"]

    def findings(self) -> List[detect.Finding]:
        """Static (per-module commcheck) + dynamic (detector) findings."""
        out: List[detect.Finding] = []
        for path in sorted(self._lint):
            out.extend(self._lint[path])
        out.extend(self.detector.findings())
        return detect.rank_findings(out)

    def alerts(self) -> List[detect.Finding]:
        if self.cfg.fail_on == "never":
            return []
        rank = detect.SEVERITY_RANK
        return [f for f in self.findings()
                if rank.get(f.severity, 99) <= rank[self.cfg.fail_on]]

    def summary(self) -> Dict[str, object]:
        return {
            "root": self.cfg.root,
            "files": len(self._traces),
            "sites": int(self.rolling.n),
            "rounds": self.rounds,
            "by_kind_link": self.rollups["kind_link"].as_dict(),
            "by_semantic": self.rollups["semantic"].as_dict(),
            "findings": [f.to_dict() for f in self.findings()],
            "ingest": {
                "errors": self.cfg.errors,
                "records": [self._records[p] for p in sorted(self._records)],
                "degraded": self.degraded(),
                "quarantined": sorted(self._quarantine),
                # files parsed by THIS process — a resumed daemon counts
                # only the delta, the resume tests' zero-re-parse witness
                "parse_count": self.parse_count,
            },
        }

    # -- crash-resume checkpoint ---------------------------------------------

    def save_checkpoint(self, path: str) -> str:
        """Atomically persist everything a restarted daemon needs.

        Same npz layout as a session save — `t{i}_`-prefixed store
        arrays over the retained per-file traces (sorted by path) plus
        one JSON side blob (`"watch"`) holding trace metadata, the
        watcher's ingested-signature map, cached lint findings, the
        quarantine and the provenance records.  Written through
        `persist.atomic_open` via the deterministic parallel npz writer
        (`persist.write_npz`), so a daemon killed mid-write leaves the
        previous complete checkpoint behind and per-poll re-saves of an
        unchanged state produce byte-identical files.
        """
        import numpy as np
        from repro.core.persist import write_npz
        from repro.core.session import _trace_meta
        paths = sorted(self._traces)
        arrs: Dict[str, object] = {}
        for i, p in enumerate(paths):
            arrs.update(self._traces[p].store.npz_arrays(prefix=f"t{i}_"))
        arrs["watch"] = np.array(json.dumps({
            "version": self.CHECKPOINT_VERSION,
            "root": self.cfg.root,
            "pattern": self.cfg.pattern,
            "paths": paths,
            "traces": [_trace_meta(self._traces[p]) for p in paths],
            "ingested": {p: list(s)
                         for p, s in self.watcher.ingested_sigs().items()},
            "lint": {p: [f.to_dict() for f in fs]
                     for p, fs in self._lint.items()},
            "quarantine": self._quarantine,
            "records": self._records,
            "rounds": self.rounds,
        }))
        with atomic_open(path, "wb") as f:
            write_npz(f, arrs)
        self._dirty = False
        return path

    def _load_checkpoint(self, path: str) -> None:
        """Resume from a checkpoint; tolerant — an unreadable or
        incompatible checkpoint logs a warning and starts fresh rather
        than wedging the daemon."""
        import numpy as np
        from repro.core.session import _trace_from_meta
        from repro.core.store import TraceStore
        try:
            with np.load(path) as arrs:
                side = json.loads(str(arrs["watch"]))
                if int(side.get("version", -1)) > self.CHECKPOINT_VERSION:
                    raise ValueError(
                        f"checkpoint version {side.get('version')} is newer "
                        f"than supported ({self.CHECKPOINT_VERSION})")
                traces = {
                    p: _trace_from_meta(
                        meta, TraceStore.from_npz_arrays(arrs,
                                                         prefix=f"t{i}_"))
                    for i, (p, meta) in enumerate(zip(side["paths"],
                                                      side["traces"]))}
        except Exception as e:
            self._log(f"[watch] ignoring unusable checkpoint {path}: "
                      f"{type(e).__name__}: {e}")
            return
        self._traces = traces
        self._lint = {p: [detect.Finding.from_dict(d) for d in fs]
                      for p, fs in side.get("lint", {}).items()}
        self._quarantine = side.get("quarantine", {})
        self._records = side.get("records", {})
        self.rounds = int(side.get("rounds", 0))
        self.watcher.restore_ingested(side.get("ingested", {}))
        self._rebuild()

    # -- output --------------------------------------------------------------

    def emit(self) -> None:
        """Re-write every configured artifact (all atomic replaces)."""
        cfg = self.cfg
        sess = self.session() if (cfg.out or cfg.report_json
                                  or cfg.report_html) else None
        if cfg.out:
            sess.save(cfg.out)
        for path, fmt in ((cfg.report_json, "json"),
                          (cfg.report_html, "html")):
            if path and len(sess):
                with atomic_open(path, "w") as fp:
                    sess.report(fmt=fmt, fp=fp)
        if cfg.summary:
            with atomic_open(cfg.summary, "w") as fp:
                json.dump(self.summary(), fp, indent=1)
                fp.write("\n")

    def _log(self, msg: str) -> None:
        if not self.cfg.quiet:
            print(msg, flush=True)

    # -- the loop ------------------------------------------------------------

    def run(self) -> int:
        """Poll until interrupted (daemon) or quiescent (`once`).

        `once` exits after a poll that found nothing ready *and*
        nothing pending, with at least two polls total (a pre-existing
        file needs two polls to prove stability).  Exit code: 1 when
        any finding reached `fail_on` severity, else 3 when any input
        was salvaged or quarantined (degraded ingest), else 0.
        """
        cfg = self.cfg
        emitted = False
        try:
            while True:
                ready, pending = self.poll_once()
                if self._changed or not emitted:
                    self.emit()
                    emitted = True
                    self._changed = False
                    self._log(f"[watch] round {self.rounds}: "
                              f"+{len(ready)} file(s), "
                              f"{len(self._traces)} total, "
                              f"{self.rolling.n} sites, "
                              f"{pending} pending")
                if cfg.once and not ready and not pending \
                        and self.rounds >= 2:
                    break
                if cfg.max_rounds is not None \
                        and self.rounds >= cfg.max_rounds:
                    break
                time.sleep(cfg.interval_s)
        except KeyboardInterrupt:
            self._log("[watch] interrupted")
        self.emit()
        alerts = self.alerts()
        for f in alerts:
            where = f" @ {f.site}" if f.site else ""
            print(f"[watch] ALERT [{f.severity}] {f.detector}{where}: "
                  f"{f.message}", file=sys.stderr)
        if alerts:
            return 1
        for p in self.degraded():
            r = self._records[p]
            print(f"[watch] ingest [{r['status']}] {p}: {r['error']}",
                  file=sys.stderr)
        return 3 if self.degraded() else 0
