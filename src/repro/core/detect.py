"""Performance-bug detectors — the paper's Fig 7 (NUMA misbinding) analogue.

On an IB/GPU cluster the classic silent misconfiguration is traffic taking a
host detour because of process placement.  On a TPU mesh the analogue is
traffic taking an *axis* detour because of bad PartitionSpecs.  Each detector
inspects the assembled trace and returns human-actionable findings.

Detectors scan the columnar `TraceStore`: candidate filtering is a numpy
mask over interned code columns, and only the (few) survivors are
materialized as rows for message construction — on 100k-event traces the
scans no longer walk Python objects.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.core.events import Trace
from repro.core.topology import Hardware, V5E

# severity -> rank; lower sorts first.  Shared by the dynamic detectors
# below and the static analyzer (commcheck) — one ordering, one schema.
SEVERITY_RANK: Dict[str, int] = {"critical": 0, "warn": 1, "info": 2}


@dataclass
class Finding:
    """One diagnostic, shared between the dynamic detectors and the
    static analyzer (`commcheck`).

    `detector` doubles as the stable finding code (`session lint --json`
    / `session detect --json` key consumers match on), `site` anchors the
    finding to an op / channel / spec path, and `wasted_bytes` /
    `time_at_risk_s` carry the cost-model ranking weight.
    """

    detector: str
    severity: str          # info | warn | critical
    message: str
    wasted_bytes: float = 0.0
    site: str = ""
    time_at_risk_s: float = 0.0

    def __str__(self):
        return f"[{self.severity}] {self.detector}: {self.message}"

    def to_dict(self) -> Dict[str, object]:
        """The stable JSON schema (identical for `lint` and `detect`)."""
        return {
            "analyzer": self.detector,
            "severity": self.severity,
            "site": self.site,
            "message": self.message,
            "wasted_bytes": float(self.wasted_bytes),
            "time_at_risk_s": float(self.time_at_risk_s),
        }


def rank_findings(findings: List[Finding]) -> List[Finding]:
    """Severity-major, wire-bytes-at-risk-minor ordering (stable)."""
    return sorted(findings,
                  key=lambda f: (SEVERITY_RANK.get(f.severity, 99),
                                 -f.wasted_bytes))


def detect_redundant_gathers(trace: Trace) -> List[Finding]:
    """Same tensor gathered more than once per execution context.

    (ucTrace: repeated identical UCT transfers within one MPI call.)
    """
    s = trace.store
    cand = s.kind.mask_of("all-gather", "all-reduce") \
        & (s.operand_bytes > (1 << 20))
    idx = np.flatnonzero(cand)
    if len(idx) < 2:
        return []
    # composite (kind, bytes, link, scope, computation) key per candidate
    key = np.zeros(len(idx), dtype=np.int64)
    for cat in (s.kind, s.link_class, s.scope, s.computation):
        key = key * len(cat.vocab) + cat.codes[idx]
    _, uniq_bytes = np.unique(s.operand_bytes[idx], return_inverse=True)
    key = key * (uniq_bytes.max() + 1) + uniq_bytes
    uniq, inv, counts = np.unique(key, return_inverse=True, return_counts=True)
    out = []
    for g in np.flatnonzero(counts > 1):
        members = idx[inv == g]
        last = int(members[-1])
        count = int(counts[g])
        nbytes = int(s.operand_bytes[last])
        wasted = (count - 1) * nbytes * int(s.multiplicity[last])
        out.append(Finding(
            "redundant_collective", "warn",
            f"{count}x identical {s.kind.value(last)} of {nbytes/1e6:.1f} MB "
            f"on {s.link_class.value(last)} "
            f"(scope '{s.scope.value(last) or '-'}', "
            f"comp '{s.computation.value(last)}') — candidates for CSE "
            f"or re-materialization of the gathered value",
            wasted_bytes=wasted, site=s.scope.value(last)))
    return out


def detect_axis_detours(trace: Trace, expected: Dict[str, str],
                        min_bytes: int = 1 << 20) -> List[Finding]:
    """Collectives spanning mesh axes their semantic class should not touch.

    `expected` maps semantic class -> axis name it should stay on
    (e.g. {"grad_sync": "data", "moe_dispatch": "model"}).  A grad-sync that
    crosses `model`, or TP traffic crossing `pod`, is the sharding analogue
    of NUMA-misbound traffic routed through remote NICs.  Sub-MB payloads
    (scalar metric reductions, grad-norm psums) are exempt.
    """
    s = trace.store
    cand = s.semantic.mask_of(*expected) \
        & (s.operand_bytes * s.multiplicity >= min_bytes)
    out = []
    for i in np.flatnonzero(cand):
        axes = s.axes[i]
        if not axes:
            continue
        want = expected[s.semantic.value(i)]
        if any(a != want for a in axes):
            nbytes = int(s.operand_bytes[i])
            out.append(Finding(
                "axis_detour", "warn",
                f"{s.semantic.value(i)} {s.kind.value(i)} "
                f"({nbytes/1e6:.1f} MB) spans "
                f"axes {axes}, expected only '{want}' — check the "
                f"PartitionSpec feeding scope '{s.scope.value(i) or '-'}'",
                wasted_bytes=nbytes * int(s.multiplicity[i]),
                site=s.scope.value(i)))
    return out


def detect_eager_floods(trace: Trace, hw: Hardware = V5E,
                        min_count: int = 64) -> List[Finding]:
    """Many tiny latency-bound transfers (the eager-protocol flood).

    (ucTrace Fig 4/6: am_short floods where rendezvous would batch.)
    """
    s = trace.store
    mask = s.protocol.mask_of("eager")
    n = int(s.multiplicity[mask].sum())
    if n >= min_count:
        lat = float((s.est_time_s[mask] * s.weights[mask]).sum())
        return [Finding(
            "eager_flood", "info",
            f"{n} latency-bound collectives/step (< {hw.rndv_threshold/1024:.0f} KiB "
            f"payload/shard), ~{lat*1e6:.0f} us serialized latency — consider "
            f"fusing/batching small collectives or increasing scan body size",
            time_at_risk_s=lat)]
    return []


def detect_layout_thrash(trace: Trace, threshold_bytes: float = 1 << 30) -> List[Finding]:
    """Heavy transpose/copy traffic around sharded ops (layout mismatch)."""
    tb = trace.op_stats.transpose_bytes
    if tb > threshold_bytes:
        return [Finding(
            "layout_thrash", "info",
            f"{tb/1e9:.2f} GB of transpose/copy traffic "
            f"({trace.op_stats.n_transpose} ops) — review operand layouts or "
            f"einsum dimension orders adjacent to collectives")]
    return []


def detect_cross_pod_bulk(trace: Trace) -> List[Finding]:
    """Bulk traffic on the slow inter-pod DCI that could stay intra-pod."""
    s = trace.store
    mask = s.link_class.mask_prefix(("dci", "xpod"))
    total = float((s.wire_total[mask] * s.weights[mask]).sum())
    out = []
    if total > 1 << 30:
        out.append(Finding(
            "cross_pod_bulk", "warn",
            f"{total/1e9:.2f} GB/step crosses the inter-pod DCI "
            f"({int(mask.sum())} collectives) — hierarchical reduction "
            f"(in-pod reduce-scatter, cross-pod exchange of 1/pod_size) or "
            f"gradient compression recommended"))
    return out


def run_all(trace: Trace, expected_axes: Dict[str, str] | None = None,
            hw: Hardware = V5E) -> List[Finding]:
    """All detectors, ranked critical > warn > info, bytes-at-risk within."""
    findings = []
    findings += detect_redundant_gathers(trace)
    if expected_axes:
        findings += detect_axis_detours(trace, expected_axes)
    findings += detect_eager_floods(trace, hw)
    findings += detect_layout_thrash(trace)
    findings += detect_cross_pod_bulk(trace)
    return rank_findings(findings)
