"""mixtral-8x22b — sparse MoE decoder, 8 experts top-2, SWA. [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,                # dense d_ff unused; experts use moe_d_ff
    vocab_size=32768,
    rope_theta=1_000_000.0,
    window=4096,               # sliding-window attention
    num_experts=8,
    top_k=2,
    moe_d_ff=16384,
    notes="every layer MoE; 8 experts < model axis (16) => expert-TP sharding",
)
