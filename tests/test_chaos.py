"""Chaos suite: fault injection over salvage parsing, batch ingest and
the watch daemon.

The contract under test (ISSUE: fault-tolerant fleet ingest): for ANY
damaged input — truncated, spliced with garbage, line-mangled, binary —
no entry point crashes, clean inputs come through byte-identical, and
every degraded input is accounted for in the machine-readable ingest
provenance (never silently dropped).
"""
import json
import os

import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core import hlo_parser
from repro.core.hlo_parser import SalvageReport, parse_hlo_store
from repro.core.session import TraceSession, IngestError, _main
from repro.core.synth import (CORRUPT_MODES, corrupt_hlo, synthetic_hlo,
                              write_corrupt_dump)
from repro.core.topology import MeshSpec
from repro.core.tracer import trace_from_hlo
from repro.core.watch import WatchConfig, WatchDaemon

MESH = MeshSpec((2, 4), ("data", "model"))
N = MESH.num_devices

TEXT = synthetic_hlo(n_sites=120, seed=11)
STRICT_STORE, _ = parse_hlo_store(TEXT, N)          # parse-level reference
CLEAN_TRACE = trace_from_hlo(TEXT, MESH, label="clean")   # full pipeline


# -- salvage parsing: the recover=True contract ------------------------------

def test_salvage_of_clean_text_is_lossless():
    store, stats, rep = parse_hlo_store(TEXT, N, recover=True)
    assert isinstance(rep, SalvageReport)
    assert rep.clean and rep.bytes_skipped == 0 and rep.dropped == []
    assert store.identical(STRICT_STORE)


@settings(max_examples=60, deadline=None)
@given(k=st.integers(min_value=0, max_value=len(TEXT)))
def test_salvage_never_raises_for_any_truncation(k):
    """Property: salvage of text[:k] never raises, never keeps rows from
    a computation the report says it dropped, and accounts for every
    skipped byte."""
    store, stats, rep = parse_hlo_store(TEXT[:k], N, recover=True)
    assert store.n <= STRICT_STORE.n
    dropped = set(rep.dropped)
    for row in store.rows():
        assert "%" + row.computation not in dropped \
            and row.computation not in dropped
    assert 0 <= rep.bytes_skipped <= rep.total_bytes == k
    assert rep.computations_dropped == len(rep.dropped)
    if rep.computations_dropped or rep.bytes_skipped:
        assert rep.first_error
    # full-length truncation is the identity
    if k == len(TEXT):
        assert rep.clean and store.identical(STRICT_STORE)


@pytest.mark.parametrize("mode", CORRUPT_MODES)
def test_salvage_never_raises_for_any_injector(mode):
    data = corrupt_hlo(TEXT, mode, seed=7)
    if isinstance(data, bytes):     # undecodable: the read layer's problem
        pytest.skip("binary corruption is rejected at read time")
    store, stats, rep = parse_hlo_store(data, N, recover=True)
    assert rep.to_dict()["computations_dropped"] == len(rep.dropped)


def test_salvage_report_round_trips_to_dict():
    data = corrupt_hlo(TEXT, "mangle_rg", seed=7)
    with pytest.raises(ValueError):
        parse_hlo_store(data, N)            # strict mode still raises
    store, _stats, rep = parse_hlo_store(data, N, recover=True)
    assert rep.dropped and not rep.clean
    d = rep.to_dict()
    assert d["dropped"] == rep.dropped
    assert json.loads(json.dumps(d)) == d   # JSON-safe


def test_trace_from_hlo_recover_carries_salvage_report():
    data = corrupt_hlo(TEXT, "mangle_rg", seed=7)
    tr = trace_from_hlo(data, MESH, recover=True)
    assert tr.salvage is not None and tr.salvage.dropped
    clean = trace_from_hlo(TEXT, MESH, recover=True)
    assert clean.salvage is not None and clean.salvage.clean


# -- batch ingest over a corrupt dump directory ------------------------------

@pytest.fixture()
def chaos_dir(tmp_path):
    clean = os.path.join(str(tmp_path), "clean.txt")
    with open(clean, "w") as f:
        f.write(TEXT)
    write_corrupt_dump(str(tmp_path), seed=4)
    return str(tmp_path)


def _files(root):
    return sorted(os.path.join(root, f) for f in os.listdir(root)
                  if f.endswith(".txt"))


def test_batch_salvage_accounts_for_every_input(chaos_dir):
    files = _files(chaos_dir)
    sess = TraceSession.from_hlo("chaos", files, MESH, max_workers=1,
                                 errors="salvage", retries=0,
                                 retry_backoff_s=0)
    rep = sess.ingest_report
    assert [r.source for r in rep.records] == files     # nothing silent
    by_src = {os.path.basename(r.source): r for r in rep.records}
    assert by_src["clean.txt"].status == "ok"
    # the clean file is byte-identical to a solo strict ingest
    assert sess.get("clean").store.identical(CLEAN_TRACE.store)
    for r in rep.degraded:
        assert r.error, r
        assert r.status in ("salvaged", "quarantined")
    for r in rep.records:
        if r.status == "salvaged":
            assert r.salvage is not None and not r.salvage["clean"]
    # undecodable bytes defeat even salvage
    assert by_src["corrupt_binary.txt"].status == "quarantined"


def test_batch_skip_drops_without_salvaging(chaos_dir):
    files = _files(chaos_dir)
    sess = TraceSession.from_hlo("chaos", files, MESH, max_workers=1,
                                 errors="skip", retries=0, retry_backoff_s=0)
    assert not any(r.status == "salvaged"
                   for r in sess.ingest_report.records)
    assert "clean" in sess.labels()


def test_batch_raise_mode_rejects_corrupt_dir(chaos_dir):
    with pytest.raises(IngestError):
        TraceSession.from_hlo("chaos", _files(chaos_dir), MESH,
                              max_workers=1)


def test_batch_pool_salvage_matches_serial_salvage(chaos_dir, monkeypatch):
    import concurrent.futures as cf

    class FakeFuture:
        def __init__(self, fn, *args):
            self._fn, self._args = fn, args

        def result(self, timeout=None):
            return self._fn(*self._args)

    class FakePool:
        def __init__(self, *a, **k):
            pass

        def submit(self, fn, *args):
            return FakeFuture(fn, *args)

        def shutdown(self, *a, **k):
            pass

    files = _files(chaos_dir)
    serial = TraceSession.from_hlo("chaos", files, MESH, max_workers=1,
                                   errors="salvage", retries=0,
                                   retry_backoff_s=0)
    monkeypatch.setattr(cf, "ProcessPoolExecutor", FakePool)
    pooled = TraceSession.from_hlo("chaos", files, MESH, max_workers=2,
                                   errors="salvage", retries=0,
                                   retry_backoff_s=0)
    assert pooled.labels() == serial.labels()
    for lab in serial.labels():
        assert pooled.get(lab).store.identical(serial.get(lab).store)
    assert [r.to_dict() for r in pooled.ingest_report.records] == \
        [r.to_dict() for r in serial.ingest_report.records]


def test_pool_timeout_falls_back_serial_then_quarantines(monkeypatch):
    """A hung worker (simulated: every pool result times out) kills the
    pool; files retry serially — good ones ingest, bad ones quarantine."""
    import concurrent.futures as cf

    class HungFuture:
        def result(self, timeout=None):
            raise cf.TimeoutError()

    class HungPool:
        def __init__(self, *a, **k):
            self._probed = False

        def submit(self, fn, *args):
            if not self._probed:        # let the startup probe pass
                self._probed = True
                f = HungFuture()
                f.result = lambda timeout=None: fn(*args)
                return f
            return HungFuture()

        def shutdown(self, *a, **k):
            pass

    monkeypatch.setattr(cf, "ProcessPoolExecutor", HungPool)
    items = [("good", TEXT), ("bad", corrupt_hlo(TEXT, "mangle_rg", seed=3))]
    sess = TraceSession.from_hlo("s", items, MESH, max_workers=2,
                                 errors="skip", retries=0, retry_backoff_s=0,
                                 timeout_s=0.01)
    assert sess.labels() == ["good"]
    statuses = {r.source: r.status for r in sess.ingest_report.records}
    assert statuses == {"good": "ok", "bad": "skipped"}


# -- the watch daemon over the same chaos directory --------------------------

def drain(daemon, max_polls=40):
    for _ in range(max_polls):
        ready, pending = daemon.poll_once()
        if not ready and not pending:
            return
    raise AssertionError("directory never became quiescent")


def test_daemon_survives_chaos_dir_and_reports_everything(chaos_dir):
    d = WatchDaemon(WatchConfig(root=chaos_dir, mesh=MESH, settle_s=0.0,
                                quiet=True, max_retries=1,
                                retry_backoff_s=0.0))
    drain(d)
    recs = {os.path.basename(p): r for p, r in d._records.items()}
    assert set(recs) == {os.path.basename(p) for p in _files(chaos_dir)}
    assert recs["clean.txt"]["status"] == "ok"
    assert d._traces[os.path.join(chaos_dir, "clean.txt")] \
        .store.identical(CLEAN_TRACE.store)
    assert recs["corrupt_binary.txt"]["status"] == "quarantined"
    summ = d.summary()
    assert summ["ingest"]["quarantined"] \
        == [os.path.join(chaos_dir, "corrupt_binary.txt")]
    for rec in summ["ingest"]["records"]:
        if rec["status"] != "ok":
            assert rec["error"]
    # daemon state == batch salvage ingest over the same directory
    batch = TraceSession.from_hlo("chaos", _files(chaos_dir), MESH,
                                  max_workers=1, errors="salvage",
                                  retries=0, retry_backoff_s=0)
    sess = d.session()
    assert sess.labels() == batch.labels()
    for lab in batch.labels():
        assert sess.get(lab).store.identical(batch.get(lab).store)


def test_daemon_raise_mode_still_crashes(chaos_dir):
    d = WatchDaemon(WatchConfig(root=chaos_dir, mesh=MESH, settle_s=0.0,
                                quiet=True, errors="raise"))
    with pytest.raises(Exception):
        drain(d)


# -- CLI: controlled exit codes over corrupt dumps ---------------------------

def test_cli_ingest_salvage_exit_codes(chaos_dir, tmp_path, capsys):
    out = str(tmp_path / "out" / "chaos.json")
    rc = _main(["ingest", out, *_files(chaos_dir), "--workers", "1",
                "--errors", "salvage", "--retries", "0",
                "--retry-backoff", "0", "--json"])
    assert rc == 3                                   # degraded, not fatal
    rep = json.loads(capsys.readouterr().out)
    assert {r["status"] for r in rep["records"]} \
        >= {"ok", "salvaged", "quarantined"}
    # the session was still written, with the report persisted inside
    loaded = TraceSession.load(out)
    assert loaded.ingest_report is not None
    assert [r["source"] for r in loaded.ingest_report.to_dict()["records"]] \
        == _files(chaos_dir)


def test_cli_watch_once_survives_chaos(chaos_dir, tmp_path, capsys):
    summary = str(tmp_path / "summary.json")
    rc = _main(["watch", chaos_dir, "--once", "--settle", "0",
                "--interval", "0.01", "--retry-backoff", "0",
                "--summary", summary, "--quiet", "--fail-on", "critical"])
    capsys.readouterr()
    assert rc in (1, 3)     # alerts or degraded ingest — never a crash
    summ = json.load(open(summary))
    assert summ["ingest"]["quarantined"], "binary file must be quarantined"
