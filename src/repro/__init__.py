"""repro — multi-layer collective tracing for JAX/TPU (ucTrace reproduction)
plus the production training/serving framework it profiles.

Subpackages:
    core         the tracer (the paper's contribution)
    models       dense / MoE / SSM / hybrid / enc-dec / VLM backbones
    distributed  sharding rules, collective algorithms, EP/PP, constraints
    data, optim, checkpoint, training   substrates
    kernels      Pallas TPU kernels (flash attention, mamba scan)
    configs      the 10 assigned architectures
    launch       mesh / dryrun / train / serve drivers
"""
__version__ = "1.0.0"
