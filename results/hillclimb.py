import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Perf hillclimb driver: hypothesis -> change -> re-lower -> measure.
# Three cells chosen from the baseline table (see EXPERIMENTS.md §Perf):
#   falcon-mamba-7b/train_4k  — worst memory-bound ratio (87:1)
#   qwen3-moe-235b-a22b/train_4k — most collective-bound (6.9:1)
#   chatglm3-6b/train_4k      — representative dense cell (tracer-guided)

import dataclasses
import json

from repro.configs import get_config
from repro.core.roofline import kernel_adjusted, roofline, scope_breakdown
from repro.core.roofline import train_model_flops
from repro.launch import presets
from repro.launch.dryrun import lower_cell
from repro.models import api as model_api

HERE = os.path.dirname(os.path.abspath(__file__))
MESH_DEV = 256
TOKENS = 256 * 4096


def attn_kernel_bytes(arch: str, st) -> float:
    """Flash-attention kernel analytic HBM traffic per device per step.

    Kernel streams q,k,v once and writes o once per invocation; scores stay
    in VMEM.  Invocations: layers x accum x ~3 passes (fwd + remat-fwd + bwd;
    bwd re-streams q,k,v,o and writes dq,dk,dv ~ 2x fwd traffic -> use 4x).
    """
    cfg = get_config(arch)
    tok_loc = TOKENS // 16 // st.accum          # per data shard per micro
    q_loc = tok_loc * cfg.q_dim // 16 * 2       # bf16, TP over model
    kv_loc = tok_loc * cfg.kv_dim // 16 * 2
    per_call = (2 * q_loc + 2 * 2 * kv_loc)     # q+o, k+v
    return per_call * cfg.num_layers * st.accum * 4.0


def ssm_kernel_bytes(arch: str, st) -> float:
    """Fused mamba-block kernel traffic: x/out + one bf16 stream of the
    discretized terms (a_bar, bx, c) + h never leaving VMEM."""
    cfg = get_config(arch)
    tok_loc = TOKENS // 16 // st.accum
    di_loc = cfg.d_inner // 16
    x_io = 2 * tok_loc * cfg.d_model * 2 * 2            # read x, write out
    xz = 2 * tok_loc * 2 * di_loc * 2                   # in_proj out r/w
    stream = 2 * 2 * tok_loc * di_loc * cfg.ssm_state * 2   # a_bar+bx bf16 w+r
    y = 2 * tok_loc * di_loc * 2
    per_layer = x_io + xz + stream + y
    return per_layer * cfg.num_layers * st.accum * 4.0  # fwd+remat+bwd


def run_variant(arch, shape, name, cfg_over, set_over, kernel=None):
    st = presets.settings_for(arch, shape)
    if set_over:
        st = dataclasses.replace(st, **set_over)
    r = lower_cell(arch, shape, settings=st, cfg_overrides=cfg_over or None)
    tr = r["trace"]
    model_flops = train_model_flops(
        model_api.flops_param_count(get_config(arch)), TOKENS)
    rf = roofline(tr, model_flops=model_flops)
    if kernel:
        scope_pat, bytes_fn, flops_keep = kernel
        rf = kernel_adjusted(rf, tr, scope_pat, bytes_fn(arch, st),
                             new_flops=None)
    row = {
        "cell": f"{arch}/{shape}", "variant": name,
        "compute_s": rf.compute_s, "memory_s": rf.memory_s,
        "collective_s": rf.collective_s, "dominant": rf.dominant,
        "bound_s": rf.bound_s, "mfu_bound": rf.model_roofline_fraction,
        "useful": rf.useful_flops_ratio,
        "mem_model_gb": r["mem_model_gb"],
        "compile_s": r["compile_s"],
    }
    print(f"{arch:22s} {name:28s} comp={rf.compute_s:8.2f}s "
          f"hbm={rf.memory_s:8.2f}s coll={rf.collective_s:8.2f}s "
          f"dom={rf.dominant:10s} mfu={rf.model_roofline_fraction:.3f} "
          f"mem={r['mem_model_gb']:.1f}GB")
    if name == "baseline":
        print(scope_breakdown(tr, top=8))
    return row


VARIANTS = {
    ("falcon-mamba-7b", "train_4k"): [
        ("baseline", {}, {}, None),
        # H1: compute a_bar/bx per chunk inside the scan (16x smaller live
        # tensors; prediction: memory term drops ~2x — the [B,S,di,N]
        # materialization dominates bytes_by_scope['ssm'])
        ("H1_ssm_inloop", {"ssm_inloop": True}, {}, None),
        # H3: fused mamba Pallas kernel (h + scan internals in VMEM);
        # prediction: ssm-scope traffic (>90% of step bytes) collapses to
        # the analytic stream -> memory term drops ~10x
        ("H3_mamba_kernel", {"ssm_inloop": True}, {},
         (r"/ssm", ssm_kernel_bytes, None)),
        # H8: bf16 gradient compression on the DP all-reduce
        ("H8_grad_bf16", {"ssm_inloop": True},
         {"grad_compression": "bf16"}, (r"/ssm", ssm_kernel_bytes, None)),
    ],
    ("qwen3-moe-235b-a22b", "train_4k"): [
        ("baseline", {}, {}, None),
        # H6: dispatch/combine one-hot tables in bf16 (prediction: the
        # [G,S,E,C] tensors halve -> memory term down, dispatch einsum
        # faster; no accuracy risk: tables hold 0/1 and gate weights)
        ("H6_bf16_tables", {"moe_table_dtype": "bfloat16"}, {}, None),
        # H5: smaller routing groups (dispatch einsum FLOPs ~ Sg^2;
        # prediction: compute term down ~15%, collective unchanged)
        ("H5_group256", {"moe_group_size": 256,
                         "moe_table_dtype": "bfloat16"}, {}, None),
        # H4: bf16 gradient compression (prediction: grad_sync AR bytes
        # halve -> collective term down ~25% given grad_sync share)
        ("H4_grad_bf16", {"moe_group_size": 256,
                          "moe_table_dtype": "bfloat16"},
         {"grad_compression": "bf16"}, None),
        # H7: flash-attention kernel on top of the MoE combo
        ("H7_combo_attn_kernel", {"moe_group_size": 256,
                                  "moe_table_dtype": "bfloat16"},
         {"grad_compression": "bf16"}, (r"/attn", attn_kernel_bytes, None)),
    ],
    ("chatglm3-6b", "train_4k"): [
        ("baseline", {}, {}, None),
        # H2 (expected refute, kept for the record): Megatron-SP residual
        # sequence sharding — prediction per earlier measurement: collective
        # term blows up on this mesh topology
        ("H2_seq_shard_refuted", {}, {"seq_shard": True}, None),
        # H7: flash-attention kernel (prediction: attn-scope bytes are the
        # largest scope -> memory term down ~2x)
        ("H7_flash_kernel", {}, {}, (r"/attn", attn_kernel_bytes, None)),
        # H8: bf16 grad compression
        ("H8_grad_bf16", {}, {"grad_compression": "bf16"},
         (r"/attn", attn_kernel_bytes, None)),
        # H9: lighter remat (dots saveable) — prediction: compute term down
        # (less recompute) at the cost of more checkpoint memory
        ("H9_remat_dots", {}, {"remat": "dots",
                               "grad_compression": "bf16"},
         (r"/attn", attn_kernel_bytes, None)),
    ],
}


def main():
    rows = []
    for (arch, shape), variants in VARIANTS.items():
        print(f"\n===== {arch} x {shape} =====")
        for name, cfg_over, set_over, kernel in variants:
            try:
                rows.append(run_variant(arch, shape, name, cfg_over,
                                        set_over, kernel))
            except Exception as e:
                print(f"{name}: FAILED {type(e).__name__}: {str(e)[:200]}")
                rows.append({"cell": f"{arch}/{shape}", "variant": name,
                             "failed": str(e)[:300]})
    with open(os.path.join(HERE, "hillclimb.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print("\nwrote results/hillclimb.json")


if __name__ == "__main__":
    main()
