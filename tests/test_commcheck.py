"""Static analyzer coverage: zero findings on clean traces, every injected
bug class flagged with its expected code, severity ranking by bytes at
risk, pspec lint, and the `session lint` CLI contract."""
import json
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import commcheck, detect, report, synth
from repro.core.events import CollectiveEvent, Trace
from repro.core.hlo_parser import parse_hlo_store
from repro.core.synth import inject_comm_bugs, synthetic_hlo, synthetic_trace
from repro.core.topology import MeshSpec

MESH = MeshSpec((2, 4), ("data", "model"))
EXAMPLES = sorted(Path(__file__).resolve().parents[1].glob("examples/hlo/*.txt"))


def mk_event(**kw):
    base = dict(name="ar", kind="all-reduce", async_start=False,
                operand_bytes=1 << 22, result_bytes=1 << 22, dtype="f32",
                replica_groups=[[d] for d in range(8)], group_size=1,
                num_groups=8, op_name="", computation="main")
    base.update(kw)
    if base["replica_groups"]:
        base.setdefault("group_size", len(base["replica_groups"][0]))
        base.setdefault("num_groups", len(base["replica_groups"]))
    return CollectiveEvent(**base)


def mk_trace(events):
    return Trace(label="t", mesh_shape=(2, 4), mesh_axes=("data", "model"),
                 num_devices=8, events=events)


# -- clean traces are clean -------------------------------------------------

def test_clean_synth_trace_no_findings():
    t = synthetic_trace("clean", MESH, n_sites=200, seed=3)
    assert commcheck.check_trace(t, MESH) == []


@pytest.mark.parametrize("n_comp", [1, 3])
def test_clean_synth_hlo_no_findings(n_comp):
    text = synthetic_hlo(n_sites=300, seed=5, n_computations=n_comp)
    store, _stats = parse_hlo_store(text, MESH.num_devices)
    t = Trace.from_store("hlo", MESH.shape, MESH.axes, MESH.num_devices, store)
    assert commcheck.check_trace(t, MESH) == []


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.stem)
def test_examples_lint_clean(path):
    from repro.core.tracer import trace_from_hlo
    t = trace_from_hlo(path.read_text(), MESH, label=path.stem)
    assert commcheck.check_trace(t, MESH) == []


# -- injected bugs: every class flagged, nothing else -----------------------

def test_injected_bugs_all_flagged():
    trace, labels = inject_comm_bugs(MESH, n_sites=64, seed=0)
    findings = commcheck.check_trace(trace, MESH)
    found = {f.detector for f in findings}
    for bug, code in labels.items():
        assert code in found, f"injected {bug} not flagged as {code}"
    # precision: only codes attributable to an injection fire
    assert found <= set(labels.values())


@pytest.mark.parametrize("bug", sorted(synth.COMM_BUGS))
def test_each_bug_flagged_in_isolation(bug):
    trace, labels = inject_comm_bugs(MESH, n_sites=32, seed=1, bugs=(bug,))
    found = {f.detector for f in commcheck.check_trace(trace, MESH)}
    assert found == {labels[bug]}


def test_deadlock_counts_and_severity():
    trace, _ = inject_comm_bugs(MESH, n_sites=32, bugs=("deadlock_order",))
    (f,) = commcheck.check_trace(trace, MESH)
    assert f.severity == "critical"
    assert f.wasted_bytes > 0
    assert "block forever" in f.message


# -- severity ranking (satellite e) -----------------------------------------

def _assert_ranked(findings):
    ranks = [detect.SEVERITY_RANK[f.severity] for f in findings]
    assert ranks == sorted(ranks)
    for a, b in zip(findings, findings[1:]):
        if a.severity == b.severity:
            assert a.wasted_bytes >= b.wasted_bytes


def test_commcheck_output_ranked_critical_first():
    trace, _ = inject_comm_bugs(MESH, n_sites=64, seed=0)
    findings = commcheck.check_trace(trace, MESH)
    sevs = {f.severity for f in findings}
    assert {"critical", "warn", "info"} <= sevs
    _assert_ranked(findings)
    # critical deadlock (largest injected payload) outranks everything
    assert findings[0].severity == "critical"
    assert findings[-1].severity == "info"


def test_run_all_output_ranked():
    trace, _ = inject_comm_bugs(MESH, n_sites=64, seed=0)
    _assert_ranked(detect.run_all(trace))


# -- replica-group / permute families on crafted stores ---------------------

def test_out_of_range_device_critical():
    t = mk_trace([mk_event(replica_groups=[[0, 1, 2, 99]])])
    findings = commcheck.check_trace(t)
    codes = {f.detector: f.severity for f in findings}
    # naming device 99 also leaves real devices uncovered — both fire
    assert codes.get("device_out_of_range") == "critical"
    assert codes.get("group_coverage") == "critical"


def test_group_overlap_critical():
    t = mk_trace([mk_event(replica_groups=[[0, 1, 2, 3], [3, 4, 5, 6]])])
    findings = commcheck.check_trace(t)
    assert findings[0].detector == "group_overlap"
    assert findings[0].severity == "critical"


def test_uniform_groups_divide_mesh_ok():
    # [4,2]<=[8]-style tiling: size 4 divides the full 8-device product
    t = mk_trace([mk_event(replica_groups=[[0, 1, 2, 3], [4, 5, 6, 7]])])
    assert commcheck.check_trace(t) == []


def test_permute_dup_target_critical_dup_source_warn():
    dup_t = mk_trace([mk_event(
        kind="collective-permute", name="cp",
        source_target_pairs=[(0, 1), (2, 1), (3, 4)],
        replica_groups=[list(range(8))])])
    codes = {f.detector: f.severity for f in commcheck.check_trace(dup_t)}
    assert codes.get("permute_dup_target") == "critical"
    dup_s = mk_trace([mk_event(
        kind="collective-permute", name="cp",
        source_target_pairs=[(0, 1), (0, 2), (3, 4)],
        replica_groups=[list(range(8))])])
    codes = {f.detector: f.severity for f in commcheck.check_trace(dup_s)}
    assert codes.get("permute_dup_source") == "warn"


def test_permute_self_loop_info():
    t = mk_trace([mk_event(
        kind="collective-permute", name="cp",
        source_target_pairs=[(0, 0), (1, 2)],
        replica_groups=[list(range(8))])])
    codes = {f.detector: f.severity for f in commcheck.check_trace(t)}
    assert codes.get("permute_self_loop") == "info"


def test_permute_oob_critical():
    t = mk_trace([mk_event(
        kind="collective-permute", name="cp",
        source_target_pairs=[(0, 12)],
        replica_groups=[list(range(8))])])
    assert any(f.detector == "device_out_of_range" and f.severity == "critical"
               for f in commcheck.check_trace(t))


# -- store group-expansion plumbing -----------------------------------------

def test_expand_groups_and_device_counts():
    t = mk_trace([mk_event(replica_groups=[[0, 1], [2, 3]]),
                  mk_event(name="ar2", replica_groups=[[0, 1, 2, 3],
                                                       [3, 4, 5, 6]])])
    store = t.store
    tcode, gidx, dev = store.expand_groups()
    assert len(tcode) == len(gidx) == len(dev) == 12
    cnt = store.table_device_counts(8)
    assert cnt.shape == (len(store.group_tables), 8)
    # second table: device 3 appears in both groups
    t2 = store.group_code[1]
    assert cnt[t2, 3] == 2
    assert cnt[t2, 7] == 0
    # cached: same arrays back
    assert store.expand_groups()[0] is tcode


# -- pspec lint (duck-typed, jax-free) --------------------------------------

class FakeSpec(tuple):
    """PartitionSpec stand-in for jax-free tests."""


def _leaf(x):
    return isinstance(x, FakeSpec)


SIZES = {"data": 2, "model": 4}


def _codes(findings):
    return {f.detector for f in findings}


def test_pspec_dup_axis():
    specs = {"w": FakeSpec(("model", "model"))}
    fs = commcheck.lint_pspecs(specs, SIZES, is_leaf=_leaf)
    assert _codes(fs) == {"pspec_dup_axis"}
    assert fs[0].severity == "critical"
    assert fs[0].site == "w"


def test_pspec_unknown_axis():
    specs = {"w": FakeSpec(("expert", None))}
    fs = commcheck.lint_pspecs(specs, SIZES, is_leaf=_leaf)
    assert _codes(fs) == {"pspec_unknown_axis"}


def test_pspec_indivisible():
    specs = {"w": FakeSpec((None, "model"))}
    fs = commcheck.lint_pspecs(specs, SIZES, shapes={"w": (8, 6)},
                               is_leaf=_leaf)
    assert _codes(fs) == {"pspec_indivisible"}
    assert fs[0].severity == "warn"


def test_pspec_unsharded_dominant_dim():
    specs = {"emb": FakeSpec((None, None))}
    fs = commcheck.lint_pspecs(specs, SIZES, shapes={"emb": (8192, 64)},
                               is_leaf=_leaf)
    assert _codes(fs) == {"pspec_unsharded_dim"}
    assert fs[0].wasted_bytes == 8192 * 64 * 4.0


def test_pspec_clean_tree_silent():
    specs = {"a": {"w": FakeSpec(("data", "model"))},
             "b": [FakeSpec((None, "model"))]}
    shapes = {"a": {"w": (4, 8)}, "b": [(16, 8)]}
    assert commcheck.lint_pspecs(specs, SIZES, shapes=shapes,
                                 is_leaf=_leaf) == []


def test_lint_sharding_real_config_no_criticals():
    from repro.configs import get_config
    from repro.distributed.sharding import lint_sharding
    mesh = SimpleNamespace(axis_names=("data", "model"),
                           devices=np.empty((2, 4), object))
    fs = lint_sharding(get_config("hymba-1.5b"), mesh)
    assert all(f.severity != "critical" for f in fs)


# -- report integration -----------------------------------------------------

def test_report_findings_key_and_engine_identity():
    trace, labels = inject_comm_bugs(MESH, n_sites=48, seed=2)
    doc_cols = json.loads(report.to_json(trace, engine="columnar"))
    doc_rows = json.loads(report.to_json(trace, engine="rows"))
    assert doc_cols == doc_rows
    codes = {f["analyzer"] for f in doc_cols["findings"]}
    assert set(labels.values()) <= codes
    clean = synthetic_trace("clean", MESH, n_sites=64, seed=7)
    assert json.loads(report.to_json(clean))["findings"] == []


def test_report_findings_computed_once_per_store():
    trace, _ = inject_comm_bugs(MESH, n_sites=48, seed=2)
    f1 = report.trace_findings(trace)
    f2 = report.trace_findings(trace)
    assert f1 is f2


def test_html_matrix_guard_above_threshold():
    big = MeshSpec((128, 2), ("data", "model"))
    t = synthetic_trace("big", big, n_sites=64, seed=0)
    html = report.to_html(t, big)
    assert f"&gt; {report.MATRIX_MAX_DIM} groups" in html
    assert "<th>src</th><th>dst</th>" in html
    # small mesh still paints the full grid
    small = synthetic_trace("small", MESH, n_sites=32, seed=0)
    assert "groups) — top" not in report.to_html(small, MESH)


# -- CLI contract -----------------------------------------------------------

def test_cli_lint_clean_and_buggy(tmp_path, capsys):
    from repro.core.session import _main
    clean = tmp_path / "clean.txt"
    clean.write_text(synthetic_hlo(n_sites=120, seed=9))
    assert _main(["lint", str(clean), "--mesh", "2,4",
                  "--axes", "data,model"]) == 0
    capsys.readouterr()
    assert _main(["lint", str(tmp_path / "nope.txt"), "--mesh", "2,4",
                  "--axes", "data,model"]) == 2
    capsys.readouterr()


def test_cli_lint_json_schema_matches_detect(tmp_path, capsys):
    from repro.core.session import TraceSession
    from repro.core.session import _main
    trace, _ = inject_comm_bugs(MESH, n_sites=48, seed=4)
    sess = TraceSession("bugs", [trace])
    path = sess.save(str(tmp_path / "bugs.json"))

    assert _main(["lint", path, "--json", "--fail-on", "never"]) == 0
    lint_doc = json.loads(capsys.readouterr().out)
    assert _main(["lint", path, "--json"]) == 1          # criticals present
    capsys.readouterr()

    assert _main(["detect", path, "--json"]) == 0
    detect_doc = json.loads(capsys.readouterr().out)

    keys = {"analyzer", "severity", "site", "message",
            "wasted_bytes", "time_at_risk_s",
            "recommendation", "est_saved_s"}
    assert lint_doc and lint_doc[0]["findings"]
    for doc in (lint_doc, detect_doc):
        for entry in doc:
            assert set(entry) == {"source", "trace", "findings"}
            for f in entry["findings"]:
                assert set(f) == keys
