"""Model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM backbones."""
from repro.models import api  # noqa: F401
