"""Watch daemon: dir tailing, incremental folds, batch-equivalent output.

The live-profiling contract: `session watch --once` over a dump
directory — including one that *grows mid-run* — must produce the same
session/report a batch `session ingest` + `session report` over the
final directory contents produces, while its rolling aggregates stay
equal to full recomputation.
"""
import os
import threading
import time

import pytest

from repro.core.session import TraceSession, _main
from repro.core.synth import synthetic_hlo, write_hlo_dump
from repro.core.topology import MeshSpec
from repro.core.watch import DirWatcher, WatchConfig, WatchDaemon

MESH = MeshSpec((2, 4), ("data", "model"))


def mk_daemon(root, **kw):
    kw.setdefault("settle_s", 0.0)
    kw.setdefault("quiet", True)
    return WatchDaemon(WatchConfig(root=str(root), mesh=MESH, **kw))


def drain(daemon, max_polls=10):
    """Poll until a round ingests nothing and nothing is pending."""
    for _ in range(max_polls):
        ready, pending = daemon.poll_once()
        if not ready and not pending:
            return
    raise AssertionError("directory never became quiescent")


def batch_session(root):
    paths = sorted(str(p) for p in __import__("glob").glob(
        os.path.join(str(root), "*.txt")))
    return TraceSession.from_hlo(os.path.basename(str(root)), paths, MESH,
                                 max_workers=1)


# -- DirWatcher: stability + settle + re-ingest ------------------------------

def test_watcher_needs_two_stable_polls(tmp_path):
    w = DirWatcher(str(tmp_path), settle_s=0.0)
    (tmp_path / "a.txt").write_text("x")
    ready, pending = w.poll()
    assert ready == [] and pending == 1        # first sighting: not ready
    ready, pending = w.poll()
    assert [os.path.basename(p) for p in ready] == ["a.txt"]
    w.mark_ingested(ready[0])
    assert w.poll() == ([], 0)                 # ingested: quiescent


def test_watcher_holds_while_file_is_growing(tmp_path):
    w = DirWatcher(str(tmp_path), settle_s=0.0)
    p = tmp_path / "a.txt"
    p.write_text("x")
    w.poll()
    p.write_text("xy")                         # signature moved between polls
    ready, pending = w.poll()
    assert ready == [] and pending == 1
    ready, _ = w.poll()
    assert len(ready) == 1


def test_watcher_settle_delay_blocks_fresh_files(tmp_path):
    w = DirWatcher(str(tmp_path), settle_s=3600.0)
    (tmp_path / "a.txt").write_text("x")
    w.poll()
    ready, pending = w.poll()
    assert ready == [] and pending == 1        # stable but too young


def test_watcher_reingests_changed_files(tmp_path):
    w = DirWatcher(str(tmp_path), settle_s=0.0)
    p = tmp_path / "a.txt"
    p.write_text("x")
    w.poll()
    ready, _ = w.poll()
    w.mark_ingested(ready[0])
    p.write_text("different content")      # new size => new signature
    w.poll()
    ready, _ = w.poll()
    assert len(ready) == 1                     # changed after ingest: redo


def test_watcher_respects_pattern(tmp_path):
    w = DirWatcher(str(tmp_path), pattern="*.hlo", settle_s=0.0)
    (tmp_path / "a.txt").write_text("x")
    (tmp_path / "b.hlo").write_text("y")
    w.poll()
    ready, pending = w.poll()
    assert [os.path.basename(p) for p in ready] == ["b.hlo"]
    assert pending == 0


# -- daemon: incremental ingest == batch over the final directory ------------

def test_daemon_matches_batch_after_midrun_growth(tmp_path):
    write_hlo_dump(str(tmp_path), n_files=2, sites_per_file=120, seed=3)
    d = mk_daemon(tmp_path, fail_on="never")
    drain(d)
    assert len(d._traces) == 2
    # the directory grows mid-run; the next polls pick the delta up
    write_hlo_dump(str(tmp_path), n_files=1, sites_per_file=120, seed=3,
                   start=2)
    drain(d)
    assert len(d._traces) == 3

    ref = batch_session(tmp_path)
    sess = d.session()
    assert sess.labels() == ref.labels()
    assert sess.report(fmt="json") == ref.report(fmt="json")
    for a, b in zip(sess, ref):
        assert a.store.identical(b.store)

    # rolling aggregates == recomputation over the union
    union = [t.store for t in ref]
    total = sum(s.n for s in union)
    assert d.rolling.n == total
    batch_roll = {}
    for t in ref:
        for k, v in t.by_kind_and_link().items():
            acc = batch_roll.setdefault(k, dict.fromkeys(v, 0.0))
            for f in v:
                acc[f] += v[f]
    inc = d.rollups["kind_link"].as_dict()
    assert set(inc) == set(batch_roll)
    for k in inc:
        for f in ("bytes", "wire_bytes", "count", "time_s"):
            assert inc[k][f] == pytest.approx(batch_roll[k][f], rel=1e-9)


def test_daemon_rebuilds_on_changed_file(tmp_path):
    paths = write_hlo_dump(str(tmp_path), n_files=2, sites_per_file=80,
                           seed=5)
    d = mk_daemon(tmp_path)
    drain(d)
    n_before = d.rolling.n
    # rewrite file 0 with a bigger module: stale contribution must vanish
    with open(paths[0], "w") as f:
        f.write(synthetic_hlo(n_sites=160, seed=99))    # new size/mtime
    drain(d)
    assert len(d._traces) == 2
    ref = batch_session(tmp_path)
    assert d.rolling.n == sum(t.store.n for t in ref) != n_before
    assert d.session().report(fmt="json") == ref.report(fmt="json")


def test_daemon_summary_and_emit_atomic(tmp_path):
    root = tmp_path / "dump"
    write_hlo_dump(str(root), n_files=2, sites_per_file=60, seed=1)
    out = tmp_path / "out"
    out.mkdir()
    d = mk_daemon(root, out=str(out / "sess.json"),
                  report_json=str(out / "report.json"),
                  summary=str(out / "summary.json"))
    drain(d)
    d.emit()
    import json
    s = json.loads((out / "summary.json").read_text())
    assert s["files"] == 2 and s["sites"] == d.rolling.n
    assert set(s["by_kind_link"]) == set(d.rollups["kind_link"].as_dict())
    loaded = TraceSession.load(str(out / "sess.json"))
    assert loaded.labels() == d.session().labels()
    assert (out / "report.json").read_text() \
        == d.session().report(fmt="json") + "\n"
    assert not [p for p in os.listdir(out) if p.endswith(".tmp")]


# -- CLI: --once over a directory that grows mid-run -------------------------

def test_watch_cli_once_with_midrun_writer(tmp_path, capsys):
    root = tmp_path / "dump"
    write_hlo_dump(str(root), n_files=2, sites_per_file=70, seed=11)
    report = str(tmp_path / "rolling_report.json")

    def late_writer():
        time.sleep(0.15)
        write_hlo_dump(str(root), n_files=1, sites_per_file=70, seed=11,
                       start=2)

    t = threading.Thread(target=late_writer)
    t.start()
    try:
        # settle 0.4s > writer delay: the pre-existing files are still
        # settling when the third lands, so quiescence cannot precede it
        rc = _main(["watch", str(root), "--once", "--quiet",
                    "--settle", "0.4", "--interval", "0.05",
                    "--report-json", report])
    finally:
        t.join()
    assert rc == 0
    ref = batch_session(root)
    assert len(ref) == 3
    with open(report) as f:
        assert f.read() == ref.report(fmt="json") + "\n"


def test_watch_cli_fail_on_alerts(tmp_path, capsys):
    root = tmp_path / "dump"
    root.mkdir()
    # two collectives of different kinds on one channel: a critical
    # channel_collision the static analyzer must flag
    (root / "bug.txt").write_text("\n".join([
        "HloModule bug",
        "",
        "%add (a: f32[], b: f32[]) -> f32[] {",
        "  %a = f32[] parameter(0)",
        "  %b = f32[] parameter(1)",
        "  ROOT %r = f32[] add(%a, %b)",
        "}",
        "",
        "ENTRY %main (x: f32[8]) -> f32[8] {",
        "  %x = f32[8] parameter(0)",
        "  %ar = f32[8] all-reduce(%x), channel_id=1, "
        "replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add",
        "  %ag = f32[64] all-gather(%x), channel_id=1, "
        "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}",
        "  ROOT %out = f32[8] copy(%x)",
        "}",
        "",
    ]))
    rc = _main(["watch", str(root), "--once", "--quiet", "--settle", "0",
                "--interval", "0.01", "--fail-on", "critical"])
    captured = capsys.readouterr()
    assert rc == 1
    assert "channel_collision" in captured.err
    # advisory mode: same directory, exit 0
    assert _main(["watch", str(root), "--once", "--quiet", "--settle", "0",
                  "--interval", "0.01"]) == 0


def test_watch_cli_rejects_missing_dir(tmp_path, capsys):
    assert _main(["watch", str(tmp_path / "nope"), "--once"]) == 2
    assert "no such directory" in capsys.readouterr().err

# -- fault tolerance: mtime skew, quarantine, crash-resume -------------------

def test_watcher_clamps_future_mtime(tmp_path):
    """NFS clock skew: a file touched into the future must still settle
    — readiness is judged on signature stability, with the settle clock
    clamped to the poll that first saw the signature."""
    p = tmp_path / "skewed.txt"
    p.write_text("x")
    t0 = time.time()
    os.utime(str(p), (t0 + 1e6, t0 + 1e6))
    w = DirWatcher(str(tmp_path), settle_s=10.0)
    assert w.poll(now=t0) == ([], 1)               # first sighting
    ready, pending = w.poll(now=t0 + 5)
    assert ready == [] and pending == 1            # stable but settling
    ready, _ = w.poll(now=t0 + 11)                 # settle elapsed (clamped)
    assert [os.path.basename(x) for x in ready] == ["skewed.txt"]


def test_watcher_future_mtime_does_not_settle_early(tmp_path):
    p = tmp_path / "skewed.txt"
    p.write_text("x")
    t0 = time.time()
    os.utime(str(p), (t0 + 1e6, t0 + 1e6))
    w = DirWatcher(str(tmp_path), settle_s=10.0)
    w.poll(now=t0)
    # without the first-observation clamp, now - mtime is hugely negative
    # forever; with a *per-poll* clamp the signature would look reset
    # each poll.  Either bug fails one of these two assertions.
    assert w.poll(now=t0 + 1) == ([], 1)
    ready, _ = w.poll(now=t0 + 12)
    assert len(ready) == 1


def test_daemon_quarantines_bad_file_then_recovers_on_change(tmp_path):
    bad = tmp_path / "bad.txt"
    bad.write_bytes(b"\xff\xfe not utf-8 \xff")
    d = mk_daemon(tmp_path, max_retries=2, retry_backoff_s=0.0)
    drain(d)
    rec = d._records[str(bad)]
    assert rec["status"] == "quarantined" and rec["error"]
    assert str(bad) in d._quarantine
    assert d.session().labels() == []
    assert d.degraded() == [str(bad)]
    # the writer finishes the dump: new signature reopens the quarantine
    bad.write_text(synthetic_hlo(n_sites=40, seed=8))
    drain(d)
    assert d._records[str(bad)]["status"] == "ok"
    assert str(bad) not in d._quarantine
    assert d.session().labels() == ["bad"]


def test_daemon_quarantine_backoff_gates_same_signature_retries(tmp_path):
    bad = tmp_path / "bad.txt"
    bad.write_bytes(b"\xff\xfe not utf-8 \xff")
    d = mk_daemon(tmp_path, max_retries=3, retry_backoff_s=1e6)
    d.poll_once()
    d.poll_once()      # first attempt fails -> quarantined, huge backoff
    q0 = dict(d._quarantine[str(bad)])
    assert q0["failures"] == 1
    for _ in range(3):
        ingested, pending = d.poll_once()
        assert ingested == [] and pending >= 1     # gated, not retried
    assert d._quarantine[str(bad)]["failures"] == 1


def test_daemon_checkpoint_resume_reparses_nothing(tmp_path):
    root = tmp_path / "dump"
    write_hlo_dump(str(root), n_files=3, sites_per_file=90, seed=21)
    ckpt = str(tmp_path / "watch.npz")
    d1 = mk_daemon(root, checkpoint=ckpt)
    drain(d1)
    assert d1.parse_count == 3 and os.path.exists(ckpt)
    report1 = d1.session().report(fmt="json")

    d2 = mk_daemon(root, checkpoint=ckpt)
    drain(d2)
    assert d2.parse_count == 0                     # zero re-parses
    assert d2.rounds >= d1.rounds                  # round counter resumed
    sess1, sess2 = d1.session(), d2.session()
    assert sess2.labels() == sess1.labels()
    for a, b in zip(sess1, sess2):
        assert a.store.identical(b.store)
    assert sess2.report(fmt="json") == report1
    assert [f.to_dict() for f in d2.findings()] \
        == [f.to_dict() for f in d1.findings()]

    # new files after resume are the only thing parsed
    write_hlo_dump(str(root), n_files=1, sites_per_file=90, seed=21, start=3)
    drain(d2)
    assert d2.parse_count == 1
    ref = batch_session(root)
    assert d2.session().report(fmt="json") == ref.report(fmt="json")


def test_daemon_checkpoint_survives_quarantine_state(tmp_path):
    root = tmp_path / "dump"
    write_hlo_dump(str(root), n_files=1, sites_per_file=60, seed=2)
    (root / "bad.txt").write_bytes(b"\xff\xfe nope \xff")
    ckpt = str(tmp_path / "watch.npz")
    d1 = mk_daemon(root, checkpoint=ckpt, max_retries=1, retry_backoff_s=0.0)
    drain(d1)
    assert d1._records[str(root / "bad.txt")]["status"] == "quarantined"

    d2 = mk_daemon(root, checkpoint=ckpt, max_retries=1, retry_backoff_s=0.0)
    drain(d2)
    assert d2.parse_count == 0                     # bad file not re-offered
    assert d2._records[str(root / "bad.txt")]["status"] == "quarantined"
    assert d2.summary()["ingest"]["quarantined"] == [str(root / "bad.txt")]


def test_daemon_ignores_unusable_checkpoint(tmp_path):
    root = tmp_path / "dump"
    write_hlo_dump(str(root), n_files=1, sites_per_file=50, seed=4)
    ckpt = tmp_path / "watch.npz"
    ckpt.write_text("not an npz at all")
    d = mk_daemon(root, checkpoint=str(ckpt))
    drain(d)                                       # fresh start, no crash
    assert d.parse_count == 1
    import numpy as np
    with np.load(str(ckpt)) as arrs:               # checkpoint rewritten
        assert "watch" in arrs


def test_daemon_sigkill_resume_matches_batch(tmp_path):
    """The acceptance drill: SIGKILL the daemon mid-run, restart on the
    same checkpoint, drain with --once — the final report must be
    byte-identical to batch ingest + report, with only post-kill files
    parsed by the resumed process."""
    import json as json_mod
    import signal
    import subprocess
    import sys as sys_mod

    root = tmp_path / "dump"
    write_hlo_dump(str(root), n_files=2, sites_per_file=80, seed=31)
    ckpt = str(tmp_path / "watch.npz")
    summary = str(tmp_path / "summary.json")
    report = str(tmp_path / "report.json")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.Popen(
        [sys_mod.executable, "-m", "repro.core.session", "watch", str(root),
         "--settle", "0", "--interval", "0.05", "--quiet",
         "--checkpoint", ckpt, "--summary", summary],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    try:
        deadline = time.time() + 60
        while time.time() < deadline:
            if os.path.exists(summary):
                try:
                    s = json_mod.load(open(summary))
                except ValueError:
                    s = {}
                if s.get("files") == 2 and os.path.exists(ckpt):
                    break
            time.sleep(0.05)
        else:
            raise AssertionError("daemon never ingested the seed files")
    finally:
        proc.send_signal(signal.SIGKILL)
        proc.wait()

    # directory keeps growing while the daemon is dead
    write_hlo_dump(str(root), n_files=1, sites_per_file=80, seed=31, start=2)
    rc = subprocess.run(
        [sys_mod.executable, "-m", "repro.core.session", "watch", str(root),
         "--once", "--settle", "0", "--interval", "0.05", "--quiet",
         "--checkpoint", ckpt, "--summary", summary,
         "--report-json", report, "--fail-on", "critical"],
        env=env).returncode
    assert rc == 0
    s = json_mod.load(open(summary))
    assert s["files"] == 3
    assert s["ingest"]["parse_count"] == 1         # only the new file
    ref = batch_session(root)
    with open(report) as f:
        assert f.read() == ref.report(fmt="json") + "\n"
