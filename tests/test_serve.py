"""Serving-path correctness: prefill+decode == full forward, per family."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import api

FAMS = ["chatglm3-6b", "falcon-mamba-7b", "hymba-1.5b", "whisper-tiny",
        "qwen2-vl-2b", "gemma3-4b", "h2o-danube-3-4b"]


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_decode_matches_forward(arch):
    cfg = smoke_config(ARCHS[arch])
    params = api.init_params(cfg, 0)
    B, S = 2, 16
    batch = api.demo_batch(cfg, B, S)
    logits_full, _ = api.forward(cfg, params, batch, attn_impl="naive")

    pre = dict(batch)
    if cfg.family == "vlm":
        pre["tokens"] = batch["tokens"][:, :-1]
        pre["positions"] = batch["positions"][:, :, :-1]
    else:
        pre["tokens"] = batch["tokens"][:, :-1]
    _lg, cache = api.prefill(cfg, params, pre, cache_len=S)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["positions"] = batch["positions"][:, :, -1:]
    lg_dec, new_cache = api.decode_step(cfg, params, cache,
                                        batch["tokens"][:, -1:],
                                        jnp.int32(S - 1), **kwargs)
    a = np.asarray(lg_dec[:, 0], np.float32)
    b = np.asarray(logits_full[:, -1], np.float32)
    rel = np.max(np.abs(a - b)) / (np.abs(b).max() + 1e-6)
    assert rel < 0.02, rel


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "qwen3-moe-235b-a22b"])
def test_moe_decode_matches_forward_without_drops(arch):
    cfg = smoke_config(ARCHS[arch])
    cfg = cfg.replace(capacity_factor=float(cfg.num_experts) / cfg.top_k)
    params = api.init_params(cfg, 0)
    B, S = 2, 16
    batch = api.demo_batch(cfg, B, S)
    logits_full, _ = api.forward(cfg, params, batch, attn_impl="naive")
    pre = {"tokens": batch["tokens"][:, :-1]}
    _lg, cache = api.prefill(cfg, params, pre, cache_len=S)
    lg_dec, _ = api.decode_step(cfg, params, cache, batch["tokens"][:, -1:],
                                jnp.int32(S - 1))
    err = np.max(np.abs(np.asarray(lg_dec[:, 0], np.float32)
                        - np.asarray(logits_full[:, -1], np.float32)))
    assert err < 0.06, err


def test_multi_step_decode_consistent():
    """Decoding 4 tokens sequentially matches teacher-forced forward."""
    cfg = smoke_config(ARCHS["h2o-danube-3-4b"])
    params = api.init_params(cfg, 0)
    B, S = 1, 16
    batch = api.demo_batch(cfg, B, S)
    logits_full, _ = api.forward(cfg, params, batch, attn_impl="naive")
    P = S - 4
    pre = {"tokens": batch["tokens"][:, :P]}
    _lg, cache = api.prefill(cfg, params, pre, cache_len=S)
    for i in range(4):
        pos = P + i
        lg, cache = api.decode_step(cfg, params, cache,
                                    batch["tokens"][:, pos:pos + 1],
                                    jnp.int32(pos))
        err = np.max(np.abs(np.asarray(lg[:, 0], np.float32)
                            - np.asarray(logits_full[:, pos], np.float32)))
        assert err < 0.08, (i, err)


def test_windowed_ring_cache_matches_full():
    """SWA windowed ring-buffer decode == full-cache windowed decode."""
    from repro.models import transformer
    cfg = smoke_config(ARCHS["h2o-danube-3-4b"])   # uniform window=16
    params = api.init_params(cfg, 0)
    B, S = 1, 32
    w = cfg.window
    batch = api.demo_batch(cfg, B, S)
    logits_full, _ = api.forward(cfg, params, batch, attn_impl="naive")

    # drive both caches token by token from scratch
    full = transformer.init_cache(cfg, B, S, windowed=False)
    ring = transformer.init_cache(cfg, B, w, windowed=True)
    # cheat: allocate ring at exactly window length
    for t in range(S):
        tok = batch["tokens"][:, t:t + 1]
        lf, full = api.decode_step(cfg, params, full, tok, jnp.int32(t))
        lr, ring = api.decode_step(cfg, params, ring, tok, jnp.int32(t))
        if t >= w:   # steady state only (cold-start masking differs)
            err = np.max(np.abs(np.asarray(lf, np.float32)
                                - np.asarray(lr, np.float32)))
            assert err < 0.08, (t, err)


def test_batched_server_end_to_end():
    from repro.launch.serve import BatchedServer, Request
    cfg = smoke_config(ARCHS["hymba-1.5b"])
    params = api.init_params(cfg, 0)
    srv = BatchedServer(cfg, params, max_batch=2, cache_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 4, dtype=np.int32), 4)
            for i in range(3)]
    queue = list(reqs)
    for _ in range(64):
        for slot in range(srv.max_batch):
            if srv.slots[slot] is None and queue:
                srv.prefill_into_slot(slot, queue.pop(0))
        srv.decode_round()
        if all(r.done for r in reqs):
            break
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)
