"""TraceSession — named multi-trace collections with save/load + comparison.

The paper's headline experiments are *comparisons across runs*: the same
Allreduce workload under different MPI libraries, UCX settings, and NUMA
bindings.  A `TraceSession` makes that shape first-class: collect traces
from several configurations, persist them as one artifact (compact JSON or
compressed npz of the columnar stores), and render n-way comparison views.

Bulk ingest (`TraceSession.from_hlo`) runs the columnar pipeline over many
HLO dumps, fanning the files out across worker processes — ingest is pure
CPU-bound Python/numpy, so a sweep of N configurations parses in roughly
the time of its largest member.

CLI:
    python -m repro.core.session demo  [--out PATH] [--format json|npz]
    python -m repro.core.session ingest OUT FILE [FILE ...] [--mesh 2,4]
                                        [--axes data,model] [--workers N]
                                        [--shards N] [--errors raise|skip|
                                        salvage] [--retries N] [--timeout S]
                                        [--json]
    python -m repro.core.session show  PATH
    python -m repro.core.session table PATH [--by kind_link|semantic|site] \\
                                            [--metric bytes|time|count]
    python -m repro.core.session diff  PATH LABEL_A LABEL_B [--by ...|site] \\
                                        [--top N] [--only-regressed] [--json] \\
                                        [--mmap]
    python -m repro.core.session query PATH [--host GLOB] [--step N|GLOB] \\
                                        [--op GLOB] [--kind GLOB] \\
                                        [--by kind_link|semantic|site] \\
                                        [--json] [--mmap]
    python -m repro.core.session report PATH [LABEL] [--format json|html] \\
                                        [--out FILE] [--stream] \\
                                        [--chunk-sites N]
    python -m repro.core.session watch ROOT [--pattern *.txt] [--mesh 2,4] \\
                                        [--axes data,model] [--out PATH] \\
                                        [--report-json PATH] \\
                                        [--report-html PATH] \\
                                        [--summary PATH] [--settle S] \\
                                        [--interval S] [--once] \\
                                        [--fail-on SEV] [--max-rounds N] \\
                                        [--errors raise|skip|salvage] \\
                                        [--checkpoint PATH]
    python -m repro.core.session lint  PATH [PATH ...] [--mesh 2,4] \\
                                        [--axes data,model] [--json] \\
                                        [--fail-on critical|warn|info|never]
    python -m repro.core.session detect PATH [LABEL] [--json] \\
                                        [--fail-on critical|warn|info|never]
    python -m repro.core.session whatif PATH [LABEL] [--mesh 2,4] \\
                                        [--axes data,model] [--top N] \\
                                        [--json]

`lint` runs the static analyzer (`commcheck`) over saved sessions
(.json/.npz) or raw HLO text files (ingested with --mesh/--axes);
`detect` runs the dynamic detectors over a saved session.  Both emit the
same stable finding schema under --json and exit 1 when any finding
reaches the --fail-on severity (default: critical for lint, never for
detect), 2 on input errors.

`whatif` is the hardwareless config sweep (`repro.core.whatif`): it
re-prices one trace under a grid of counterfactual scenarios — mesh
axis permutations, rendezvous-threshold tiers, link bandwidth/latency
tiers — by re-running the columnar annotation pass (no re-parse, no
hardware), and ranks the scenarios by estimated step time saved.
Accepts a saved session or a raw HLO text file; exits 0 on success,
2 on input errors.

`watch` is the live-profiling daemon (see `repro.core.watch`): it tails
an HLO dump directory, ingests new/changed files incrementally
(append-mode stores + streaming detector/lint state), and re-emits its
outputs atomically every poll; `--once` drains the directory and exits.
Damaged dumps are salvaged or quarantined instead of crashing the loop
(exit 3 when anything was degraded, after the `--fail-on` alert exit 1),
and `--checkpoint` makes the daemon crash-resumable.

`ingest` exits 0 on full success; with `--errors=skip|salvage` it exits
3 when any input was skipped, salvaged or quarantined (the session is
still written, carrying the machine-readable ingest report), and 2 for
hard failures.

`query` is the warehouse slice view: filter the session's traces by
host/step (parsed from trace labels, `host012_step003`-style) and its
rows by op/kind globs, then aggregate the slice — without merging or
materializing anything.  `diff` and `report` accept the same slice
specs (`host=00*,step=1`) in place of a trace label: matching traces
tree-merge into one side of the comparison.  `--mmap` opens an
*uncompressed* npz (`ingest --no-compress`) zero-copy, so fleet-scale
sessions slice without loading; exit codes follow `detect`/`lint`
(0 ok, 2 input errors).
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import os
import re
import sys
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.events import HloOpStats, Trace
from repro.core.hlo_parser import AUTO_SHARD_BYTES
from repro.core.persist import atomic_open, open_npz_mmap, write_npz
from repro.core.store import TraceStore
from repro.core.topology import Hardware, MeshSpec, V5E

_TRACE_SCALARS = ("hlo_flops", "hlo_bytes", "per_device_memory_bytes",
                  "argument_bytes", "output_bytes")


# --------------------------------------------------------------------------
# Trace <-> dict (rides on the columnar store serialization)
# --------------------------------------------------------------------------

def trace_to_dict(trace: Trace) -> Dict[str, object]:
    return {**_trace_meta(trace), "store": trace.store.to_dict()}


def trace_from_dict(d: Dict[str, object]) -> Trace:
    return _trace_from_meta(d, TraceStore.from_dict(d["store"]))


def _trace_meta(trace: Trace) -> Dict[str, object]:
    return {
        "label": trace.label,
        "mesh_shape": list(trace.mesh_shape),
        "mesh_axes": list(trace.mesh_axes),
        "num_devices": trace.num_devices,
        "scalars": {k: getattr(trace, k) for k in _TRACE_SCALARS},
        "op_stats": dataclasses.asdict(trace.op_stats),
    }


def _trace_from_meta(meta: Dict[str, object], store: TraceStore) -> Trace:
    return Trace.from_store(
        meta["label"], tuple(meta["mesh_shape"]), tuple(meta["mesh_axes"]),
        int(meta["num_devices"]), store,
        op_stats=HloOpStats(**meta["op_stats"]),
        **{k: float(v) for k, v in meta["scalars"].items()})


# --------------------------------------------------------------------------
# warehouse label metadata + slice specs
# --------------------------------------------------------------------------

# fleet dump naming convention: labels (= file stems) carry the host id
# and step index, e.g. "host012_step003".  The host capture requires a
# non-letter (or start) before "host" so e.g. "localhost" doesn't match.
_HOST_RE = re.compile(r"(?:^|[^A-Za-z])host[_-]?([0-9A-Za-z]+)")
_STEP_RE = re.compile(r"(?:^|[^A-Za-z])step[_-]?([0-9]+)")

_SLICE_KEYS = ("host", "step", "op", "kind")


def label_meta(label: str) -> Dict[str, object]:
    """Parse per-trace warehouse metadata out of a trace label.

    Returns a dict with `host` (string id) and/or `step` (int) when the
    label follows the `host012_step003` fleet-dump convention; keys are
    absent when the label carries no such marker.  This is the per-trace
    extension of the `IngestReport` per-file provenance — labels are
    file stems, so the ingest record and the trace agree.
    """
    meta: Dict[str, object] = {}
    m = _HOST_RE.search(label)
    if m:
        meta["host"] = m.group(1)
    m = _STEP_RE.search(label)
    if m:
        meta["step"] = int(m.group(1))
    return meta


def parse_slice(spec: str) -> Dict[str, str]:
    """Parse a `host=00*,step=3,op=*,kind=*` slice spec into kwargs.

    The CLI accepts these wherever a trace label is expected (`diff`,
    `report`) and as the `query` filter flags; unknown keys and bare
    words raise `ValueError` (CLI exit 2).
    """
    out: Dict[str, str] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(
                f"bad slice spec {part!r}: expected key=value with key "
                f"in {'/'.join(_SLICE_KEYS)}")
        k, v = part.split("=", 1)
        if k not in _SLICE_KEYS:
            raise ValueError(
                f"unknown slice key {k!r} (expected one of "
                f"{'/'.join(_SLICE_KEYS)})")
        if not v:
            raise ValueError(f"empty value for slice key {k!r} "
                             f"(use {k}=* to match everything)")
        out[k] = v
    return out


def _step_match(step: int, spec: str) -> bool:
    """Match a parsed step index against a numeric or glob spec."""
    spec = str(spec)
    if spec.isdigit():
        return step == int(spec)
    return (fnmatch.fnmatchcase(str(step), spec)
            or fnmatch.fnmatchcase(f"{step:03d}", spec))


# --------------------------------------------------------------------------
# bulk ingest — many HLO dumps -> one session, fanned out across processes
# --------------------------------------------------------------------------

class IngestError(RuntimeError):
    """A specific input failed to ingest.

    Raised by `TraceSession.from_hlo` with the offending file/label in
    the message (chained to the original error) — a genuine per-file
    failure must not be mistaken for pool unavailability and silently
    retried serially.
    """


@dataclasses.dataclass
class IngestRecord:
    """Per-input provenance of one `from_hlo` ingest.

    `status` is the outcome class:
      * `ok`          — parsed cleanly (possibly after retries);
      * `salvaged`    — strict parse failed, salvage parsing recovered a
        partial trace (`salvage` holds the `SalvageReport` dict);
      * `skipped`     — failed under `errors="skip"`, input excluded;
      * `quarantined` — failed even recovery (unreadable bytes, hung
        worker that also failed serially), input excluded.
    """

    source: str
    label: str
    status: str = "ok"
    attempts: int = 1
    error: str = ""
    salvage: Optional[Dict[str, object]] = None
    # warehouse provenance, derived from the label's fleet-dump naming
    # convention when not given (see `label_meta`); "" / None = unknown
    host: str = ""
    step: Optional[int] = None

    def __post_init__(self) -> None:
        if not self.host and self.step is None:
            meta = label_meta(self.label)
            self.host = str(meta.get("host", ""))
            self.step = meta.get("step")

    def to_dict(self) -> Dict[str, object]:
        return {"source": self.source, "label": self.label,
                "status": self.status, "attempts": int(self.attempts),
                "error": self.error, "salvage": self.salvage,
                "host": self.host, "step": self.step}

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "IngestRecord":
        return cls(source=d["source"], label=d["label"],
                   status=d.get("status", "ok"),
                   attempts=int(d.get("attempts", 1)),
                   error=d.get("error", ""), salvage=d.get("salvage"),
                   host=str(d.get("host", "")), step=d.get("step"))


@dataclasses.dataclass
class IngestReport:
    """Machine-readable record of every input a bulk ingest touched.

    Attached to the session `from_hlo` returns (and persisted with it),
    so a partial session carries the provenance of what was skipped,
    salvaged, or quarantined — the contract the `session ingest` exit
    codes (0 clean / 3 degraded) and the watch-daemon summary build on.
    """

    errors: str = "raise"
    records: List[IngestRecord] = dataclasses.field(default_factory=list)

    @property
    def degraded(self) -> List[IngestRecord]:
        return [r for r in self.records if r.status != "ok"]

    @property
    def ok(self) -> bool:
        return not self.degraded

    def to_dict(self) -> Dict[str, object]:
        return {"errors": self.errors,
                "records": [r.to_dict() for r in self.records]}

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "IngestReport":
        return cls(errors=d.get("errors", "raise"),
                   records=[IngestRecord.from_dict(r)
                            for r in d.get("records", ())])


def _retry_delays(retries: int, backoff_s: float):
    """Exponential backoff schedule: backoff, 2*backoff, 4*backoff, ..."""
    return [backoff_s * (1 << i) for i in range(max(retries, 0))]


def _ingest_one(job) -> Trace:
    """Worker: ingest one (label, hlo_text) through the columnar pipeline.

    Module-level so it pickles into `ProcessPoolExecutor` workers; the
    returned `Trace` ships back as its columnar store (rows stay lazy).
    """
    label, text, mesh, hw, engine, shards = job
    from repro.core.tracer import trace_from_hlo
    return trace_from_hlo(text, mesh, label=label, hw=hw, engine=engine,
                          shards=shards)


def _errstr(e: BaseException) -> str:
    return f"{type(e).__name__}: {e}"


def _read_text(path) -> str:
    with open(path) as f:
        return f.read()


def _ingest_jobs(items, mesh: MeshSpec, hw: Hardware, engine: str,
                 shards: Optional[int], *, errors: str = "raise",
                 retries: int = 0, backoff_s: float = 0.0) -> List:
    """One entry per input: (source, item, job | None, IngestRecord | None).

    A `None` job means the input could not even be read (missing file,
    undecodable bytes); under a non-raise policy that failure is
    pre-recorded as quarantined — after read retries with backoff, the
    file may still be landing — instead of raised, and the entry is
    excluded from parsing.
    """
    entries = []
    for it in items:
        if isinstance(it, (tuple, list)):
            label, text = it
            entries.append((label, it, (label, text, mesh, hw, engine,
                                        shards), None))
            continue
        src = str(it)
        label = os.path.splitext(os.path.basename(src))[0]
        attempts, err, text = 1, None, None
        try:
            text = _read_text(src)
        except Exception as e:
            err = e
            if errors == "raise":
                if isinstance(e, FileNotFoundError):
                    raise      # CLI reports the filename specially
                raise IngestError(f"failed to read {src!r}: {e}") from e
            for delay in _retry_delays(retries, backoff_s):
                time.sleep(delay)
                attempts += 1
                try:
                    text = _read_text(src)
                    err = None
                    break
                except Exception as e2:
                    err = e2
        if err is not None:
            entries.append((src, it, None,
                            IngestRecord(src, label, "quarantined", attempts,
                                         error=_errstr(err))))
        else:
            entries.append((src, it, (label, text, mesh, hw, engine,
                                      shards), None))
    return entries


def _recover_one(src: str, item, job, err: BaseException, errors: str,
                 retries: int, backoff_s: float):
    """Recovery ladder for one input whose strict parse failed.

    retry with exponential backoff (re-reading path inputs — the dump
    may have still been landing) -> salvage parse (`errors="salvage"`,
    columnar engine) -> skip/quarantine.  Returns (Trace | None,
    IngestRecord); a None trace means the input is excluded.
    """
    label, text, mesh, hw, engine, shards = job
    attempts, last = 1, err
    for delay in _retry_delays(retries, backoff_s):
        if delay > 0:
            time.sleep(delay)
        attempts += 1
        try:
            if not isinstance(item, (tuple, list)):
                text = _read_text(item)
            return (_ingest_one((label, text, mesh, hw, engine, shards)),
                    IngestRecord(src, label, "ok", attempts))
        except Exception as e:
            last = e
    if errors == "salvage" and engine == "columnar" and isinstance(text, str):
        from repro.core.tracer import trace_from_hlo
        try:
            tr = trace_from_hlo(text, mesh, label=label, hw=hw,
                                engine=engine, recover=True)
            sal = tr.salvage.to_dict() if tr.salvage is not None else None
            return tr, IngestRecord(src, label, "salvaged", attempts,
                                    error=_errstr(last), salvage=sal)
        except Exception as e:
            last = e
    status = "skipped" if errors == "skip" else "quarantined"
    return None, IngestRecord(src, label, status, attempts,
                              error=_errstr(last))


class TraceSession:
    """An ordered, label-addressed collection of traces."""

    def __init__(self, name: str, traces: Optional[Sequence[Trace]] = None):
        self.name = name
        # provenance of the bulk ingest that built this session (set by
        # `from_hlo`, persisted through save/load); None for hand-built
        # or legacy-loaded sessions
        self.ingest_report: Optional[IngestReport] = None
        self._traces: List[Trace] = []
        for t in traces or ():
            self.add(t)

    # -- collection interface -----------------------------------------------

    def add(self, trace: Trace) -> Trace:
        if trace.label in self.labels():
            raise ValueError(f"duplicate trace label {trace.label!r} "
                             f"in session {self.name!r}")
        self._traces.append(trace)
        return trace

    def labels(self) -> List[str]:
        return [t.label for t in self._traces]

    def get(self, label: str) -> Trace:
        for t in self._traces:
            if t.label == label:
                return t
        raise KeyError(f"no trace {label!r} in session {self.name!r} "
                       f"(have {self.labels()})")

    def __len__(self) -> int:
        return len(self._traces)

    def __iter__(self) -> Iterator[Trace]:
        return iter(self._traces)

    # -- aggregate views -----------------------------------------------------

    def aggregate(self, by: str = "kind_link") -> Dict[str, Dict[str, Dict[str, float]]]:
        """{trace label: {traffic class: {bytes, wire_bytes, count, time_s}}}."""
        fn = {"kind_link": lambda t: t.by_kind_and_link(),
              "semantic": lambda t: t.by_semantic()}[by]
        return {t.label: fn(t) for t in self._traces}

    def totals(self) -> List[Dict[str, float]]:
        """Per-trace one-line summaries (the session overview rows)."""
        return [{
            "label": t.label,
            "sites": t.store.n,
            "collectives_per_step": float(t.store.multiplicity.sum()),
            "collective_gb": t.total_collective_bytes() / 1e9,
            "wire_gb": t.total_wire_bytes() / 1e9,
            "est_ms": t.total_est_time_s() * 1e3,
            "overlapped_ms": t.overlapped_est_time_s() * 1e3,
        } for t in self._traces]

    def table(self, by: str = "kind_link", metric: str = "bytes") -> str:
        from repro.core.report import session_table
        return session_table(self._traces, by=by, metric=metric)

    def diff(self, label_a: str, label_b: str, by: str = "kind_link",
             top: Optional[int] = None, only_regressed: bool = False,
             as_json: bool = False) -> str:
        """Pairwise diff between two labels or fleet slices.

        Either side may be a trace label or a `host=00*,step=1` slice
        spec (see `parse_slice`): slice sides tree-merge their matching
        traces into one synthetic trace first, so "hosts 00x vs hosts
        01x" is one diff, not a quadratic pile of pairs.  `top` keeps
        only the N largest-|byte-delta| rows, `only_regressed` keeps
        NEW/GREW rows, and `as_json` returns the machine-readable
        payload (`diff.diff_json`, with a `slice` block naming the
        specs) instead of the rendered table.
        """
        from repro.core.diff import diff_json, render_diff
        a, n_a = self._resolve(label_a)
        b, n_b = self._resolve(label_b)
        if as_json:
            extra = None
            if n_a is not None or n_b is not None:
                extra = {"a": {"spec": label_a,
                               "traces": 1 if n_a is None else n_a},
                         "b": {"spec": label_b,
                               "traces": 1 if n_b is None else n_b}}
            return json.dumps(diff_json(a, b, by=by, top=top,
                                        only_regressed=only_regressed,
                                        extra=extra),
                              indent=1)
        return render_diff(a, b, by=by, top=top,
                           only_regressed=only_regressed)

    # -- warehouse query layer -----------------------------------------------

    def select(self, host: Optional[str] = None, step: Optional[str] = None,
               op: Optional[str] = None, kind: Optional[str] = None
               ) -> "TraceSession":
        """The sub-session matching a warehouse slice.

        `host`/`step` filter whole traces on their label metadata
        (`label_meta`; shell globs, numeric steps match exactly).
        `op`/`kind` filter *rows* inside each surviving trace on the
        interned codes (`Categorical.mask_glob` — O(vocab) string work,
        one vectorized mask per column) *before* any rollup runs.
        Traces with no row filter are shared by reference, so slicing a
        memory-mapped session stays zero-copy.
        """
        out: List[Trace] = []
        for t in self._traces:
            meta = label_meta(t.label)
            if host is not None and not fnmatch.fnmatchcase(
                    str(meta.get("host", "")), host):
                continue
            if step is not None:
                st = meta.get("step")
                if st is None or not _step_match(st, step):
                    continue
            if op is not None or kind is not None:
                mask = np.ones(t.store.n, dtype=bool)
                if op is not None:
                    mask &= t.store.op_name.mask_glob(op)
                if kind is not None:
                    mask &= t.store.kind.mask_glob(kind)
                t = _trace_from_meta(_trace_meta(t), t.store.where(mask))
            out.append(t)
        sel = TraceSession(self.name, out)
        sel.ingest_report = self.ingest_report
        return sel

    def merged(self, label: str = "fleet", arity: int = 8,
               workers: int = 1) -> Trace:
        """All traces tree-merged into one synthetic fleet trace.

        Store rows concatenate in session order via
        `TraceStore.merge_tree` (identical to the flat merge, O(log n)
        reduction depth); scalars sum and op stats fold with
        `HloOpStats.merged`.  Mesh metadata comes from the first trace —
        a fleet dump shares one mesh by construction.  A single-trace
        session returns that trace's store unmerged (and uncopied).
        """
        if not self._traces:
            raise KeyError(
                f"session {self.name!r} has no traces to merge")
        store = TraceStore.merge_tree([t.store for t in self._traces],
                                      arity=arity, workers=workers)
        meta = _trace_meta(self._traces[0])
        meta["label"] = label
        meta["scalars"] = {
            k: float(sum(getattr(t, k) for t in self._traces))
            for k in _TRACE_SCALARS}
        meta["op_stats"] = dataclasses.asdict(
            HloOpStats.merged([t.op_stats for t in self._traces]))
        return _trace_from_meta(meta, store)

    def _resolve(self, label: str) -> Tuple[Trace, Optional[int]]:
        """A trace for a label *or* slice spec: (trace, n merged | None).

        A spec containing "=" selects+merges (raising `KeyError` when it
        matches nothing, same contract as an unknown label); a plain
        label passes through `get`.
        """
        if "=" in label:
            sel = self.select(**parse_slice(label))
            if not len(sel):
                raise KeyError(
                    f"slice {label!r} matches no traces in session "
                    f"{self.name!r} (have {self.labels()})")
            return sel.merged(label=label), len(sel)
        return self.get(label), None

    def query(self, host: Optional[str] = None, step: Optional[str] = None,
              op: Optional[str] = None, kind: Optional[str] = None,
              by: str = "kind_link") -> Dict[str, object]:
        """Aggregate a warehouse slice without merging or materializing.

        Filters with `select`, then folds the surviving stores through
        `IncrementalRollup` — O(unique labels) state, no concatenation —
        so querying a memory-mapped fleet session touches only the
        columns the rollup reads.  Returns the stable machine payload
        (`session query --json`): slice echo, per-trace rows, fleet
        totals, and the requested rollup.
        """
        from repro.core.store import IncrementalRollup
        sel = self.select(host=host, step=step, op=op, kind=kind)
        roll = IncrementalRollup(by)
        for t in sel:
            roll.update(t.store)
        rows = roll.as_dict()
        totals = {m: float(sum(r[m] for r in rows.values()))
                  for m in ("bytes", "wire_bytes", "count", "time_s")}
        payload: Dict[str, object] = {
            "session": self.name,
            "slice": {"host": host, "step": step, "op": op, "kind": kind},
            "traces": sel.labels(),
            "sites": int(sum(t.store.n for t in sel)),
            "totals": totals,
            "rollup": {"by": by, "rows": rows},
        }
        if self.ingest_report is not None:
            degraded = self.ingest_report.degraded
            payload["ingest"] = {
                "records": len(self.ingest_report.records),
                "degraded": len(degraded),
                "degraded_hosts": sorted({r.host for r in degraded
                                          if r.host}),
            }
        return payload

    def report(self, label: Optional[str] = None, fmt: str = "json",
               fp=None, stream: bool = False, chunk_sites: int = 8192):
        """Render one trace (default: the first) as JSON or HTML.

        `label` may also be a `host=00*`-style slice spec: the matching
        traces tree-merge into one synthetic fleet trace first.  With
        `fp` set, writes to it — streamed through the chunked columnar
        emitters when `stream=True` (bounded memory at 1M+ sites).
        Without `fp`, returns the rendered string.
        """
        from repro.core import report as report_mod
        if not self._traces:
            raise KeyError(f"session {self.name!r} has no traces to report")
        tr = self._resolve(label)[0] if label is not None else self._traces[0]
        mesh = MeshSpec(tr.mesh_shape, tr.mesh_axes)
        if fp is None:
            return report_mod.to_json(tr) if fmt == "json" \
                else report_mod.to_html(tr, mesh)
        if fmt == "json":
            if stream:
                report_mod.write_json(tr, fp, chunk_sites=chunk_sites)
            else:
                fp.write(report_mod.to_json(tr))
        else:
            if stream:
                report_mod.write_html(tr, mesh, fp)
            else:
                fp.write(report_mod.to_html(tr, mesh))
        fp.write("\n")
        return None

    # -- bulk ingest ---------------------------------------------------------

    @classmethod
    def from_hlo(cls, name: str,
                 items: Sequence[Union[str, Tuple[str, str]]],
                 mesh: MeshSpec, *, hw: Hardware = V5E,
                 engine: str = "columnar",
                 max_workers: Optional[int] = None,
                 shards: Optional[int] = None,
                 errors: str = "raise",
                 retries: int = 1,
                 retry_backoff_s: float = 0.1,
                 timeout_s: Optional[float] = None) -> "TraceSession":
        """Ingest many HLO dumps into one session, in parallel.

        `items` are either `(label, hlo_text)` pairs or paths to HLO text
        files (label = file stem).  Each file runs the full columnar
        pipeline (parse -> annotate -> attribute) in its own worker
        process; results come back as columnar stores.  Falls back to
        serial ingest when the *pool* is unavailable (restricted
        environments, spawn bootstrap failure, pool death) or for a
        single file.

        `errors` is the per-input failure policy:
          * `"raise"` (default) — a genuine per-file failure raises
            `IngestError` naming the offending input instead of silently
            re-running everything serially.  Zero overhead on clean
            inputs; the returned session still carries an all-ok
            `ingest_report`.
          * `"skip"` — failed inputs are retried (`retries` attempts
            with exponential backoff from `retry_backoff_s`, re-reading
            path inputs) then dropped; the session holds the survivors.
          * `"salvage"` — like skip, but a damaged module is first
            re-parsed with salvage recovery
            (`parse_hlo_store(recover=True)`): intact computations are
            kept as a partial trace, and only inputs that defeat even
            salvage (unreadable bytes, no recoverable computations) are
            quarantined.

        Every input's outcome lands in `session.ingest_report`
        (an `IngestReport`, persisted through save/load), so a partial
        session is never silently partial.

        `timeout_s` bounds each worker's result: a hung worker kills the
        pool, and the stuck input plus everything still pending is
        retried serially under the same `errors` policy (quarantined if
        it fails again).

        `shards` additionally splits each *single* module per-computation
        across workers (`None` = auto above `hlo_parser.AUTO_SHARD_BYTES`,
        `1` = serial).  When the per-file pool is used, *auto*-sharding is
        pinned to 1 — the file fan-out already owns the cores — but an
        explicit `shards=N` is honored inside each file worker (the
        caller opted into the oversubscription).
        """
        if errors not in ("raise", "skip", "salvage"):
            raise ValueError(f"errors must be 'raise', 'skip' or 'salvage', "
                             f"got {errors!r}")
        pool_files = max_workers is None or max_workers > 1
        if max_workers is None:
            max_workers = min(len(items), os.cpu_count() or 1)
        pool_files = pool_files and max_workers > 1 and len(items) > 1
        entries = _ingest_jobs(items, mesh, hw, engine,
                               (shards or 1) if pool_files else shards,
                               errors=errors, retries=retries,
                               backoff_s=retry_backoff_s)
        # input-order maps: results[i] -> Trace, recs[i] -> IngestRecord
        results: Dict[int, Trace] = {}
        recs: Dict[int, IngestRecord] = {
            i: rec for i, (_s, _it, job, rec) in enumerate(entries)
            if job is None}
        live = [(i, src, it, job)
                for i, (src, it, job, _rec) in enumerate(entries)
                if job is not None]
        pending = None      # live subset to (re)run serially
        if pool_files:
            import concurrent.futures as cf
            import multiprocessing
            import pickle
            from concurrent.futures import ProcessPoolExecutor
            from concurrent.futures.process import BrokenProcessPool
            from repro.core.hlo_parser import _SPAWN_PROBE_TIMEOUT_S

            # spawn, not fork: the parent often has jax loaded (and so
            # multiple live threads) by the time a sweep is ingested,
            # and forking a multithreaded process can deadlock workers.
            ex = None
            try:
                ex = ProcessPoolExecutor(
                    max_workers=max_workers,
                    mp_context=multiprocessing.get_context("spawn"))
                # no-op probe: where spawn cannot bootstrap workers the
                # map below hangs rather than raising, so pool *startup*
                # failure — and only that — is detected here and falls
                # back to serial ingest
                ex.submit(int).result(timeout=_SPAWN_PROBE_TIMEOUT_S)
            except Exception:
                if ex is not None:
                    ex.shutdown(wait=False, cancel_futures=True)
                ex = None
            if ex is None:
                pending = live
            else:
                futs = [ex.submit(_ingest_one, job)
                        for _i, _s, _it, job in live]
                pending = []
                dead = False
                try:
                    for (i, src, it, job), fut in zip(live, futs):
                        if dead:
                            pending.append((i, src, it, job))
                            continue
                        try:
                            results[i] = fut.result(timeout=timeout_s)
                            recs[i] = IngestRecord(src, job[0])
                        except (BrokenProcessPool, pickle.PicklingError):
                            # the pool died, not the input: retry serially
                            dead = True
                            pending.append((i, src, it, job))
                        except cf.TimeoutError:
                            # hung worker: kill the pool; this input and
                            # everything still pending retries serially
                            # (quarantined under skip/salvage if it fails
                            # again)
                            dead = True
                            pending.append((i, src, it, job))
                        except Exception as e:
                            if errors == "raise":
                                raise IngestError(
                                    f"failed to ingest {src!r}: {e}") from e
                            tr, rec = _recover_one(src, it, job, e, errors,
                                                   retries, retry_backoff_s)
                            if tr is not None:
                                results[i] = tr
                            recs[i] = rec
                finally:
                    ex.shutdown(wait=False, cancel_futures=True)
            if pending:
                # serial per file (texts already in memory); single-module
                # sharding may still parallelize inside each parse
                pending = [(i, src, it, job[:5] + (shards,))
                           for i, src, it, job in pending]
        if pending is None:
            pending = live
        for i, src, it, job in pending:
            try:
                results[i] = _ingest_one(job)
                recs[i] = IngestRecord(src, job[0])
            except Exception as e:
                if errors == "raise":
                    raise IngestError(f"failed to ingest {src!r}: {e}") from e
                tr, rec = _recover_one(src, it, job, e, errors,
                                       retries, retry_backoff_s)
                if tr is not None:
                    results[i] = tr
                recs[i] = rec
        report = IngestReport(errors=errors,
                              records=[recs[i] for i in sorted(recs)])
        sess = cls(name, [results[i] for i in sorted(results)])
        sess.ingest_report = report
        return sess

    # -- persistence ---------------------------------------------------------

    def save(self, path: str, *, compress: bool = True,
             workers: Optional[int] = None) -> str:
        """Persist to `path` (.json or .npz, by extension; default .json).

        Writes are atomic (same-directory temp file + `os.replace`): a
        concurrent reader — the watch daemon re-saving every poll while
        CI collects artifacts — sees the previous complete file or the
        new one, never a torn intermediate.  Returns the path actually
        written; `load` applies the same extension defaulting, so
        `load(p)` works for any extensionless `p` passed to `save`.

        The npz container is `persist.write_npz`: byte-deterministic
        (same session -> same file) and DEFLATE'd across a thread pool
        (`workers`; zlib releases the GIL) while one writer assembles
        the archive — the `savez_compressed` single-thread bottleneck
        is gone.  `compress=False` stores members raw, the layout
        `load(mmap=True)` opens zero-copy.
        """
        rep = self.ingest_report.to_dict() if self.ingest_report else None
        if path.endswith(".npz"):
            arrs: Dict[str, np.ndarray] = {}
            for i, t in enumerate(self._traces):
                arrs.update(t.store.npz_arrays(prefix=f"t{i}_"))
            side = {"name": self.name,
                    "traces": [_trace_meta(t) for t in self._traces]}
            if rep is not None:
                side["ingest_report"] = rep
            arrs["session"] = np.array(json.dumps(side))
            with atomic_open(path, "wb") as f:
                write_npz(f, arrs, compress=compress, workers=workers)
            return path
        if not path.endswith(".json"):
            path += ".json"
        payload = {"name": self.name,
                   "traces": [trace_to_dict(t) for t in self._traces]}
        if rep is not None:
            payload["ingest_report"] = rep
        with atomic_open(path, "w") as f:
            json.dump(payload, f, separators=(",", ":"),
                      sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str, *, mmap: bool = False) -> "TraceSession":
        """Load a saved session; `mmap=True` opens an npz zero-copy.

        The mmap path requires an *uncompressed* archive (`save` with
        `compress=False` / `session ingest --no-compress`): columns
        adopt read-only memory maps lazily (`TraceStore.from_npz_arrays
        (lazy=True)`), so a 10M-site session opens without
        materializing row data — pages fault in as queries touch them,
        and any mutation (`append`) copies instead of writing through.
        Raises `ValueError` for a compressed archive or a non-npz path.
        """
        if not path.endswith((".json", ".npz")):
            path += ".json"    # mirror save's extension defaulting
        if path.endswith(".npz"):
            if mmap:
                if not os.path.exists(path):
                    raise FileNotFoundError(path)
                marrs = open_npz_mmap(path)
                side = json.loads(str(marrs["session"]))
                traces = [
                    _trace_from_meta(
                        meta, TraceStore.from_npz_arrays(
                            marrs, prefix=f"t{i}_", lazy=True))
                    for i, meta in enumerate(side["traces"])]
            else:
                with np.load(path) as arrs:
                    side = json.loads(str(arrs["session"]))
                    traces = [
                        _trace_from_meta(
                            meta, TraceStore.from_npz_arrays(
                                arrs, prefix=f"t{i}_"))
                        for i, meta in enumerate(side["traces"])]
            sess = cls(side["name"], traces)
            if side.get("ingest_report") is not None:
                sess.ingest_report = IngestReport.from_dict(
                    side["ingest_report"])
            return sess
        if mmap:
            raise ValueError(
                f"mmap load requires an uncompressed .npz session, "
                f"got {path!r}")
        with open(path) as f:
            payload = json.load(f)
        sess = cls(payload["name"],
                   [trace_from_dict(d) for d in payload["traces"]])
        if payload.get("ingest_report") is not None:
            sess.ingest_report = IngestReport.from_dict(
                payload["ingest_report"])
        return sess


# --------------------------------------------------------------------------
# demo session: the "Allreduce across MPI libraries / UCX settings" shape
# --------------------------------------------------------------------------

def demo_session(n_sites: int = 2000, seed: int = 0) -> TraceSession:
    """Three mesh/config variants of the same synthetic workload.

    The knobs mirror the paper's comparison dimensions: mesh layout
    (NUMA-binding analogue), rendezvous threshold (UCX setting analogue),
    and axis bias (library algorithm-choice analogue).
    """
    import dataclasses as dc

    from repro.core.synth import synthetic_trace
    from repro.core.topology import MeshSpec, V5E

    sess = TraceSession("demo-allreduce-sweep")
    sess.add(synthetic_trace(
        "dp8-baseline", MeshSpec((8,), ("data",)), V5E,
        n_sites=n_sites, seed=seed))
    sess.add(synthetic_trace(
        "dp2xtp4", MeshSpec((2, 4), ("data", "model")), V5E,
        n_sites=n_sites, seed=seed, axis_weights=(2.0, 1.0)))
    sess.add(synthetic_trace(
        "pod2xdp4-rndv64k", MeshSpec((2, 4), ("pod", "data")),
        dc.replace(V5E, rndv_threshold=1 << 16),
        n_sites=n_sites, seed=seed, axis_weights=(1.0, 3.0)))
    return sess


def _main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.session",
        description="multi-trace session workflows")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("demo", help="build, save, reload and compare a "
                                    "3-config synthetic sweep")
    p.add_argument("--out", default="results/session_demo.json",
                   help="save path (default results/session_demo.json)")
    p.add_argument("--format", choices=("json", "npz"), default=None,
                   help="force the session format, overriding the --out "
                        "extension")
    p.add_argument("--sites", type=int, default=2000,
                   help="synthetic collective sites per trace "
                        "(default 2000)")

    p = sub.add_parser(
        "ingest",
        help="parse HLO dump files into a session (parallel columnar "
             "ingest)",
        description="Parse HLO dump files into one saved session. "
                    "Exit codes: with --errors=raise (default), 0 on "
                    "success and 2 on the first bad input; with "
                    "--errors=skip|salvage, 0 only when every input "
                    "ingested cleanly, 3 when any input was skipped, "
                    "salvaged or quarantined (the session is still "
                    "written with the survivors and carries the ingest "
                    "report), and 2 for hard failures (unwritable "
                    "output, bad arguments).")
    p.add_argument("out", help="output session path (.json or .npz)")
    p.add_argument("files", nargs="+", help="HLO text files")
    p.add_argument("--mesh", default="2,4",
                   help="mesh shape, comma-separated (default 2,4)")
    p.add_argument("--axes", default="data,model",
                   help="mesh axis names, comma-separated")
    p.add_argument("--workers", type=int, default=None,
                   help="worker processes for the per-file fan-out "
                        "(default: one per file, capped at CPU count; "
                        "1 = serial)")
    p.add_argument("--shards", type=int, default=None,
                   help="split each single module per-computation across "
                        "this many parse shards (default: auto above "
                        f"{AUTO_SHARD_BYTES >> 20}MB, or serial when the "
                        "multi-file pool owns the cores; 1 = serial)")
    p.add_argument("--errors", choices=("raise", "skip", "salvage"),
                   default="raise",
                   help="per-input failure policy: raise (default) aborts "
                        "with exit 2 on the first bad input; skip retries "
                        "then drops bad inputs; salvage additionally "
                        "recovers the intact computations of damaged "
                        "modules as partial traces. skip/salvage exit 0 "
                        "on full success, 3 when anything was degraded")
    p.add_argument("--retries", type=int, default=1,
                   help="re-attempts per failed input, with exponential "
                        "backoff (default 1; skip/salvage only)")
    p.add_argument("--retry-backoff", type=float, default=0.1,
                   help="initial retry backoff in seconds, doubling per "
                        "attempt (default 0.1)")
    p.add_argument("--timeout", type=float, default=None,
                   help="per-file worker timeout in seconds: a hung "
                        "worker kills the pool and the file is retried "
                        "serially, then quarantined (default: none)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="print the machine-readable ingest report "
                        "(every input's outcome) to stdout")
    p.add_argument("--no-compress", action="store_true",
                   help="store npz members raw instead of DEFLATE'd — "
                        "the layout `query`/`diff --mmap` opens "
                        "zero-copy (larger file, instant open)")

    p = sub.add_parser("watch", help="tail an HLO dump directory: ingest "
                                     "new/changed files, keep rolling "
                                     "reports fresh (live profiling)")
    p.add_argument("root", help="dump directory to watch")
    p.add_argument("--pattern", default="*.txt",
                   help="glob for dump files inside ROOT (default *.txt)")
    p.add_argument("--mesh", default="2,4",
                   help="mesh shape, comma-separated (default 2,4)")
    p.add_argument("--axes", default="data,model",
                   help="mesh axis names, comma-separated")
    p.add_argument("--out", default=None,
                   help="rolling session save path (.json or .npz)")
    p.add_argument("--report-json", default=None,
                   help="rolling JSON report path (first trace)")
    p.add_argument("--report-html", default=None,
                   help="rolling HTML report path (first trace)")
    p.add_argument("--summary", default=None,
                   help="rolling machine summary JSON (aggregates + "
                        "findings)")
    p.add_argument("--settle", type=float, default=0.25,
                   help="seconds a file's size+mtime must hold still "
                        "before it is ingested (default 0.25)")
    p.add_argument("--interval", type=float, default=1.0,
                   help="seconds between polls (default 1.0)")
    p.add_argument("--once", action="store_true",
                   help="ingest until the directory is quiescent, then "
                        "exit (CI/testing mode)")
    p.add_argument("--fail-on", choices=("critical", "warn", "info", "never"),
                   default="never",
                   help="print alerts and exit 1 when any finding reaches "
                        "this severity (default: never); without alerts "
                        "the daemon exits 3 when any input was salvaged "
                        "or quarantined, else 0")
    p.add_argument("--shards", type=int, default=None,
                   help="parse shards per ingested file (default: auto)")
    p.add_argument("--max-rounds", type=int, default=None,
                   help="stop after this many polls (default: unbounded)")
    p.add_argument("--quiet", action="store_true",
                   help="suppress per-round progress lines")
    p.add_argument("--errors", choices=("raise", "skip", "salvage"),
                   default="salvage",
                   help="per-file failure policy: salvage (default) "
                        "recovers the intact computations of damaged "
                        "dumps, skip quarantines them whole, raise "
                        "crashes the daemon (strict mode)")
    p.add_argument("--max-retries", type=int, default=3,
                   help="same-signature re-attempts (with exponential "
                        "backoff) before a failing file's quarantine "
                        "seals until the file changes (default 3)")
    p.add_argument("--retry-backoff", type=float, default=0.5,
                   help="initial quarantine retry backoff in seconds, "
                        "doubling per failure (default 0.5)")
    p.add_argument("--checkpoint", default=None,
                   help="crash-resume checkpoint path (.npz): atomically "
                        "rewritten after every state-changing poll; a "
                        "daemon restarted on the same checkpoint resumes "
                        "without re-parsing already-ingested files")

    p = sub.add_parser("show", help="per-trace summaries of a saved session")
    p.add_argument("path", help="saved session (.json or .npz)")

    p = sub.add_parser("table", help="n-way comparison table")
    p.add_argument("path", help="saved session (.json or .npz)")
    p.add_argument("--by", choices=("kind_link", "semantic", "site"),
                   default="kind_link",
                   help="rollup key; 'site' breaks out per compiled "
                        "callsite (op_name x kind x axes)")
    p.add_argument("--metric", choices=("bytes", "time", "count"),
                   default="bytes",
                   help="cell metric: operand bytes, modeled est time, "
                        "or collective count per step (default bytes)")

    p = sub.add_parser("diff", help="pairwise deep-dive between two labels "
                                    "or fleet slices")
    p.add_argument("path", help="saved session (.json or .npz)")
    p.add_argument("label_a", help="baseline trace label, or a fleet slice "
                                   "spec like host=00*,step=1 (matching "
                                   "traces tree-merge into one side)")
    p.add_argument("label_b", help="candidate trace label or slice spec "
                                   "(deltas are B-A)")
    p.add_argument("--by", choices=("kind_link", "semantic", "site"),
                   default="kind_link",
                   help="alignment key; 'site' aligns per compiled callsite "
                        "(op_name x kind x axes)")
    p.add_argument("--top", type=int, default=None,
                   help="keep only the N largest-|byte-delta| rows")
    p.add_argument("--only-regressed", action="store_true",
                   help="keep only rows that grew or are new in B")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit a machine-readable JSON diff instead of the "
                        "rendered table")
    p.add_argument("--mmap", action="store_true",
                   help="open an uncompressed npz session zero-copy "
                        "(see `ingest --no-compress`)")

    p = sub.add_parser(
        "query",
        help="filter a saved session by host/step/op/kind and aggregate "
             "the slice (warehouse view)",
        description="Select traces by host/step (parsed from "
                    "host012_step003-style labels) and rows by op/kind "
                    "globs, then aggregate the slice without merging. "
                    "Exit codes: 0 on success (an empty slice is a "
                    "valid, empty answer), 2 on input errors — same "
                    "contract as detect/lint.")
    p.add_argument("path", help="saved session (.json or .npz)")
    p.add_argument("--host", default=None,
                   help="host id glob (e.g. 00*), matched against the "
                        "trace label's hostNNN marker")
    p.add_argument("--step", default=None,
                   help="step index (numeric, exact) or glob against the "
                        "label's stepNNN marker")
    p.add_argument("--op", default=None,
                   help="op_name glob, filters rows on interned codes")
    p.add_argument("--kind", default=None,
                   help="collective kind glob (e.g. all-reduce*)")
    p.add_argument("--by", choices=("kind_link", "semantic", "site"),
                   default="kind_link",
                   help="rollup key for the slice aggregate "
                        "(default kind_link)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the stable machine payload instead of text")
    p.add_argument("--mmap", action="store_true",
                   help="open an uncompressed npz session zero-copy "
                        "(see `ingest --no-compress`)")

    p = sub.add_parser("lint", help="static collective-correctness analysis "
                                    "(commcheck) over sessions or HLO dumps")
    p.add_argument("paths", nargs="+",
                   help="saved sessions (.json/.npz) or HLO text files")
    p.add_argument("--mesh", default="2,4",
                   help="mesh shape for HLO inputs, comma-separated")
    p.add_argument("--axes", default="data,model",
                   help="mesh axis names for HLO inputs, comma-separated")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the stable machine schema (same as "
                        "`detect --json`) instead of text")
    p.add_argument("--fail-on", choices=("critical", "warn", "info", "never"),
                   default="critical",
                   help="exit 1 when any finding reaches this severity "
                        "(default: critical)")

    p = sub.add_parser("detect", help="dynamic performance detectors over "
                                      "a saved session")
    p.add_argument("path", help="saved session (.json or .npz)")
    p.add_argument("label", nargs="?", default=None,
                   help="trace label (default: all traces)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the stable machine schema (same as "
                        "`lint --json`) instead of text")
    p.add_argument("--fail-on", choices=("critical", "warn", "info", "never"),
                   default="never",
                   help="exit 1 when any finding reaches this severity "
                        "(default: never — detectors are advisory)")

    p = sub.add_parser("report", help="render one trace of a session as "
                                      "JSON or a self-contained HTML page",
                       epilog="the report carries the full per-trace "
                              "rollups and findings; for interactive "
                              "per-callsite views use `table --by site` "
                              "and `diff --by site`")
    p.add_argument("path", help="saved session (.json or .npz)")
    p.add_argument("label", nargs="?", default=None,
                   help="trace label or fleet slice spec like host=00* "
                        "(default: the session's first trace)")
    p.add_argument("--format", choices=("json", "html"), default="json",
                   help="output format (default json)")
    p.add_argument("--out", default=None, help="output file (default stdout)")
    p.add_argument("--stream", action="store_true",
                   help="stream through the chunked columnar emitters "
                        "(bounded memory for very large traces)")
    p.add_argument("--chunk-sites", type=int, default=8192,
                   help="sites per chunk when streaming (default 8192)")

    p = sub.add_parser(
        "whatif",
        help="hardwareless config sweep: re-price a trace under "
             "counterfactual meshes/thresholds and rank the savings",
        description="Re-annotate one trace under a grid of what-if "
                    "scenarios (mesh axis permutations, rendezvous "
                    "threshold tiers, link bandwidth/latency tiers) "
                    "without re-parsing or hardware, and rank scenarios "
                    "by estimated step time saved vs the baseline. "
                    "Exit codes: 0 on success, 2 on input errors.")
    p.add_argument("path", help="saved session (.json/.npz) or HLO text "
                                "file")
    p.add_argument("label", nargs="?", default=None,
                   help="trace label (default: the session's first trace; "
                        "ignored for HLO inputs)")
    p.add_argument("--mesh", default="2,4",
                   help="mesh shape for HLO inputs, comma-separated "
                        "(saved sessions carry their own mesh)")
    p.add_argument("--axes", default="data,model",
                   help="mesh axis names for HLO inputs, comma-separated")
    p.add_argument("--top", type=int, default=5,
                   help="top per-site savings kept per scenario "
                        "(default 5)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the machine-readable sweep (baseline + "
                        "every scenario, ranked by time saved) instead "
                        "of the table")

    args = ap.parse_args(argv)

    if args.cmd == "demo":
        out = args.out
        if args.format and not out.endswith("." + args.format):
            out = os.path.splitext(out)[0] + "." + args.format
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        sess = demo_session(n_sites=args.sites)
        path = sess.save(out)
        loaded = TraceSession.load(path)
        print(f"session '{loaded.name}': {len(loaded)} traces -> {path} "
              f"({os.path.getsize(path)//1024} KB)")
        _print_totals(loaded)
        print()
        print(loaded.table())
        print()
        print(loaded.table(by="semantic", metric="time"))
        return 0

    if args.cmd == "ingest":
        shape = tuple(int(x) for x in args.mesh.split(","))
        axes = tuple(args.axes.split(","))
        if len(shape) != len(axes):
            print("error: --mesh and --axes must have the same rank",
                  file=sys.stderr)
            return 2
        mesh = MeshSpec(shape, axes)
        try:
            sess = TraceSession.from_hlo(
                os.path.splitext(os.path.basename(args.out))[0],
                args.files, mesh, max_workers=args.workers,
                shards=args.shards, errors=args.errors,
                retries=args.retries, retry_backoff_s=args.retry_backoff,
                timeout_s=args.timeout)
        except FileNotFoundError as e:
            print(f"error: no such file: {e.filename}", file=sys.stderr)
            return 2
        except IngestError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        path = sess.save(args.out, compress=not args.no_compress)
        rep = sess.ingest_report
        if args.as_json:
            print(json.dumps(rep.to_dict(), indent=1))
        else:
            print(f"session '{sess.name}': ingested {len(sess)} traces "
                  f"-> {path}")
            if len(sess):
                _print_totals(sess)
        for r in rep.degraded:
            print(f"ingest: [{r.status}] {r.source} "
                  f"({r.attempts} attempt(s)): {r.error}", file=sys.stderr)
        return 3 if rep.degraded else 0

    if args.cmd == "watch":
        from repro.core.watch import WatchConfig, WatchDaemon
        shape = tuple(int(x) for x in args.mesh.split(","))
        axes = tuple(args.axes.split(","))
        if len(shape) != len(axes):
            print("error: --mesh and --axes must have the same rank",
                  file=sys.stderr)
            return 2
        if not os.path.isdir(args.root):
            print(f"error: no such directory: {args.root}", file=sys.stderr)
            return 2
        for out in (args.out, args.report_json, args.report_html,
                    args.summary, args.checkpoint):
            if out:
                os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        cfg = WatchConfig(
            root=args.root, mesh=MeshSpec(shape, axes),
            pattern=args.pattern, out=args.out,
            report_json=args.report_json, report_html=args.report_html,
            summary=args.summary, settle_s=args.settle,
            interval_s=args.interval, once=args.once,
            fail_on=args.fail_on, shards=args.shards,
            max_rounds=args.max_rounds, quiet=args.quiet,
            errors=args.errors, max_retries=args.max_retries,
            retry_backoff_s=args.retry_backoff, checkpoint=args.checkpoint)
        return WatchDaemon(cfg).run()

    if args.cmd == "lint":
        from repro.core import commcheck
        shape = tuple(int(x) for x in args.mesh.split(","))
        axes = tuple(args.axes.split(","))
        if len(shape) != len(axes):
            print("error: --mesh and --axes must have the same rank",
                  file=sys.stderr)
            return 2
        mesh = MeshSpec(shape, axes)
        results = []
        for path in args.paths:
            try:
                if path.endswith((".json", ".npz")):
                    for t in TraceSession.load(path):
                        results.append((path, t.label,
                                        commcheck.check_trace(t)))
                else:
                    from repro.core.tracer import trace_from_hlo
                    with open(path) as f:
                        text = f.read()
                    label = os.path.splitext(os.path.basename(path))[0]
                    tr = trace_from_hlo(text, mesh, label=label)
                    results.append((path, label,
                                    commcheck.check_trace(tr, mesh)))
            except FileNotFoundError:
                print(f"error: no such file: {path}", file=sys.stderr)
                return 2
            except (KeyError, ValueError, json.JSONDecodeError) as e:
                print(f"error: cannot lint {path} ({e!r})", file=sys.stderr)
                return 2
        return _emit_findings(results, args.as_json, args.fail_on)

    if args.cmd == "whatif":
        from repro.core import whatif as whatif_mod
        try:
            if args.path.endswith((".json", ".npz")):
                sess = TraceSession.load(args.path)
                if not len(sess):
                    print(f"error: session {sess.name!r} has no traces",
                          file=sys.stderr)
                    return 2
                tr = sess.get(args.label) if args.label else list(sess)[0]
            else:
                shape = tuple(int(x) for x in args.mesh.split(","))
                axes = tuple(args.axes.split(","))
                if len(shape) != len(axes):
                    print("error: --mesh and --axes must have the same rank",
                          file=sys.stderr)
                    return 2
                from repro.core.tracer import trace_from_hlo
                with open(args.path) as f:
                    text = f.read()
                label = os.path.splitext(os.path.basename(args.path))[0]
                tr = trace_from_hlo(text, MeshSpec(shape, axes), label=label)
        except FileNotFoundError:
            print(f"error: no such file: {args.path}", file=sys.stderr)
            return 2
        except (KeyError, ValueError, json.JSONDecodeError) as e:
            print(f"error: cannot sweep {args.path} ({e!r})",
                  file=sys.stderr)
            return 2
        mesh = MeshSpec(tr.mesh_shape, tr.mesh_axes)
        results = whatif_mod.sweep(tr.store, mesh, top=args.top)
        if args.as_json:
            print(json.dumps(
                whatif_mod.sweep_to_dict(results, tr.label, mesh), indent=1))
        else:
            print(whatif_mod.render_sweep(results, tr.label))
        return 0

    try:
        sess = TraceSession.load(args.path,
                                 mmap=getattr(args, "mmap", False))
    except FileNotFoundError:
        print(f"error: no such session file: {args.path}", file=sys.stderr)
        return 2
    except (KeyError, ValueError, json.JSONDecodeError) as e:
        print(f"error: {args.path} is not a saved session ({e!r})",
              file=sys.stderr)
        return 2
    if args.cmd == "show":
        print(f"session '{sess.name}': {len(sess)} traces")
        _print_totals(sess)
    elif args.cmd == "table":
        print(sess.table(by=args.by, metric=args.metric))
    elif args.cmd == "diff":
        try:
            print(sess.diff(args.label_a, args.label_b, by=args.by,
                            top=args.top, only_regressed=args.only_regressed,
                            as_json=args.as_json))
        except (KeyError, ValueError) as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            return 2
    elif args.cmd == "query":
        try:
            payload = sess.query(host=args.host, step=args.step,
                                 op=args.op, kind=args.kind, by=args.by)
        except ValueError as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            return 2
        if args.as_json:
            print(json.dumps(payload, indent=1))
        else:
            sl = payload["slice"]
            spec = ",".join(f"{k}={v}" for k, v in sl.items()
                            if v is not None) or "(all)"
            print(f"session '{payload['session']}' slice {spec}: "
                  f"{len(payload['traces'])} trace(s), "
                  f"{payload['sites']} sites")
            tot = payload["totals"]
            print(f"  totals: {tot['bytes']/1e9:.3f} GB, "
                  f"{tot['wire_bytes']/1e9:.3f} wire GB, "
                  f"{tot['count']:.0f} collectives/step, "
                  f"{tot['time_s']*1e3:.3f} est ms")
            rows = payload["rollup"]["rows"]
            for lbl in sorted(rows, key=lambda k: -rows[k]["bytes"]):
                r = rows[lbl]
                print(f"  {lbl:40s} {r['bytes']/1e9:9.3f} GB "
                      f"{r['count']:8.0f}/step {r['time_s']*1e3:9.3f} ms")
    elif args.cmd == "detect":
        from repro.core import detect as detect_mod
        try:
            traces = [sess.get(args.label)] if args.label else list(sess)
        except KeyError as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            return 2
        results = [(args.path, t.label, detect_mod.run_all(t))
                   for t in traces]
        return _emit_findings(results, args.as_json, args.fail_on)
    elif args.cmd == "report":
        # resolve the label before touching the output path, so a typo'd
        # label can't truncate a previous report
        try:
            label = args.label if args.label is not None else \
                (sess.labels() or [None])[0]
            if label is None:
                raise KeyError(f"session {sess.name!r} has no traces "
                               f"to report")
            sess._resolve(label)
        except (KeyError, ValueError) as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            return 2
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            # atomic: a concurrent reader (watch daemon consumers, CI
            # artifact collection) never sees a half-written report
            with atomic_open(args.out, "w") as fp:
                sess.report(label, fmt=args.format, fp=fp,
                            stream=args.stream,
                            chunk_sites=args.chunk_sites)
            print(f"wrote {args.format} report -> {args.out} "
                  f"({os.path.getsize(args.out)//1024} KB)")
        else:
            sess.report(label, fmt=args.format, fp=sys.stdout,
                        stream=args.stream, chunk_sites=args.chunk_sites)
    return 0


def _emit_findings(results, as_json: bool, fail_on: str) -> int:
    """Shared `lint`/`detect` output: one stable schema, one exit policy.

    `results` is a list of (source path, trace label, findings).  Returns
    1 when any finding reaches the `fail_on` severity, else 0.
    """
    from repro.core.detect import SEVERITY_RANK
    if as_json:
        print(json.dumps([
            {"source": src, "trace": lbl,
             "findings": [f.to_dict() for f in fs]}
            for src, lbl, fs in results], indent=1))
    else:
        for src, lbl, fs in results:
            print(f"{src} :: {lbl}: {len(fs)} finding(s)")
            for f in fs:
                where = f" @ {f.site}" if f.site else ""
                print(f"  [{f.severity}] {f.detector}{where}: {f.message}")
    if fail_on == "never":
        return 0
    worst = min((SEVERITY_RANK.get(f.severity, 99)
                 for _src, _lbl, fs in results for f in fs), default=99)
    return 1 if worst <= SEVERITY_RANK[fail_on] else 0


def _print_totals(sess: TraceSession) -> None:
    rows = sess.totals()
    print(f"  {'label':24s} {'sites':>7s} {'coll/step':>10s} {'GB':>9s} "
          f"{'wireGB':>9s} {'est_ms':>9s} {'ovl_ms':>9s}")
    for r in rows:
        print(f"  {r['label']:24s} {r['sites']:7d} "
              f"{int(r['collectives_per_step']):10d} "
              f"{r['collective_gb']:9.3f} {r['wire_gb']:9.3f} "
              f"{r['est_ms']:9.3f} {r['overlapped_ms']:9.3f}")


if __name__ == "__main__":
    raise SystemExit(_main())
