#!/usr/bin/env sh
# Tier-1 test gate: run from the repo root.  Extra args pass through to
# pytest (e.g. `scripts/test.sh tests/test_session.py -k roundtrip`).
set -eu
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
