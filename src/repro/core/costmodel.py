"""Analytic completion model — the `completion tracking` analogue.

ucTrace wraps UCT completion callbacks to time each transfer.  Without
hardware we *model* completion: ring/torus formulas per collective kind,
with a per-hop latency term and a bandwidth term over the bottleneck link
class.  The same schema is populated from the XLA xplane profile on a real
TPU fleet (isolated here so nothing else changes).

The model also classifies each transfer into the paper's eager/rendezvous
analogue: below `hw.rndv_threshold` the latency term dominates ("eager");
above it the bandwidth term does ("rndv").
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.core.events import CollectiveEvent
from repro.core.topology import (Hardware, MeshSpec, hop_latency, link_class,
                                 slowest_link_bw, varying_axes)


def wire_bytes_per_device(kind: str, operand_bytes: int, group_size: int) -> float:
    """Ring-algorithm wire bytes each participant sends, per execution."""
    n = max(group_size, 1)
    if n == 1:
        return 0.0
    per_shard = operand_bytes / n
    if kind == "all-reduce":
        # reduce-scatter + all-gather: 2 (n-1)/n x payload
        return 2.0 * (n - 1) * per_shard
    if kind in ("all-gather", "reduce-scatter"):
        return (n - 1) * per_shard
    if kind in ("all-to-all", "ragged-all-to-all"):
        # each device keeps 1/n of its per-device operand, sends the rest
        return operand_bytes * (n - 1) / n
    if kind == "collective-broadcast":
        return operand_bytes
    if kind == "collective-permute":
        return operand_bytes
    return operand_bytes


def _latency_hops(kind: str, group_size: int) -> int:
    n = max(group_size, 1)
    if n == 1:
        return 0
    if kind in ("all-reduce",):
        return 2 * (n - 1)          # ring RS+AG phases
    if kind in ("all-gather", "reduce-scatter"):
        return n - 1
    if kind in ("all-to-all", "ragged-all-to-all"):
        return n - 1
    return 1                        # permute / broadcast


def estimate_time_s(ev: CollectiveEvent, mesh: MeshSpec, hw: Hardware) -> float:
    """Modeled completion time of one execution of the collective."""
    bw = slowest_link_bw(mesh, ev.axes, hw)
    lat = hop_latency(mesh, ev.axes, hw)
    # bidirectional ring: two directions usable for bandwidth collectives
    eff_bw = 2.0 * bw
    t_bw = ev.wire_bytes_per_device / eff_bw if eff_bw else 0.0
    t_lat = _latency_hops(ev.kind, ev.group_size) * lat
    return t_lat + t_bw


def protocol_regime(ev: CollectiveEvent, hw: Hardware) -> str:
    """eager/rendezvous analogue: latency- vs bandwidth-dominated."""
    per_shard = ev.operand_bytes / max(ev.group_size, 1)
    return "eager" if per_shard < hw.rndv_threshold else "rndv"


def annotate_event(ev: CollectiveEvent, mesh: MeshSpec, hw: Hardware) -> None:
    """Fill topology + completion fields in place."""
    groups = ev.replica_groups
    rep = groups[0] if groups else []
    ev.axes = varying_axes(mesh, rep)
    if ev.source_target_pairs:
        # permutes: classify from an example pair
        s, t = ev.source_target_pairs[0]
        ev.axes = varying_axes(mesh, [s, t])
    ev.link_class = link_class(mesh, ev.axes)
    ev.wire_bytes_per_device = wire_bytes_per_device(
        ev.kind, ev.operand_bytes, ev.group_size)
    ev.protocol = protocol_regime(ev, hw)
    ev.est_time_s = estimate_time_s(ev, mesh, hw)


# --------------------------------------------------------------------------
# batched path: one vectorized pass over TraceStore columns
# --------------------------------------------------------------------------

def annotate_store(store, mesh: MeshSpec, hw: Hardware) -> None:
    """Columnar `annotate_event`: fill topology + completion columns in place.

    Topology resolution (`varying_axes`, `link_class`, link bw/latency) runs
    once per *unique* replica-group / permute table and broadcasts through
    the store's int32 codes; wire bytes, latency hops, protocol regime, and
    `est_time_s` are vectorized numpy expressions branching on the interned
    `kind` codes via masks.  Field-for-field (bit-for-bit on the float
    columns) equivalent to running `annotate_event` over `store.rows()` —
    pinned by tests/test_ingest.py.

    Contract: annotation *rebinds, never mutates*.  Derived columns
    (`link_class`, `protocol`, `wire_bytes_per_dev`, `est_time_s`, axes)
    are assigned as fresh arrays/Categoricals; the input columns they are
    computed from are only read.  `repro.core.whatif` relies on this to
    re-annotate a `TraceStore.annotation_clone()` (which shares row data
    by reference) under counterfactual meshes/hardware without touching
    the baseline store.
    """
    from repro.core.store import Categorical, build_remap

    n = store.n
    if n == 0:
        store.link_class = Categorical.constant(0)
        store.protocol = Categorical.constant(0)
        return

    # ---- axes: once per unique group table (permute pairs override) -------
    ax_index = {}
    axes_tables = []

    def _ax_code(t: Tuple[str, ...]) -> int:
        c = ax_index.get(t)
        if c is None:
            c = ax_index[t] = len(axes_tables)
            axes_tables.append(t)
        return c

    g_codes = np.fromiter(
        (_ax_code(varying_axes(mesh, groups[0] if groups else []))
         for groups in store.group_tables),
        dtype=np.int32, count=len(store.group_tables))
    axes_code = (g_codes[store.group_code] if len(g_codes)
                 else np.zeros(n, dtype=np.int32))
    stp_mask = store.stp_code >= 0
    if stp_mask.any():
        s_codes = np.fromiter(
            (_ax_code(varying_axes(mesh, [pairs[0][0], pairs[0][1]]))
             for pairs in store.stp_tables),
            dtype=np.int32, count=len(store.stp_tables))
        axes_code[stp_mask] = s_codes[store.stp_code[stp_mask]]
    store.set_axes(axes_tables, axes_code)

    # ---- per-axes-class scalars, broadcast per row ------------------------
    lc_map, lc_vocab = build_remap([link_class(mesh, t) for t in axes_tables])
    store.link_class = Categorical(lc_map[axes_code], lc_vocab)

    bw = np.array([slowest_link_bw(mesh, t, hw) for t in axes_tables],
                  dtype=np.float64)[axes_code]
    lat = np.array([hop_latency(mesh, t, hw) for t in axes_tables],
                   dtype=np.float64)[axes_code]

    # ---- wire bytes + latency hops: masks over interned kind codes -------
    kc = store.kind.codes
    ob = store.operand_bytes
    nn = np.maximum(store.group_size, 1)
    per_shard = ob / nn
    wire = ob.astype(np.float64)                  # permute/broadcast/default
    hops = np.ones(n, dtype=np.int64)
    for code, kind in enumerate(store.kind.vocab):
        mask = kc == code
        if not mask.any():
            continue
        if kind == "all-reduce":
            wire[mask] = (2.0 * (nn[mask] - 1)) * per_shard[mask]
            hops[mask] = 2 * (nn[mask] - 1)
        elif kind in ("all-gather", "reduce-scatter"):
            wire[mask] = (nn[mask] - 1) * per_shard[mask]
            hops[mask] = nn[mask] - 1
        elif kind in ("all-to-all", "ragged-all-to-all"):
            wire[mask] = ob[mask] * (nn[mask] - 1) / nn[mask]
            hops[mask] = nn[mask] - 1
    single = nn == 1
    wire[single] = 0.0
    hops[single] = 0
    store.wire_bytes_per_device = wire

    # ---- protocol regime + completion time --------------------------------
    eager = per_shard < hw.rndv_threshold
    proto_codes = np.where(eager, np.int32(0), np.int32(1))
    store.protocol = Categorical(proto_codes, ["eager", "rndv"])

    eff_bw = 2.0 * bw
    t_bw = np.divide(wire, eff_bw, out=np.zeros(n, dtype=np.float64),
                     where=eff_bw != 0.0)
    store.est_time_s = hops * lat + t_bw


# --------------------------------------------------------------------------
# explicit algorithm models (Fig 5 analogue: ring / RSAG / recursive doubling)
# --------------------------------------------------------------------------

def allreduce_time(algorithm: str, payload_bytes: int, group_size: int,
                   link_bw: float, lat: float) -> float:
    """Closed-form Allreduce cost for the three classic algorithms."""
    n = max(group_size, 2)
    b = payload_bytes
    bw = 2.0 * link_bw
    if algorithm == "ring":
        return 2 * (n - 1) * lat + 2 * (n - 1) / n * b / bw
    if algorithm == "reduce_scatter_allgather":
        # same traffic as ring but log-structured latency on a torus
        steps = 2 * math.ceil(math.log2(n))
        return steps * lat + 2 * (n - 1) / n * b / bw
    if algorithm == "recursive_doubling":
        steps = math.ceil(math.log2(n))
        return steps * lat + steps * b / bw
    raise ValueError(algorithm)
