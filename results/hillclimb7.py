import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Round 7: the flagship cell — llama3-405b train_4k.
# Baseline (single-pod) mfu 0.167, memory-dominant (301.6 s).
# H27: flash kernel; H28: multi-pod + HSDP + flash (the production rec).
import dataclasses, json
from repro.configs import get_config
from repro.core.roofline import kernel_adjusted, roofline, train_model_flops, scope_breakdown
from repro.launch import presets
from repro.launch.dryrun import lower_cell
from repro.models import api as model_api

HERE = os.path.dirname(os.path.abspath(__file__))
TOK = 256 * 4096
cfg = get_config("llama3-405b")
N = model_api.flops_param_count(cfg)


def attn_bytes(dp, accum):
    tok_loc = TOK // dp // accum
    q_loc = tok_loc * cfg.q_dim // 16 * 2
    kv_loc = tok_loc * cfg.kv_dim // 16 * 2
    return (2 * q_loc + 4 * kv_loc) * cfg.num_layers * accum * 4.0


rows = []
def run(name, multi_pod, st, kernel_dp=None):
    r = lower_cell("llama3-405b", "train_4k", multi_pod=multi_pod, settings=st)
    tr = r["trace"]
    rf = roofline(tr, model_flops=train_model_flops(N, TOK))
    if name == "baseline":
        print(scope_breakdown(tr, top=6))
    if kernel_dp:
        rf = kernel_adjusted(rf, tr, r"/attn", attn_bytes(kernel_dp, st.accum))
    print(f"{name:28s} comp={rf.compute_s:7.2f}s hbm={rf.memory_s:7.2f}s "
          f"coll={rf.collective_s:7.2f}s overlap={tr.overlapped_est_time_s():7.2f}s "
          f"dom={rf.dominant:10s} mfu={rf.model_roofline_fraction:.3f} "
          f"mem={r['mem_model_gb']}GB")
    rows.append({"variant": name, "mfu": rf.model_roofline_fraction,
                 "compute_s": rf.compute_s, "memory_s": rf.memory_s,
                 "collective_s": rf.collective_s,
                 "mem_gb": r["mem_model_gb"]})

st0 = presets.settings_for("llama3-405b", "train_4k")
run("baseline", False, st0)
run("H27_flash", False, st0, kernel_dp=16)
run("H28_mp_hsdp_flash", True, dataclasses.replace(st0, hsdp=True), kernel_dp=32)
run("H28b_mp_fsdp_flash", True, st0, kernel_dp=32)
with open(os.path.join(HERE, "hillclimb7.json"), "w") as f:
    json.dump(rows, f, indent=1)
