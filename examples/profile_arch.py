"""Profile any assigned architecture x shape on a reduced host mesh and
write the interactive HTML report (the paper's visualizer artifact).

    PYTHONPATH=src python examples/profile_arch.py --arch mixtral-8x22b \
        --shape decode_32k --out /tmp/trace.html

Uses the reduced (smoke) config of the same family so it compiles in
seconds on CPU; the production 512-chip traces come from
`python -m repro.launch.dryrun --html results/html`.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax

from repro.core import MeshSpec
from repro.core.report import semantic_table, summary, to_html, top_contenders_table
from repro.launch.dryrun import lower_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--out", default="/tmp/repro_trace.html")
    args = ap.parse_args()

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    spec = MeshSpec((2, 4), ("data", "model"))
    print(f"tracing {args.arch} x {args.shape} on a 2x4 host mesh ...")
    r = lower_cell(args.arch, args.shape, mesh=mesh, mesh_spec=spec)
    if "skipped" in r:
        print("cell skipped:", r["skipped"])
        return
    tr = r["trace"]
    print(summary(tr))
    print(top_contenders_table(tr))
    print(semantic_table(tr))
    with open(args.out, "w") as f:
        f.write(to_html(tr, spec))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
