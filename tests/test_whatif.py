"""What-if engine: scenario re-pricing, invariants, CLI sweep schema."""
import json

import numpy as np
import pytest

from repro.core.synth import misconfigured_trace, synthetic_trace
from repro.core.topology import MeshSpec, V5E
from repro.core.whatif import (IDENTITY, Scenario, compare, default_scenarios,
                               reannotate, site_deltas, sweep, sweep_to_dict)

MESH = MeshSpec((2, 4), ("data", "model"))


@pytest.fixture(scope="module")
def base_trace():
    return synthetic_trace("whatif-base", MESH, n_sites=300, seed=7)


@pytest.fixture(scope="module")
def base_store(base_trace):
    # annotate_store-normalized baseline: synthetic traces are annotated
    # per-event, so vocab interning order differs from the columnar pass;
    # one identity re-annotation pins the canonical columnar form.
    return reannotate(base_trace.store, IDENTITY, MESH)


# --------------------------------------------------------------------------
# invariants
# --------------------------------------------------------------------------

def test_identity_scenario_is_byte_identical(base_store):
    again = reannotate(base_store, IDENTITY, MESH)
    assert again is not base_store
    assert again.identical(base_store)


def test_reannotate_never_mutates_baseline(base_store):
    before_est = base_store.est_time_s.copy()
    before_wire = base_store.wire_bytes_per_device.copy()
    before_link = list(base_store.link_class.vocab)
    alt = Scenario("flip", mesh=MeshSpec((4, 2), ("model", "data")))
    reannotate(base_store, alt, MESH)
    assert np.array_equal(base_store.est_time_s, before_est)
    assert np.array_equal(base_store.wire_bytes_per_device, before_wire)
    assert list(base_store.link_class.vocab) == before_link


def test_site_deltas_antisymmetric(base_store):
    alt = reannotate(base_store, Scenario("lat0", hw_overrides={
        "ici_latency_s": 0.0, "dci_latency_s": 0.0}), MESH)
    fwd = site_deltas(base_store, alt)
    rev = site_deltas(alt, base_store)
    assert set(fwd) == set(rev)
    assert any(abs(v) > 0 for v in fwd.values())
    for k, v in fwd.items():
        assert rev[k] == -v


def test_identity_compare_saves_nothing(base_store):
    r = compare(base_store, IDENTITY, MESH)
    assert r.saved_s == 0.0
    assert r.speedup == 1.0
    assert r.wire == r.baseline_wire
    assert r.top_sites == []


# --------------------------------------------------------------------------
# scenario semantics
# --------------------------------------------------------------------------

def test_default_scenarios_cover_the_grid(base_store):
    names = [s.name for s in default_scenarios(MESH)]
    assert "mesh:model,data" in names
    assert any(n.startswith("rndv:") for n in names)
    assert "ici-2x" in names and "lat-half" in names
    assert len(set(names)) == len(names)
    # all-ICI mesh: no dci axis, so no dci-2x scenario
    assert "dci-2x" not in names


def test_rndv_scenario_moves_protocol_not_time(base_store):
    r = compare(base_store, Scenario("rndv", hw_overrides={
        "rndv_threshold": 1 << 4}), MESH)
    assert r.est_s == pytest.approx(r.baseline_s)
    assert r.eager < r.baseline_eager     # tiny threshold: almost all rndv


def test_bandwidth_scenario_saves_time(base_store):
    r = compare(base_store, Scenario("ici-2x", hw_overrides={
        "ici_bw": V5E.ici_bw * 2}), MESH)
    assert r.saved_s > 0
    assert r.speedup > 1.0
    assert r.top_sites and r.top_sites[0]["saved_s"] > 0


def test_misconfigured_trace_planted_fix_ranks_first():
    trace, mesh, expect = misconfigured_trace(n_sites=200)
    results = sweep(trace.store, mesh)
    assert results[0].scenario.name == expect
    assert results[0].saved_s > 0
    # strictly beats every other scenario, not a tie
    assert results[0].saved_s > results[1].saved_s


# --------------------------------------------------------------------------
# CLI + schema
# --------------------------------------------------------------------------

def test_sweep_to_dict_roundtrips(base_store):
    results = sweep(base_store, MESH)
    doc = sweep_to_dict(results, "whatif-base", MESH)
    again = json.loads(json.dumps(doc))
    assert again == doc
    assert set(doc) == {"label", "mesh", "baseline", "scenarios"}
    assert set(doc["baseline"]) == {"est_time_s", "wire_bytes",
                                    "eager_sites"}
    for s in doc["scenarios"]:
        assert {"name", "description", "mesh", "est_time_s", "baseline_s",
                "saved_s", "speedup", "wire_bytes", "wire_saved_bytes",
                "eager_sites", "baseline_eager_sites", "by_key",
                "top_sites"} <= set(s)
    saved = [s["saved_s"] for s in doc["scenarios"]]
    assert saved == sorted(saved, reverse=True)


def test_cli_whatif_json_ranks_planted_fix(tmp_path, capsys):
    from repro.core.session import TraceSession, _main
    trace, mesh, expect = misconfigured_trace(n_sites=200)
    path = str(tmp_path / "misconfig.json")
    TraceSession("misconfig", [trace]).save(path)
    assert _main(["whatif", path, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["label"] == "misconfigured"
    assert doc["scenarios"][0]["name"] == expect
    assert doc["scenarios"][0]["saved_s"] > 0
    assert doc["scenarios"][0]["est_time_s"] == pytest.approx(
        doc["baseline"]["est_time_s"] - doc["scenarios"][0]["saved_s"])


def test_cli_whatif_table_and_errors(tmp_path, capsys):
    from repro.core.session import TraceSession, _main
    trace, mesh, expect = misconfigured_trace(n_sites=100)
    path = str(tmp_path / "m.json")
    TraceSession("m", [trace]).save(path)
    assert _main(["whatif", path]) == 0
    out = capsys.readouterr().out
    assert "what-if sweep" in out and expect in out and "best:" in out
    assert _main(["whatif", str(tmp_path / "nope.json")]) == 2
    capsys.readouterr()
    assert _main(["whatif", path, "no-such-label"]) == 2
    capsys.readouterr()
    # bad mesh rank for an HLO input
    hlo = tmp_path / "x.txt"
    hlo.write_text("HloModule x\n")
    assert _main(["whatif", str(hlo), "--mesh", "2,4",
                  "--axes", "data"]) == 2
    capsys.readouterr()


def test_detect_findings_carry_quantified_recommendations():
    from repro.core import detect
    trace, _mesh, _fix = misconfigured_trace(n_sites=300)
    findings = detect.run_all(trace)
    assert findings
    quantified = [f for f in findings if f.est_saved_s > 0]
    assert quantified
    for f in quantified:
        assert f.recommendation
        d = f.to_dict()
        assert d["est_saved_s"] == f.est_saved_s
        assert d["recommendation"] == f.recommendation


def test_roofline_scenario_overlay(base_trace, base_store):
    from repro.core.roofline import (roofline, scenario_adjusted,
                                     scenario_overlay_table)
    rf = roofline(base_trace, model_flops=1e12)
    results = sweep(base_store, MESH)
    adj = scenario_adjusted(rf, results[0])
    assert adj.compute_s == rf.compute_s and adj.memory_s == rf.memory_s
    assert adj.collective_s == results[0].est_s
    assert adj.label.endswith("@" + results[0].scenario.name)
    table = scenario_overlay_table(rf, results)
    assert rf.label in table and "1.00x" in table
