"""qwen3-moe-235b-a22b — 128-expert top-8 MoE. [hf:Qwen/Qwen3-30B-A3B family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,              # q_dim 8192 > d_model (qwen3 style)
    d_ff=1536,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    top_k=8,
    moe_d_ff=1536,
    notes="128 experts over 16-way model axis => 8 experts/shard (pure EP)",
)
