"""Fig 8 analogue: workload communication profile vs fleet size.

The paper profiles GROMACS at 8/16 nodes and shows the PME (all-to-all)
fraction and transport switch (rc->dc) with scale.  We trace a reduced MoE
arch train step at 16/64/256 devices and report how the per-semantic
communication split and modeled step time scale.
"""
from __future__ import annotations

import json

from _util import run_worker

WORKER_TMPL = """
import json
import jax
import jax.numpy as jnp
from repro.configs import ARCHS, smoke_config
from repro.core import MeshSpec, trace_from_hlo
from repro.distributed import sharding as sh
from repro.distributed.autoshard import activation_sharding
from repro.launch.presets import StepSettings
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import adamw

D, M = %d, %d
mesh = jax.make_mesh((D, M), ("data", "model"))
spec = MeshSpec((D, M), ("data", "model"))
cfg = smoke_config(ARCHS["mixtral-8x22b"]).replace(
    d_model=256, moe_d_ff=512, num_layers=4, vocab_size=1024,
    num_heads=16, num_kv_heads=8, head_dim=16, num_experts=8, top_k=2,
    window=0)
st = StepSettings(accum=1, remat="full")
opt_cfg = adamw.AdamWConfig()
step = make_train_step(cfg, opt_cfg, st)
params = api.abstract_params(cfg)
f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
opt = {"m": jax.tree.map(f32, params), "v": jax.tree.map(f32, params),
       "count": jax.ShapeDtypeStruct((), jnp.int32)}
shape = type("S", (), {"global_batch": 4 * D, "seq_len": 256,
                       "kind": "train"})()
batch = api.batch_specs(cfg, shape)
pspecs = sh.param_pspecs(cfg, mesh)
jfn = jax.jit(step, in_shardings=(
    sh.named(mesh, pspecs),
    sh.named(mesh, {"m": pspecs, "v": pspecs,
                    "count": jax.sharding.PartitionSpec()}),
    sh.named(mesh, sh.batch_pspecs(cfg, shape, mesh))),
    donate_argnums=(0, 1))
with activation_sharding(mesh):
    compiled = jfn.lower(params, opt, batch).compile()
tr = trace_from_hlo(compiled.as_text(), spec, label=f"{D}x{M}",
                    cost_analysis=compiled.cost_analysis())
sem = tr.by_semantic()
tot = sum(a["bytes"] for a in sem.values()) or 1.0
split = "|".join(f"{k}={100*a['bytes']/tot:.0f}%%"
                 for k, a in sorted(sem.items(), key=lambda kv: -kv[1]["bytes"])[:4])
print("JSON" + json.dumps([
    (f"scale/{D*M}dev/moe_train", tr.total_est_time_s() * 1e6,
     f"{split}|wireMB={tr.total_wire_bytes()/1e6:.1f}")]))
"""


def run():
    rows = []
    for d, m in ((4, 4), (8, 8), (16, 16)):
        out = run_worker(WORKER_TMPL % (d, m), devices=d * m, timeout=560)
        for line in out.splitlines():
            if line.startswith("JSON"):
                rows += [tuple(r) for r in json.loads(line[4:])]
    return rows
