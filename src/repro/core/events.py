"""Event model for the multi-layer trace (the ucTrace data model, TPU-ified).

Layer mapping (see DESIGN.md §2):
  MPI  function   -> `semantic`   (grad_sync / attention / moe_dispatch / ...)
  UCP  operation  -> `jax_prim`   (the jax-level primitive from op_name)
  UCT  send       -> `CollectiveEvent` (one compiled HLO collective op)
  UCT  transport  -> `link_class` (ici.<axis> / dci.pod / mixed / local)
  completion time -> `est_time_s` (cost model; xplane-fed on real hardware)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class CollectiveEvent:
    """One HLO collective op instance (the UCT-layer record)."""

    name: str                      # HLO op name (%all-reduce.1)
    kind: str                      # all-reduce | all-gather | reduce-scatter |
                                   # all-to-all | collective-permute
    async_start: bool              # -start form (overlappable)
    operand_bytes: int             # sum of operand payload bytes
    result_bytes: int
    dtype: str
    replica_groups: List[List[int]]    # resolved device ids per group
    group_size: int
    num_groups: int
    op_name: str                   # HLO metadata op_name (call-stack analogue)
    computation: str               # enclosing HLO computation
    multiplicity: int = 1          # executions per step (while-loop trip counts)
    channel_id: Optional[int] = None
    source_target_pairs: Optional[List[Tuple[int, int]]] = None  # permutes

    # derived (filled by attribution/topology/cost model)
    link_class: str = ""           # ici.data | ici.model | dci.pod | mixed(..) | local
    axes: Tuple[str, ...] = ()     # mesh axes the groups span
    semantic: str = ""             # MPI-function analogue
    jax_prim: str = ""             # UCP-operation analogue
    scope: str = ""                # named_scope path prefix
    protocol: str = ""             # eager | rndv  (latency- vs bandwidth-bound)
    wire_bytes_per_device: float = 0.0
    est_time_s: float = 0.0

    @property
    def total_wire_bytes(self) -> float:
        """Wire traffic summed over participating devices, per execution."""
        return self.wire_bytes_per_device * self.group_size * self.num_groups


def site_key(e: "CollectiveEvent") -> str:
    """Site-level alignment key: op_name x kind x mesh axes.

    The per-event analogue of the interned code triple the columnar diff
    aligns on (`TraceStore._codes_for("site")`) — one key per compiled
    callsite class, so cross-run regressions localize to the op_name that
    produced them instead of washing out in kind x link rollups.
    """
    return f"{e.op_name}|{e.kind}|{','.join(e.axes)}"


@dataclass
class HloOpStats:
    """Non-collective per-program stats used by detectors/roofline."""

    n_transpose: int = 0
    n_fusion: int = 0
    n_convert: int = 0
    n_reshape: int = 0
    transpose_bytes: int = 0
    # loop-aware totals (x while trip counts) — cost_analysis counts loop
    # bodies once, so these are the authoritative roofline inputs.
    flops: float = 0.0
    bytes_accessed: float = 0.0
    # per-named_scope attribution (module-level rollups + kernel-adjusted
    # rooflines: e.g. subtract `attn` score traffic when the Pallas flash
    # kernel replaces the XLA blocked path)
    bytes_by_scope: Dict[str, float] = field(default_factory=dict)
    flops_by_scope: Dict[str, float] = field(default_factory=dict)

    @classmethod
    def merged(cls, parts: List["HloOpStats"]) -> "HloOpStats":
        """Combine per-shard stats (sharded ingest; see hlo_parser).

        Every contribution is an integer-valued float (byte/FLOP counts x
        integer multiplicities), so the partial-sum reassociation is exact
        below 2^53 and the merge equals a serial accumulation.  Scope dicts
        keep first-seen order across shards — the serial insertion order.
        """
        out = cls()
        for p in parts:
            out.n_transpose += p.n_transpose
            out.n_fusion += p.n_fusion
            out.n_convert += p.n_convert
            out.n_reshape += p.n_reshape
            out.transpose_bytes += p.transpose_bytes
            out.flops += p.flops
            out.bytes_accessed += p.bytes_accessed
            for k, v in p.bytes_by_scope.items():
                out.bytes_by_scope[k] = out.bytes_by_scope.get(k, 0.0) + v
            for k, v in p.flops_by_scope.items():
                out.flops_by_scope[k] = out.flops_by_scope.get(k, 0.0) + v
        return out


class Trace:
    """A complete multi-layer communication trace of one compiled step.

    The trace is columnar end to end: the default ingest path
    (`tracer.trace_from_hlo(engine="columnar")`) parses straight into a
    `TraceStore` (see store.py) and this class is built `from_store`, with
    `events` as a lazily-materialized row view — exactly like a trace
    loaded from a saved store.  Named rollups and totals are `np.bincount`
    over interned codes, not Python loops.

    Events can still be *supplied* as a list of `CollectiveEvent` (the
    per-event reference pipeline and hand-built test traces); the store is
    then built lazily from the rows.  Staleness detection is by length
    only: reassigning `events` or changing the list's length invalidates
    the store automatically; any same-length mutation (replacing a list
    item, editing an event's fields in place) after an aggregate was
    computed requires an explicit `invalidate()`.
    """

    # set by a salvage ingest (`tracer.trace_from_hlo(recover=True)`):
    # the `hlo_parser.SalvageReport` describing what the damaged module
    # lost, None for a clean/strict parse
    salvage = None

    def __init__(self, label: str, mesh_shape: Tuple[int, ...],
                 mesh_axes: Tuple[str, ...], num_devices: int,
                 events: Optional[List[CollectiveEvent]] = None,
                 op_stats: Optional[HloOpStats] = None, *,
                 store=None,
                 hlo_flops: float = 0.0, hlo_bytes: float = 0.0,
                 per_device_memory_bytes: float = 0.0,
                 argument_bytes: float = 0.0, output_bytes: float = 0.0):
        self.label = label
        self.mesh_shape = tuple(mesh_shape)
        self.mesh_axes = tuple(mesh_axes)
        self.num_devices = num_devices
        self.op_stats = op_stats if op_stats is not None else HloOpStats()
        # compiled-artifact numbers (cost_analysis / memory_analysis)
        self.hlo_flops = hlo_flops
        self.hlo_bytes = hlo_bytes
        self.per_device_memory_bytes = per_device_memory_bytes
        self.argument_bytes = argument_bytes
        self.output_bytes = output_bytes
        if store is not None and events is None:
            self._events: Optional[List[CollectiveEvent]] = None
        else:
            self._events = list(events) if events is not None else []
        self._store = store

    def __repr__(self) -> str:
        return (f"Trace(label={self.label!r}, mesh_shape={self.mesh_shape}, "
                f"mesh_axes={self.mesh_axes}, sites={self.sites})")

    @property
    def sites(self) -> int:
        """Number of collective op sites (without materializing rows)."""
        return len(self._events) if self._events is not None else self._store.n

    # ---- columnar backing --------------------------------------------------

    @property
    def events(self) -> List[CollectiveEvent]:
        if self._events is None:          # loaded from a store: rows on demand
            self._events = self._store.rows()
        return self._events

    @events.setter
    def events(self, value: List[CollectiveEvent]) -> None:
        self._events = list(value)
        self._store = None

    @property
    def store(self):
        """The columnar view; (re)built when the event list changed length."""
        from repro.core.store import TraceStore
        if self._store is None or (self._events is not None
                                   and self._store.n != len(self._events)):
            self._store = TraceStore.from_events(self._events or [])
        return self._store

    def invalidate(self) -> None:
        """Drop the cached columns after a same-length event mutation
        (item replacement or in-place field edit) — length changes are
        detected automatically, these are not."""
        if self._events is None:
            self._events = self._store.rows()
        self._store = None

    @classmethod
    def from_store(cls, label: str, mesh_shape: Tuple[int, ...],
                   mesh_axes: Tuple[str, ...], num_devices: int, store,
                   **kw) -> "Trace":
        return cls(label, mesh_shape, mesh_axes, num_devices, store=store, **kw)

    # ---- aggregate views (vectorized over the store) -----------------------
    def total_collective_bytes(self) -> float:
        """Sum of operand sizes x multiplicity (roofline definition)."""
        return self.store.total_collective_bytes()

    def total_wire_bytes(self) -> float:
        return self.store.total_wire_bytes()

    def total_est_time_s(self) -> float:
        return self.store.total_est_time_s()

    def overlapped_est_time_s(self) -> float:
        """Lower bound on collective time with perfect cross-link overlap.

        Different link classes (ici.data vs ici.model vs dci.pod) use
        disjoint physical links, so a latency-hiding scheduler can run them
        concurrently: the bound is the max per-class serialized time, not
        the sum.  Together with total_est_time_s() this brackets reality.
        """
        return self.store.overlapped_est_time_s()

    def by(self, key_fn) -> Dict[str, Dict[str, float]]:
        """Aggregate {key: {bytes, wire_bytes, count, time_s}}.

        Reference per-event path for *arbitrary* key functions (and the
        baseline the columnar rollups are equivalence-tested against).
        The named rollups below run columnar instead.
        """
        agg: Dict[str, Dict[str, float]] = {}
        for e in self.events:
            k = key_fn(e)
            a = agg.setdefault(k, {"bytes": 0.0, "wire_bytes": 0.0,
                                   "count": 0.0, "time_s": 0.0})
            a["bytes"] += e.operand_bytes * e.multiplicity
            a["wire_bytes"] += e.total_wire_bytes * e.multiplicity
            a["count"] += e.multiplicity
            a["time_s"] += e.est_time_s * e.multiplicity
        return agg

    def by_kind_and_link(self):
        return self.store.by_kind_and_link()

    def by_semantic(self):
        return self.store.by_semantic()

    def by_site(self):
        """Per-callsite rollup keyed on `site_key` (op_name x kind x axes)."""
        return self.store.by_site()
