"""Columnar trace storage — struct-of-arrays over numpy.

The per-event dataclass list is the right *construction* format for small
traces, but the wrong *aggregation* format: every Table II rollup,
comm-matrix assembly, and detector scan walks Python objects attribute by
attribute.  INAM-style cross-layer profilers solve this with columnar
stores; we do the same.  `TraceStore` holds one numpy array per numeric
field and interned categorical codes for the string fields (kind, link
class, semantic, op_name, ...), so aggregations become `np.bincount` over
composite codes instead of Python loops — 1-2 orders of magnitude faster
at the 100k-event scale the paper's experiments produce.

The irregular per-row payloads are *deduplicated*: replica groups, permute
pairs, and mesh-axes tuples repeat heavily (unrolled loops stamp the same
`replica_groups=[G,S]<=[dims]` attr thousands of times), so the store keeps
one table of unique values per payload plus an int32 code per row.  This is
what makes whole-pipeline batching possible: the cost model resolves
topology once per unique group table (`costmodel.annotate_store`) and
attribution runs its regex cascade once per unique op_name
(`attribution.attribute_store`), both broadcasting results through codes.

`CollectiveEvent` remains the row view: `store.row(i)` / `store.rows()`
materialize dataclass rows, and `Trace` keeps exposing `.events` so every
existing consumer (detectors, renderers, diffing) is unaffected.
"""
from __future__ import annotations

import fnmatch
import json
import os
import sys
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import CollectiveEvent

SCHEMA_VERSION = 2

# numeric columns: (name, dtype)
_NUM_COLS: Tuple[Tuple[str, object], ...] = (
    ("operand_bytes", np.int64),
    ("result_bytes", np.int64),
    ("multiplicity", np.int64),
    ("group_size", np.int64),
    ("num_groups", np.int64),
    ("channel_id", np.int64),          # -1 encodes None
    ("async_start", np.bool_),
    ("wire_bytes_per_device", np.float64),
    ("est_time_s", np.float64),
)

# interned string columns
_CAT_COLS: Tuple[str, ...] = (
    "kind", "link_class", "semantic", "protocol", "jax_prim", "scope",
    "dtype", "computation", "op_name",
)


def _grow(buf: Optional[np.ndarray], cur: np.ndarray,
          add: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Append `add` after logical column `cur`, reusing the amortized
    capacity buffer `buf` while `cur` is still a live view of it.

    Returns `(buf, view)` with `view = buf[:len(cur) + len(add)]`.  A
    column that was replaced wholesale since the last append (e.g.
    `annotate_store` swapping in computed `est_time_s`) no longer aliases
    `buf`, so a fresh buffer is seeded from the current values; doubling
    growth keeps N appends at O(total rows) amortized copies.
    """
    n, k = len(cur), len(add)
    if buf is None or cur.base is not buf or len(buf) < n + k \
            or buf.dtype != cur.dtype:
        cap = 1 << max(n + k, 4).bit_length()
        nbuf = np.empty(cap, dtype=cur.dtype)
        nbuf[:n] = cur
        buf = nbuf
    buf[n:n + k] = add
    return buf, buf[:n + k]


class Categorical:
    """An interned string column: int32 codes into a first-seen vocab."""

    __slots__ = ("codes", "vocab", "_index", "_buf")

    def __init__(self, codes: np.ndarray, vocab: List[str]):
        self.codes = np.asarray(codes, dtype=np.int32)
        self.vocab = list(vocab)
        self._index: Optional[Dict[str, int]] = None
        self._buf: Optional[np.ndarray] = None

    @classmethod
    def from_values(cls, values: Sequence[str]) -> "Categorical":
        index: Dict[str, int] = {}
        codes = np.empty(len(values), dtype=np.int32)
        for i, v in enumerate(values):
            code = index.get(v)
            if code is None:
                code = index[v] = len(index)
            codes[i] = code
        return cls(codes, list(index))

    @classmethod
    def constant(cls, n: int, value: str = "") -> "Categorical":
        """A column of `n` identical values (the un-annotated placeholder)."""
        if n == 0:
            return cls(np.empty(0, dtype=np.int32), [])
        return cls(np.zeros(n, dtype=np.int32), [value])

    def __len__(self) -> int:
        return len(self.codes)

    def value(self, i: int) -> str:
        return self.vocab[self.codes[i]]

    def values(self) -> List[str]:
        return [self.vocab[c] for c in self.codes]

    def mask_of(self, *labels: str) -> np.ndarray:
        """Boolean mask of rows whose value is one of `labels`."""
        want = {i for i, v in enumerate(self.vocab) if v in labels}
        if not want:
            return np.zeros(len(self.codes), dtype=bool)
        return np.isin(self.codes, np.fromiter(want, dtype=np.int32))

    def mask_prefix(self, prefixes: Tuple[str, ...]) -> np.ndarray:
        want = {i for i, v in enumerate(self.vocab) if v.startswith(prefixes)}
        if not want:
            return np.zeros(len(self.codes), dtype=bool)
        return np.isin(self.codes, np.fromiter(want, dtype=np.int32))

    def mask_glob(self, pattern: str) -> np.ndarray:
        """Boolean mask of rows whose value matches a shell-style glob.

        The match runs once per *vocab entry*, so filtering a million-row
        column by `op=transformer*attention*` costs O(vocab) string work
        plus one vectorized `isin` — the query layer's row filter.
        A pattern without wildcards degenerates to an exact match.
        """
        want = {i for i, v in enumerate(self.vocab)
                if fnmatch.fnmatchcase(v, pattern)}
        if not want:
            return np.zeros(len(self.codes), dtype=bool)
        return np.isin(self.codes, np.fromiter(want, dtype=np.int32))

    def remap(self, fn) -> "Categorical":
        """New categorical applying `fn` once per *vocab entry* (not per row),
        merging entries that map to the same output string."""
        return self.remap_table([fn(v) for v in self.vocab])

    def remap_table(self, table: Sequence[str]) -> "Categorical":
        """New categorical with vocab entry i replaced by `table[i]`
        (entries mapping to the same output are merged)."""
        remap, merged = build_remap(table)
        codes = remap[self.codes] if len(table) else \
            np.empty(0, dtype=np.int32)
        return Categorical(codes, merged)

    def extend(self, other: "Categorical") -> None:
        """In-place append of `other`'s rows, interning its vocab
        first-seen into ours — the streaming equivalent of the
        `build_remap` union in `TraceStore.merge`, with the vocab index
        cached across calls and codes kept in an amortized buffer."""
        index = self._index
        if index is None or len(index) != len(self.vocab):
            index = self._index = {v: i for i, v in enumerate(self.vocab)}
        remap = np.empty(len(other.vocab), dtype=np.int32)
        for i, v in enumerate(other.vocab):
            j = index.get(v)
            if j is None:
                j = index[v] = len(self.vocab)
                self.vocab.append(v)
            remap[i] = j
        add = remap[other.codes] if len(other.codes) \
            else np.empty(0, dtype=np.int32)
        self._buf, self.codes = _grow(self._buf, self.codes, add)


class LazyNames:
    """List-like view of the packed per-row name member, decoded on demand.

    The npz layout stores row names as one newline-joined utf-8 blob
    (`{prefix}names`, a uint8 column) so an mmap-mode open does not pay
    O(rows) Python-string materialization up front.  Rollups, detectors,
    and diff never touch names; only `row()`/report rendering do — this
    decodes once on first access and behaves like the list afterwards.
    """

    __slots__ = ("_packed", "_n", "_list")

    def __init__(self, packed: np.ndarray, n: int):
        self._packed = packed
        self._n = n
        self._list: Optional[List[str]] = None

    def _materialize(self) -> List[str]:
        if self._list is None:
            if self._n == 0:
                self._list = []
            else:
                # n==1 with an empty name packs to b"", which still
                # decodes correctly: "".split("\n") == [""]
                self._list = bytes(self._packed).decode("utf-8").split("\n")
                if len(self._list) != self._n:
                    raise ValueError(
                        f"packed names decode to {len(self._list)} rows, "
                        f"expected {self._n}")
        return self._list

    def __len__(self) -> int:
        return self._n

    def __iter__(self):
        return iter(self._materialize())

    def __getitem__(self, i):
        return self._materialize()[i]

    def __eq__(self, other) -> bool:
        if isinstance(other, (list, LazyNames)):
            return self._materialize() == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return f"LazyNames(n={self._n})"


def pack_names(names: Sequence[str]) -> np.ndarray:
    """Pack row names into the uint8 npz column `LazyNames` decodes."""
    blob = "\n".join(names).encode("utf-8")
    return np.frombuffer(blob, dtype=np.uint8)


def _intern(index: Dict, key, table: List, value_fn) -> int:
    code = index.get(key)
    if code is None:
        code = index[key] = len(table)
        table.append(value_fn())
    return code


def build_remap(entries: Sequence) -> Tuple[np.ndarray, List]:
    """Intern `entries` in first-seen order: returns (int32 map of
    len(entries), merged vocab) with `vocab[map[i]] == entries[i]`.

    The shared core of every vocab-level broadcast (Categorical.remap,
    the batched cost model's link classes, attribution's semantic labels).
    """
    index: Dict = {}
    vocab: List = []
    table = np.empty(max(len(entries), 1), dtype=np.int32)
    for i, v in enumerate(entries):
        j = index.get(v)
        if j is None:
            j = index[v] = len(vocab)
            vocab.append(v)
        table[i] = j
    return table, vocab


class TraceStore:
    """Struct-of-arrays event store backing a `Trace`.

    Numeric fields are numpy columns; string fields are `Categorical`
    (codes + vocab); the irregular per-row payloads are deduplicated into
    unique-value tables addressed by int32 codes:

      * `group_tables[group_code[i]]`  — replica groups of row i,
      * `stp_tables[stp_code[i]]`      — permute pairs (code -1 = none),
      * `axes_tables[axes_code[i]]`    — mesh-axes tuple of row i.

    The per-row list views (`replica_groups`, `source_target_pairs`,
    `axes`, `op_names`) are materialized lazily for compatibility.
    """

    def __init__(self, n: int, num: Dict[str, np.ndarray],
                 cat: Dict[str, Categorical],
                 names: List[str],
                 group_tables: List[List[List[int]]], group_code: np.ndarray,
                 stp_tables: List[List[Tuple[int, int]]], stp_code: np.ndarray,
                 axes_tables: List[Tuple[str, ...]], axes_code: np.ndarray):
        self.n = n
        for col, _dt in _NUM_COLS:
            setattr(self, col, num[col])
        for col in _CAT_COLS:
            setattr(self, col, cat[col])
        self.names = names
        self.group_tables = group_tables
        self.group_code = np.asarray(group_code, dtype=np.int32)
        self.stp_tables = stp_tables
        self.stp_code = np.asarray(stp_code, dtype=np.int32)
        self.axes_tables = axes_tables
        self.axes_code = np.asarray(axes_code, dtype=np.int32)
        self._edges: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
        self._gexp: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
        self._rg_rows: Optional[List[List[List[int]]]] = None
        self._stp_rows: Optional[List] = None
        self._axes_rows: Optional[List[Tuple[str, ...]]] = None
        # append-mode state: amortized column buffers + cached payload
        # table indices (value-keyed), see `append`
        self._bufs: Dict[str, np.ndarray] = {}
        self._tbl_idx: Dict[str, Dict] = {}

    # ---- construction ------------------------------------------------------

    @classmethod
    def from_events(cls, events: Iterable[CollectiveEvent]) -> "TraceStore":
        evs = list(events)
        n = len(evs)
        num = {col: np.fromiter(
            ((-1 if e.channel_id is None else e.channel_id) if col == "channel_id"
             else getattr(e, col) for e in evs),
            dtype=dt, count=n) for col, dt in _NUM_COLS}
        cat = {col: Categorical.from_values([getattr(e, col) for e in evs])
               for col in _CAT_COLS}

        # intern the irregular payloads (id() front-cache: parsers and synth
        # reuse the same group-list objects across many events)
        g_idx: Dict = {}
        g_ids: Dict[int, int] = {}
        group_tables: List[List[List[int]]] = []
        group_code = np.empty(n, dtype=np.int32)
        s_idx: Dict = {}
        stp_tables: List[List[Tuple[int, int]]] = []
        stp_code = np.empty(n, dtype=np.int32)
        a_idx: Dict = {}
        axes_tables: List[Tuple[str, ...]] = []
        axes_code = np.empty(n, dtype=np.int32)
        for i, e in enumerate(evs):
            gc = g_ids.get(id(e.replica_groups))
            if gc is None:
                key = tuple(tuple(g) for g in e.replica_groups)
                gc = _intern(g_idx, key, group_tables, lambda: e.replica_groups)
                g_ids[id(e.replica_groups)] = gc
            group_code[i] = gc
            if e.source_target_pairs:
                key = tuple(e.source_target_pairs)
                stp_code[i] = _intern(s_idx, key, stp_tables,
                                      lambda: e.source_target_pairs)
            else:
                stp_code[i] = -1
            axes_code[i] = _intern(a_idx, tuple(e.axes), axes_tables,
                                   lambda: tuple(e.axes))
        return cls(n, num, cat, names=[e.name for e in evs],
                   group_tables=group_tables, group_code=group_code,
                   stp_tables=stp_tables, stp_code=stp_code,
                   axes_tables=axes_tables, axes_code=axes_code)

    @classmethod
    def empty(cls) -> "TraceStore":
        """A zero-row store (identity element of `merge`)."""
        return cls(
            0, {col: np.empty(0, dtype=dt) for col, dt in _NUM_COLS},
            {col: Categorical(np.empty(0, dtype=np.int32), [])
             for col in _CAT_COLS},
            names=[], group_tables=[],
            group_code=np.empty(0, dtype=np.int32),
            stp_tables=[], stp_code=np.empty(0, dtype=np.int32),
            axes_tables=[], axes_code=np.empty(0, dtype=np.int32))

    @classmethod
    def merge(cls, stores: Sequence["TraceStore"]) -> "TraceStore":
        """Concatenate shard stores into one (sharded single-module ingest).

        Rows keep shard order; every interned vocabulary (categorical
        columns, replica-group / permute / axes tables) is re-interned
        across shards in first-seen order via `build_remap`, and the
        shard codes are remapped through the resulting tables.  Because a
        serial parse also interns in first-seen row order (and keys the
        payload tables by *value*), merging the chunk parses of
        `split_hlo_module` is byte-identical to parsing the whole module
        serially — pinned by tests/test_shard.py and the `--shard-only`
        bench gate.
        """
        stores = list(stores)
        if not stores:
            return cls.empty()
        if len(stores) == 1:
            return stores[0]
        n = sum(s.n for s in stores)
        num = {col: np.concatenate([getattr(s, col) for s in stores])
               for col, _dt in _NUM_COLS}

        cat: Dict[str, Categorical] = {}
        for col in _CAT_COLS:
            entries: List[str] = []
            for s in stores:
                entries.extend(getattr(s, col).vocab)
            remap, union = build_remap(entries)
            parts = []
            off = 0
            for s in stores:
                c = getattr(s, col)
                k = len(c.vocab)
                parts.append(remap[off:off + k][c.codes] if len(c.codes)
                             else np.empty(0, dtype=np.int32))
                off += k
            cat[col] = Categorical(np.concatenate(parts), union)

        def intern_tables(tables_of, key_fn):
            index: Dict = {}
            tables: List = []
            maps: List[np.ndarray] = []
            for s in stores:
                ts = tables_of(s)
                m = np.empty(len(ts), dtype=np.int32)
                for i, t in enumerate(ts):
                    key = key_fn(t)
                    j = index.get(key)
                    if j is None:
                        j = index[key] = len(tables)
                        tables.append(t)
                    m[i] = j
                maps.append(m)
            return tables, maps

        group_tables, g_maps = intern_tables(
            lambda s: s.group_tables,
            lambda t: tuple(tuple(int(x) for x in g) for g in t))
        group_code = np.concatenate(
            [m[s.group_code] if len(s.group_code)
             else np.empty(0, dtype=np.int32)
             for s, m in zip(stores, g_maps)])
        stp_tables, s_maps = intern_tables(
            lambda s: s.stp_tables,
            lambda t: tuple((int(a), int(b)) for a, b in t))
        stp_parts = []
        for s, m in zip(stores, s_maps):
            c = s.stp_code
            if not len(c):
                stp_parts.append(np.empty(0, dtype=np.int32))
            elif len(m):
                stp_parts.append(np.where(
                    c >= 0, m[np.clip(c, 0, None)], np.int32(-1)))
            else:
                stp_parts.append(c)
        stp_code = np.concatenate(stp_parts)
        axes_tables, a_maps = intern_tables(
            lambda s: s.axes_tables, lambda t: tuple(t))
        axes_code = np.concatenate(
            [m[s.axes_code] if len(s.axes_code)
             else np.empty(0, dtype=np.int32)
             for s, m in zip(stores, a_maps)])

        names: List[str] = []
        for s in stores:
            names.extend(s.names)
        return cls(n, num, cat, names=names,
                   group_tables=group_tables, group_code=group_code,
                   stp_tables=stp_tables, stp_code=stp_code,
                   axes_tables=axes_tables, axes_code=axes_code)

    @classmethod
    def merge_tree(cls, stores: Sequence["TraceStore"], arity: int = 8,
                   workers: int = 1) -> "TraceStore":
        """`merge(stores)` as a k-ary reduction tree: O(log n) depth.

        A serial fold over n per-host stores copies the accumulated rows
        at every step — O(n²·m) row traffic for a fleet of n stores of m
        rows; even the single flat `merge` call walks every vocab in one
        process.  The tree reduces `arity` stores at a time, level by
        level, so total row traffic is O(n·m·log_k n) and each level's
        chunk merges are independent — with `workers > 1` they run on a
        process pool (fork preferred: the store list is inherited
        copy-on-write and only (lo, hi) spans ride the pipe).

        Result is `TraceStore.identical` to `merge(stores)` for *any*
        arity and worker count: `merge` interns every vocabulary in
        first-seen order over the concatenation of its inputs' vocabs,
        and first-seen interning is associative over concatenation — so
        any ordered bracketing yields the same vocab order, codes, and
        payload tables (pinned by tests/test_warehouse.py).
        `workers <= 1` reduces in-process.
        """
        if arity < 2:
            raise ValueError(f"merge_tree arity must be >= 2, got {arity}")
        stores = list(stores)
        if not stores:
            return cls.empty()
        while len(stores) > 1:
            chunks = [stores[i:i + arity]
                      for i in range(0, len(stores), arity)]
            merged = None
            if workers and workers > 1 and len(chunks) > 1:
                merged = _pooled_merge_level(chunks, workers)
            if merged is None:
                merged = [cls.merge(c) for c in chunks]
            stores = merged
        return stores[0]

    def append(self, other: "TraceStore") -> "TraceStore":
        """In-place streaming variant of `merge`: extend self with `other`.

        `s = TraceStore.empty()` followed by `s.append(c)` per chunk
        leaves `s` `identical` to `TraceStore.merge(chunks)` — and
        therefore, when the chunks are `split_hlo_module` parses, to the
        batch `parse_hlo_store` of the concatenated input (pinned by
        tests/test_append.py and `bench_overhead --append-only`).
        Interning state (categorical vocab indices, payload-table value
        indices) is cached between calls and every numeric/code column
        lives in a doubling capacity buffer, so N appends cost O(total
        rows) amortized — this is what keeps the watch daemon's rolling
        store fresh without per-poll recomputation.

        Returns `self`.  `other` is unmodified; its payload tables are
        adopted by reference, exactly as `merge` shares them.
        """
        if other is self:
            raise ValueError("cannot append a TraceStore to itself")
        bufs = self._bufs
        for col, _dt in _NUM_COLS:
            bufs[col], view = _grow(bufs.get(col), getattr(self, col),
                                    getattr(other, col))
            setattr(self, col, view)
        for col in _CAT_COLS:
            getattr(self, col).extend(getattr(other, col))

        def extend_tables(name, tables, other_tables, key_fn):
            idx = self._tbl_idx.get(name)
            if idx is None or len(idx) != len(tables):
                idx = self._tbl_idx[name] = {key_fn(t): i
                                             for i, t in enumerate(tables)}
            m = np.empty(len(other_tables), dtype=np.int32)
            for i, t in enumerate(other_tables):
                key = key_fn(t)
                j = idx.get(key)
                if j is None:
                    j = idx[key] = len(tables)
                    tables.append(t)
                m[i] = j
            return m

        g_map = extend_tables(
            "group", self.group_tables, other.group_tables,
            lambda t: tuple(tuple(int(x) for x in g) for g in t))
        add = g_map[other.group_code] if len(other.group_code) \
            else np.empty(0, dtype=np.int32)
        bufs["group_code"], self.group_code = _grow(
            bufs.get("group_code"), self.group_code, add)

        s_map = extend_tables(
            "stp", self.stp_tables, other.stp_tables,
            lambda t: tuple((int(a), int(b)) for a, b in t))
        c = other.stp_code
        if not len(c):
            add = np.empty(0, dtype=np.int32)
        elif len(s_map):
            add = np.where(c >= 0, s_map[np.clip(c, 0, None)], np.int32(-1))
        else:
            add = c
        bufs["stp_code"], self.stp_code = _grow(
            bufs.get("stp_code"), self.stp_code, add)

        a_map = extend_tables("axes", self.axes_tables, other.axes_tables,
                              lambda t: tuple(t))
        add = a_map[other.axes_code] if len(other.axes_code) \
            else np.empty(0, dtype=np.int32)
        bufs["axes_code"], self.axes_code = _grow(
            bufs.get("axes_code"), self.axes_code, add)

        if not isinstance(self.names, list):
            self.names = list(self.names)    # adopt a lazy (mmap) name view
        self.names.extend(other.names)
        self.n += other.n
        self._edges = self._gexp = None
        self._rg_rows = self._stp_rows = self._axes_rows = None
        return self

    def identical(self, other: "TraceStore") -> bool:
        """Field-for-field equality, codes and vocabs included.

        Stricter than row-wise equality: two stores whose rows match but
        whose interned vocab/table *order* differs are not `identical`.
        This is the shard-equivalence pin (merge(shards) vs serial parse).
        """
        if self.n != other.n or self.names != other.names:
            return False
        for col, _dt in _NUM_COLS:
            if not np.array_equal(getattr(self, col), getattr(other, col)):
                return False
        for col in _CAT_COLS:
            a, b = getattr(self, col), getattr(other, col)
            if a.vocab != b.vocab or not np.array_equal(a.codes, b.codes):
                return False
        def norm_groups(tables):
            return [tuple(tuple(int(x) for x in g) for g in t)
                    for t in tables]
        def norm_stp(tables):
            return [tuple((int(a), int(b)) for a, b in t) for t in tables]
        return (norm_groups(self.group_tables) == norm_groups(other.group_tables)
                and np.array_equal(self.group_code, other.group_code)
                and norm_stp(self.stp_tables) == norm_stp(other.stp_tables)
                and np.array_equal(self.stp_code, other.stp_code)
                and [tuple(a) for a in self.axes_tables]
                == [tuple(a) for a in other.axes_tables]
                and np.array_equal(self.axes_code, other.axes_code))

    def annotation_clone(self) -> "TraceStore":
        """A scratch copy sharing this store's row data by reference.

        `costmodel.annotate_store` *rebinds* the annotation columns
        (`link_class`, `protocol`, `wire_bytes_per_device`, `est_time_s`,
        and the axes payload via `set_axes`) — it never writes into the
        existing arrays.  Re-annotating a clone under an alternate
        mesh/hardware therefore leaves this store untouched: that is the
        what-if engine's baseline-never-mutated invariant (pinned by
        tests/test_whatif.py).  The clone must not be appended to or
        edited row-wise — the payload tables and name list are aliased.
        """
        num = {col: getattr(self, col) for col, _dt in _NUM_COLS}
        cat = {col: getattr(self, col) for col in _CAT_COLS}
        return TraceStore(
            self.n, num, cat, names=self.names,
            group_tables=self.group_tables, group_code=self.group_code,
            stp_tables=self.stp_tables, stp_code=self.stp_code,
            axes_tables=self.axes_tables, axes_code=self.axes_code)

    def where(self, mask: np.ndarray) -> "TraceStore":
        """New store holding the rows where `mask` is True.

        Codes are kept as-is against *copies* of the vocab/table
        containers (append/extend mutate those lists in place, so
        sharing them would let a later append to either store corrupt
        the other).  Vocabularies are not compacted: rollups key on
        occurring codes only, so unused entries are invisible to every
        aggregate — and skipping compaction keeps the filter O(rows).
        Works on mmap-backed stores without copying unselected rows'
        strings (the fancy-indexed numeric columns are fresh arrays).
        """
        mask = np.asarray(mask, dtype=bool)
        if mask.shape != (self.n,):
            raise ValueError(
                f"mask shape {mask.shape} != ({self.n},)")
        idx = np.flatnonzero(mask)
        num = {col: np.asarray(getattr(self, col))[idx]
               for col, _dt in _NUM_COLS}
        cat = {col: Categorical(getattr(self, col).codes[idx],
                                list(getattr(self, col).vocab))
               for col in _CAT_COLS}
        names = self.names
        return TraceStore(
            int(len(idx)), num, cat,
            names=[names[int(i)] for i in idx],
            group_tables=list(self.group_tables),
            group_code=self.group_code[idx],
            stp_tables=list(self.stp_tables),
            stp_code=self.stp_code[idx],
            axes_tables=list(self.axes_tables),
            axes_code=self.axes_code[idx])

    # ---- per-row compatibility views ---------------------------------------

    @property
    def replica_groups(self) -> List[List[List[int]]]:
        if self._rg_rows is None:
            tables = self.group_tables
            self._rg_rows = [tables[c] for c in self.group_code]
        return self._rg_rows

    @property
    def source_target_pairs(self) -> List[Optional[List[Tuple[int, int]]]]:
        if self._stp_rows is None:
            tables = self.stp_tables
            self._stp_rows = [None if c < 0 else tables[c]
                              for c in self.stp_code]
        return self._stp_rows

    @property
    def axes(self) -> List[Tuple[str, ...]]:
        if self._axes_rows is None:
            tables = self.axes_tables
            self._axes_rows = [tables[c] for c in self.axes_code]
        return self._axes_rows

    @property
    def op_names(self) -> List[str]:
        return self.op_name.values()

    def set_axes(self, axes_tables: List[Tuple[str, ...]],
                 axes_code: np.ndarray) -> None:
        """Replace the axes payload (used by `costmodel.annotate_store`)."""
        self.axes_tables = axes_tables
        self.axes_code = np.asarray(axes_code, dtype=np.int32)
        self._axes_rows = None
        # a same-length replacement would fool append's len-based
        # staleness check on the cached value index — drop it outright
        self._tbl_idx.pop("axes", None)

    # ---- row views ---------------------------------------------------------

    def row(self, i: int) -> CollectiveEvent:
        """Materialize row `i` as the classic dataclass view.

        The mutable payloads (replica groups, permute pairs) are *copied*
        out of the shared dedup tables: `Trace` documents an
        edit-rows-in-place + `invalidate()` workflow, and an edit through
        an aliased table would silently rewrite every sibling row.
        """
        ch = int(self.channel_id[i])
        sc = self.stp_code[i]
        return CollectiveEvent(
            name=self.names[i],
            kind=self.kind.value(i),
            async_start=bool(self.async_start[i]),
            operand_bytes=int(self.operand_bytes[i]),
            result_bytes=int(self.result_bytes[i]),
            dtype=self.dtype.value(i),
            replica_groups=[list(g)
                            for g in self.group_tables[self.group_code[i]]],
            group_size=int(self.group_size[i]),
            num_groups=int(self.num_groups[i]),
            op_name=self.op_name.value(i),
            computation=self.computation.value(i),
            multiplicity=int(self.multiplicity[i]),
            channel_id=None if ch < 0 else ch,
            source_target_pairs=None if sc < 0 else list(self.stp_tables[sc]),
            link_class=self.link_class.value(i),
            axes=self.axes_tables[self.axes_code[i]],
            semantic=self.semantic.value(i),
            jax_prim=self.jax_prim.value(i),
            scope=self.scope.value(i),
            protocol=self.protocol.value(i),
            wire_bytes_per_device=float(self.wire_bytes_per_device[i]),
            est_time_s=float(self.est_time_s[i]))

    def rows(self) -> List[CollectiveEvent]:
        return [self.row(i) for i in range(self.n)]

    # ---- derived columns ---------------------------------------------------

    @property
    def weights(self) -> np.ndarray:
        """Execution multiplicity as float (the x-loop-trip-count weight)."""
        return self.multiplicity.astype(np.float64)

    @property
    def wire_total(self) -> np.ndarray:
        """Per-site total wire bytes (per execution), all participants."""
        return (self.wire_bytes_per_device * self.group_size.astype(np.float64)
                * self.num_groups.astype(np.float64))

    # ---- vectorized aggregates --------------------------------------------

    def total_collective_bytes(self) -> float:
        return float(np.dot(self.operand_bytes.astype(np.float64), self.weights))

    def total_wire_bytes(self) -> float:
        return float(np.dot(self.wire_total, self.weights))

    def total_est_time_s(self) -> float:
        return float(np.dot(self.est_time_s, self.weights))

    def overlapped_est_time_s(self) -> float:
        if self.n == 0:
            return 0.0
        per_class = np.bincount(self.link_class.codes,
                                weights=self.est_time_s * self.weights,
                                minlength=len(self.link_class.vocab))
        return float(per_class.max())

    def _rollup_arrays(self, inv: np.ndarray, nb: int) -> np.ndarray:
        """(4, nb) metric matrix [bytes, wire_bytes, count, time_s].

        Each row is a bincount over `inv`, accumulating in *row order* —
        the same add sequence the per-event dict reference performs per
        key, so the float sums are bit-identical, not merely close.
        """
        w = self.weights
        b = np.bincount(inv, weights=self.operand_bytes * w, minlength=nb)
        wire = np.bincount(inv, weights=self.wire_total * w, minlength=nb)
        c = np.bincount(inv, weights=w, minlength=nb)
        t = np.bincount(inv, weights=self.est_time_s * w, minlength=nb)
        return np.stack([b, wire, c, t])

    def _aggregate(self, inv: np.ndarray, labels: List[str]
                   ) -> Dict[str, Dict[str, float]]:
        """{label: {bytes, wire_bytes, count, time_s}} via bincount."""
        m = self._rollup_arrays(inv, len(labels))
        return {labels[i]: {"bytes": float(m[0, i]),
                            "wire_bytes": float(m[1, i]),
                            "count": float(m[2, i]), "time_s": float(m[3, i])}
                for i in range(len(labels))}

    def _join_codes(self, cats: Sequence[Categorical], sep: str = "|"
                    ) -> Tuple[np.ndarray, List[str]]:
        """Composite key codes over several categoricals (occurring only)."""
        if self.n == 0:
            return np.empty(0, dtype=np.int64), []
        combo = np.zeros(self.n, dtype=np.int64)
        for cat in cats:
            combo = combo * len(cat.vocab) + cat.codes
        uniq, inv = np.unique(combo, return_inverse=True)
        labels = []
        for code in uniq:
            parts = []
            for cat in reversed(cats):
                code, r = divmod(code, len(cat.vocab))
                parts.append(cat.vocab[r])
            labels.append(sep.join(reversed(parts)))
        return inv, labels

    def axes_labels(self) -> Categorical:
        """The axes payload as a categorical of joined labels ("data,model").

        Distinct tuples joining to the same string are merged, so the codes
        key on the *label* exactly like the per-event dict reference.
        """
        raw = [",".join(t) for t in self.axes_tables]
        return Categorical(self.axes_code, raw).remap_table(raw)

    def _codes_for(self, by: str) -> Tuple[np.ndarray, List[str]]:
        """(inverse codes, labels) for a named rollup key."""
        if by == "semantic":
            # empty semantic rolls up as "other" (matches per-event path)
            merged = self.semantic.remap(lambda v: v or "other")
            uniq, inv = np.unique(merged.codes, return_inverse=True)
            return inv, [merged.vocab[c] for c in uniq]
        if by == "kind_link":
            return self._join_codes((self.kind, self.link_class))
        if by == "site":
            # per-callsite key: interned op_name x kind x axes codes
            return self._join_codes((self.op_name, self.kind,
                                     self.axes_labels()))
        return self._join_codes((self.semantic, self.kind, self.link_class))

    def rollup(self, by: str) -> Tuple[List[str], np.ndarray]:
        """(labels, (4, n_labels) matrix [bytes, wire_bytes, count, time_s]).

        The array-shaped sibling of the dict rollups below — what the
        columnar renderers and the code-aligned diff consume directly.
        """
        if self.n == 0:
            return [], np.zeros((4, 0))
        inv, labels = self._codes_for(by)
        return labels, self._rollup_arrays(inv, len(labels))

    def by_kind_and_link(self) -> Dict[str, Dict[str, float]]:
        return self._aggregate(*self._codes_for("kind_link"))

    def by_semantic(self) -> Dict[str, Dict[str, float]]:
        if self.n == 0:
            return {}
        return self._aggregate(*self._codes_for("semantic"))

    def by_sem_kind_link(self) -> Dict[str, Dict[str, float]]:
        return self._aggregate(*self._codes_for("sem_kind_link"))

    def by_site(self) -> Dict[str, Dict[str, float]]:
        return self._aggregate(*self._codes_for("site"))

    def serial_est_time_s(self) -> float:
        """Total modeled time accumulated in strict row order.

        `total_est_time_s` uses `np.dot` (pairwise summation); the
        renderers need the *sequential* sum so the columnar and per-event
        paths print bit-identical totals.
        """
        if self.n == 0:
            return 0.0
        return float(np.add.accumulate(self.est_time_s * self.weights)[-1])

    # ---- replica-group expansion (static analysis support) -----------------

    def expand_groups(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Flattened expansion of the *unique* replica-group tables.

        Returns `(table_code, group_idx, device)` int64 arrays with one
        entry per device slot of every unique table — the scatter-ready
        form the static analyzer (`commcheck`) consumes.  Sized by the
        deduplicated tables, not by rows: a 100k-site trace stamping the
        same handful of `replica_groups` attrs expands each table once.
        Cached on the store.
        """
        if self._gexp is None:
            tcodes: List[np.ndarray] = []
            gidxs: List[np.ndarray] = []
            devs: List[np.ndarray] = []
            for c, table in enumerate(self.group_tables):
                for gi, group in enumerate(table):
                    k = len(group)
                    if not k:
                        continue
                    tcodes.append(np.full(k, c, dtype=np.int64))
                    gidxs.append(np.full(k, gi, dtype=np.int64))
                    devs.append(np.asarray(group, dtype=np.int64))
            if tcodes:
                self._gexp = (np.concatenate(tcodes), np.concatenate(gidxs),
                              np.concatenate(devs))
            else:
                z = np.empty(0, dtype=np.int64)
                self._gexp = (z, z.copy(), z.copy())
        return self._gexp

    def table_device_counts(self, num_devices: int) -> np.ndarray:
        """`(n_tables, num_devices)` appearance counts per unique table.

        Entry `[t, d]` is how many group slots of table `t` name device
        `d` — 0 = not a participant, >1 = listed twice (overlap).  Devices
        outside `[0, num_devices)` are dropped here; out-of-range lint
        reads the raw expansion instead.
        """
        counts = np.zeros((len(self.group_tables), num_devices),
                          dtype=np.int64)
        if counts.size == 0:
            return counts
        tcode, _gi, dev = self.expand_groups()
        ok = (dev >= 0) & (dev < num_devices)
        np.add.at(counts, (tcode[ok], dev[ok]), 1)
        return counts

    # ---- comm-matrix edges -------------------------------------------------

    def ring_edges(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Directed (src, dst, bytes) edge arrays for the comm matrix.

        Ring collectives contribute neighbor edges within each replica
        group; permutes follow their explicit source->target pairs.  Rows
        sharing a group/pair table are folded first (their per-row weights
        are bincount-summed per table code), so each unique topology emits
        its edges once.  Built once per store and cached — `np.add.at`
        scatters the whole edge list in one call.
        """
        if self._edges is None:
            srcs: List[np.ndarray] = []
            dsts: List[np.ndarray] = []
            ws: List[np.ndarray] = []
            stp_mask = self.stp_code >= 0
            ring_mask = ~stp_mask
            # ring rows: weight = wire_bytes_per_device x multiplicity,
            # summed over rows sharing the same group table
            if ring_mask.any():
                w_ring = np.bincount(
                    self.group_code[ring_mask],
                    weights=(self.wire_bytes_per_device
                             * self.weights)[ring_mask],
                    minlength=len(self.group_tables))
                for gc in np.flatnonzero(w_ring):
                    per_link = float(w_ring[gc])
                    for group in self.group_tables[gc]:
                        if len(group) <= 1:
                            continue
                        arr = np.asarray(group, dtype=np.int64)
                        srcs.append(arr)
                        dsts.append(np.roll(arr, -1))
                        ws.append(np.full(len(arr), per_link))
            # permute rows: weight = operand_bytes x multiplicity per pair
            if stp_mask.any():
                w_stp = np.bincount(
                    self.stp_code[stp_mask],
                    weights=(self.operand_bytes.astype(np.float64)
                             * self.weights)[stp_mask],
                    minlength=len(self.stp_tables))
                for sc in np.flatnonzero(w_stp):
                    pairs = np.asarray(self.stp_tables[sc], dtype=np.int64)
                    srcs.append(pairs[:, 0])
                    dsts.append(pairs[:, 1])
                    ws.append(np.full(len(pairs), float(w_stp[sc])))
            if srcs:
                self._edges = (np.concatenate(srcs), np.concatenate(dsts),
                               np.concatenate(ws))
            else:
                z = np.empty(0, dtype=np.int64)
                self._edges = (z, z.copy(), np.empty(0, dtype=np.float64))
        return self._edges

    # ---- serialization -----------------------------------------------------

    def _payload_dict(self) -> Dict[str, object]:
        return {
            "names": list(self.names),
            "group_tables": self.group_tables,
            "group_code": self.group_code.tolist(),
            "stp_tables": [[list(p) for p in t] for t in self.stp_tables],
            "stp_code": self.stp_code.tolist(),
            "axes_tables": [list(a) for a in self.axes_tables],
            "axes_code": self.axes_code.tolist(),
        }

    def to_dict(self) -> Dict[str, object]:
        """Compact JSON-able dict (exact integer round-trip)."""
        return {
            "version": SCHEMA_VERSION,
            "n": self.n,
            "num": {col: getattr(self, col).tolist() for col, _ in _NUM_COLS},
            "cat": {col: {"vocab": getattr(self, col).vocab,
                          "codes": getattr(self, col).codes.tolist()}
                    for col in _CAT_COLS},
            **self._payload_dict(),
        }

    @classmethod
    def _payload_from(cls, d: Dict[str, object]):
        return dict(
            names=list(d["names"]),
            group_tables=[[list(map(int, g)) for g in t]
                          for t in d["group_tables"]],
            group_code=np.asarray(d["group_code"], dtype=np.int32),
            stp_tables=[[(int(a), int(b)) for a, b in t]
                        for t in d["stp_tables"]],
            stp_code=np.asarray(d["stp_code"], dtype=np.int32),
            axes_tables=[tuple(a) for a in d["axes_tables"]],
            axes_code=np.asarray(d["axes_code"], dtype=np.int32))

    @staticmethod
    def _payload_from_v1(d: Dict[str, object]):
        """Intern the per-row payloads of a schema-1 file."""
        g_idx: Dict = {}
        group_tables: List[List[List[int]]] = []
        s_idx: Dict = {}
        stp_tables: List[List[Tuple[int, int]]] = []
        a_idx: Dict = {}
        axes_tables: List[Tuple[str, ...]] = []
        group_code, stp_code, axes_code = [], [], []
        for rgs in d["replica_groups"]:
            groups = [list(map(int, g)) for g in rgs]
            key = tuple(tuple(g) for g in groups)
            group_code.append(_intern(g_idx, key, group_tables, lambda: groups))
        for p in d["source_target_pairs"]:
            if not p:
                stp_code.append(-1)
                continue
            pairs = [(int(a), int(b)) for a, b in p]
            stp_code.append(_intern(s_idx, tuple(pairs), stp_tables,
                                    lambda: pairs))
        for a in d["axes"]:
            t = tuple(a)
            axes_code.append(_intern(a_idx, t, axes_tables, lambda: t))
        return dict(
            names=list(d["names"]),
            group_tables=group_tables,
            group_code=np.asarray(group_code, dtype=np.int32),
            stp_tables=stp_tables,
            stp_code=np.asarray(stp_code, dtype=np.int32),
            axes_tables=axes_tables,
            axes_code=np.asarray(axes_code, dtype=np.int32))

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "TraceStore":
        version = d.get("version")
        if version not in (1, SCHEMA_VERSION):
            raise ValueError(f"unknown TraceStore schema: {version!r}")
        n = int(d["n"])
        num = {col: np.asarray(d["num"][col], dtype=dt).reshape(n)
               for col, dt in _NUM_COLS}
        cat = {}
        for col in _CAT_COLS:
            if col == "op_name" and col not in d["cat"]:
                # v1 kept op_name as a per-row list, not a categorical
                cat[col] = Categorical.from_values(list(d["op_names"]))
                continue
            cat[col] = Categorical(
                np.asarray(d["cat"][col]["codes"], dtype=np.int32).reshape(n),
                list(d["cat"][col]["vocab"]))
        payload = cls._payload_from(d) if version == SCHEMA_VERSION \
            else cls._payload_from_v1(d)
        return cls(n, num, cat, **payload)

    def npz_arrays(self, prefix: str = "") -> Dict[str, np.ndarray]:
        """Flat array dict for the npz container (no object arrays).

        Numeric and code columns go in natively; per-row names pack into
        one newline-joined uint8 blob (`{prefix}names`, see `LazyNames`)
        so the side-car stays O(vocab) not O(rows); the remaining
        irregular payloads (unique tables, vocabs) ride in one JSON
        side-car string — small relative to the columns.
        """
        arrs: Dict[str, np.ndarray] = {}
        for col, _dt in _NUM_COLS:
            arrs[f"{prefix}{col}"] = getattr(self, col)
        for col in _CAT_COLS:
            arrs[f"{prefix}cat_{col}"] = getattr(self, col).codes
        arrs[f"{prefix}group_code"] = self.group_code
        arrs[f"{prefix}stp_code"] = self.stp_code
        arrs[f"{prefix}axes_code"] = self.axes_code
        arrs[f"{prefix}names"] = pack_names(self.names)
        side = {
            "version": SCHEMA_VERSION,
            "n": self.n,
            "vocab": {col: getattr(self, col).vocab for col in _CAT_COLS},
            "group_tables": self.group_tables,
            "stp_tables": [[list(p) for p in t] for t in self.stp_tables],
            "axes_tables": [list(a) for a in self.axes_tables],
        }
        arrs[f"{prefix}meta"] = np.array(json.dumps(side))
        return arrs

    @classmethod
    def from_npz_arrays(cls, arrs, prefix: str = "",
                        lazy: bool = False) -> "TraceStore":
        """Rebuild a store from `npz_arrays` output (or an mmap view).

        `np.asarray` adopts matching-dtype members without copying, so
        handing this an `MmapNpz` mapping builds a store whose columns
        are read-only memory maps — `lazy=True` additionally defers the
        packed-name decode (`LazyNames`), the only O(rows) Python work
        left on the load path.  Older archives that kept names in the
        JSON side-car still load.
        """
        side = json.loads(str(arrs[f"{prefix}meta"]))
        version = side.get("version")
        if version not in (1, SCHEMA_VERSION):
            raise ValueError(f"unknown TraceStore schema: {version!r}")
        n = int(side["n"])
        num = {col: np.asarray(arrs[f"{prefix}{col}"], dtype=dt).reshape(n)
               for col, dt in _NUM_COLS}
        cat = {}
        for col in _CAT_COLS:
            if col == "op_name" and col not in side["vocab"]:
                cat[col] = Categorical.from_values(list(side["op_names"]))
                continue
            cat[col] = Categorical(
                np.asarray(arrs[f"{prefix}cat_{col}"],
                           dtype=np.int32).reshape(n),
                list(side["vocab"][col]))
        if f"{prefix}names" in arrs:
            lazy_names = LazyNames(arrs[f"{prefix}names"], n)
            names = lazy_names if lazy else lazy_names._materialize()
        else:
            names = list(side["names"])    # pre-warehouse archives
        if version == SCHEMA_VERSION:
            payload = dict(
                names=names,
                group_tables=[[list(map(int, g)) for g in t]
                              for t in side["group_tables"]],
                group_code=np.asarray(arrs[f"{prefix}group_code"],
                                      dtype=np.int32).reshape(n),
                stp_tables=[[(int(a), int(b)) for a, b in t]
                            for t in side["stp_tables"]],
                stp_code=np.asarray(arrs[f"{prefix}stp_code"],
                                    dtype=np.int32).reshape(n),
                axes_tables=[tuple(a) for a in side["axes_tables"]],
                axes_code=np.asarray(arrs[f"{prefix}axes_code"],
                                     dtype=np.int32).reshape(n))
        else:
            payload = cls._payload_from_v1(side)
        return cls(n, num, cat, **payload)


# --------------------------------------------------------------------------
# pooled tree-merge level (merge_tree workers)
# --------------------------------------------------------------------------

# fork workers inherit the level's store list copy-on-write, so only
# (lo, hi) spans ride the job pipe; the lock serializes concurrent
# pooled merges (same discipline as hlo_parser._FORK_SHARD_STATE)
_FORK_MERGE_STATE = None
_FORK_MERGE_LOCK = threading.Lock()


def _merge_span(span):
    """Fork worker: merge one chunk of the inherited store list."""
    lo, hi = span
    return TraceStore.merge(_FORK_MERGE_STATE[lo:hi])


def _merge_job(stores):
    """Spawn worker: merge one pickled chunk of stores."""
    return TraceStore.merge(stores)


def _pooled_merge_level(chunks, workers):
    """One merge_tree level on a process pool; None -> caller runs serial.

    Mirrors `parse_hlo_store_sharded`'s ladder: fork when safe (a
    jax-loaded parent is multithreaded; forking it can deadlock), else
    spawn behind a no-op probe so a pool that cannot bootstrap degrades
    to the in-process path instead of hanging `ex.map` forever.
    """
    import multiprocessing
    import pickle
    from concurrent.futures import ProcessPoolExecutor
    from concurrent.futures.process import BrokenProcessPool
    from repro.core.hlo_parser import _SPAWN_PROBE_TIMEOUT_S
    global _FORK_MERGE_STATE

    workers = min(workers, len(chunks), os.cpu_count() or 1)
    if workers <= 1:
        return None
    method = "fork" if (
        "fork" in multiprocessing.get_all_start_methods()
        and "jax" not in sys.modules) else "spawn"
    try:
        mp_ctx = multiprocessing.get_context(method)
        if method == "fork":
            spans, off = [], 0
            for c in chunks:
                spans.append((off, off + len(c)))
                off += len(c)
            with _FORK_MERGE_LOCK:
                _FORK_MERGE_STATE = [s for c in chunks for s in c]
                try:
                    with ProcessPoolExecutor(max_workers=workers,
                                             mp_context=mp_ctx) as ex:
                        return list(ex.map(_merge_span, spans))
                finally:
                    _FORK_MERGE_STATE = None
        else:
            ex = ProcessPoolExecutor(max_workers=workers, mp_context=mp_ctx)
            try:
                ex.submit(int).result(timeout=_SPAWN_PROBE_TIMEOUT_S)
                results = list(ex.map(_merge_job, chunks))
                ex.shutdown()
                return results
            except Exception:
                ex.shutdown(wait=False, cancel_futures=True)
                raise OSError("spawn pool unusable")
    except (BrokenProcessPool, pickle.PicklingError, ImportError, OSError):
        return None


# --------------------------------------------------------------------------
# cross-store alignment (the code-aligned N-way diff core)
# --------------------------------------------------------------------------

def union_rollup(stores: Sequence[TraceStore], by: str
                 ) -> Tuple[List[str], np.ndarray]:
    """Shared-vocabulary rollup across N stores.

    Each store rolls up once to (labels, metrics); the label lists are
    interned into one union vocabulary (first-seen order across stores)
    and every store's metric columns scatter into its slice of a
    `(4, n_keys, n_stores)` tensor ([bytes, wire_bytes, count, time_s]).
    Keys absent from a store stay zero — exactly the `dict.get(key, zero)`
    semantics of the per-event alignment, without any string-keyed dicts
    on the N-trace hot path.
    """
    per = [s.rollup(by) for s in stores]
    all_labels: List[str] = []
    for labels, _ in per:
        all_labels.extend(labels)
    remap, union = build_remap(all_labels)
    out = np.zeros((4, len(union), len(stores)))
    off = 0
    for t, (labels, mat) in enumerate(per):
        k = len(labels)
        out[:, remap[off:off + k], t] = mat
        off += k
    return union, out


class IncrementalRollup:
    """Streaming sibling of `union_rollup`: fold per-chunk rollups into
    one (labels, matrix) accumulator without keeping the chunks.

    `update(store)` rolls the chunk up once and scatter-adds its metric
    columns into a union-vocabulary `(4, n_labels)` matrix, interning
    labels first-seen across chunks.  State is O(unique labels), not
    O(rows) — how the watch daemon keeps Table II aggregates fresh per
    poll without re-rolling the whole rolling store.
    """

    def __init__(self, by: str):
        self.by = by
        self.labels: List[str] = []
        self._index: Dict[str, int] = {}
        self.matrix = np.zeros((4, 0))

    def update(self, store: TraceStore) -> None:
        labels, mat = store.rollup(self.by)
        if not labels:
            return
        cols = np.empty(len(labels), dtype=np.int64)
        for i, lbl in enumerate(labels):
            j = self._index.get(lbl)
            if j is None:
                j = self._index[lbl] = len(self.labels)
                self.labels.append(lbl)
            cols[i] = j
        if len(self.labels) > self.matrix.shape[1]:
            grown = np.zeros((4, len(self.labels)))
            grown[:, :self.matrix.shape[1]] = self.matrix
            self.matrix = grown
        # chunk labels are unique, so fancy-index += is a safe scatter
        self.matrix[:, cols] += mat

    def as_dict(self) -> Dict[str, Dict[str, float]]:
        m = self.matrix
        return {lbl: {"bytes": float(m[0, i]), "wire_bytes": float(m[1, i]),
                      "count": float(m[2, i]), "time_s": float(m[3, i])}
                for i, lbl in enumerate(self.labels)}
