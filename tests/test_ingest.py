"""Ingest-pipeline equivalence: columnar fast path vs per-event reference.

The batched cost model (`costmodel.annotate_store`), vocab-level
attribution (`attribution.attribute_store`), and single-pass parser
(`hlo_parser.parse_hlo_store`) must match the per-event reference path
(`annotate_event` / `attribute_event` / `parse_hlo`) field-for-field on
randomized synthetic HLO with duplicated op_names and mixed iota/explicit
replica groups.
"""
import dataclasses

import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core import attribution, costmodel, hlo_parser
from repro.core.store import TraceStore
from repro.core.synth import synthetic_hlo, synthetic_trace
from repro.core.topology import MeshSpec, V5E, resolve_iota_groups
from repro.core.tracer import trace_from_hlo

MESH = MeshSpec((2, 4), ("data", "model"))


def ingest_pair(seed: int, n_sites: int = 400, trip_count: int = 12):
    text = synthetic_hlo(n_sites=n_sites, seed=seed, trip_count=trip_count)
    ref = trace_from_hlo(text, MESH, label="ref", engine="rows")
    fast = trace_from_hlo(text, MESH, label="fast", engine="columnar")
    return ref, fast


# -- end-to-end: parse -> annotate -> attribute -> store --------------------

@given(seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_ingest_rows_match_reference(seed):
    """Every materialized row of the columnar ingest equals the reference
    `CollectiveEvent`, field for field."""
    ref, fast = ingest_pair(seed)
    er, ef = ref.events, fast.events
    assert len(er) == len(ef) and len(er) > 0
    for a, b in zip(er, ef):
        if a != b:   # narrow the failure to the diverging field
            for fld in dataclasses.fields(a):
                assert getattr(a, fld.name) == getattr(b, fld.name), fld.name
        assert a == b


@given(seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_ingest_aggregates_byte_identical(seed):
    ref, fast = ingest_pair(seed)
    assert ref.by_kind_and_link() == fast.by_kind_and_link()
    assert ref.by_semantic() == fast.by_semantic()
    assert ref.store.by_sem_kind_link() == fast.store.by_sem_kind_link()
    assert ref.total_collective_bytes() == fast.total_collective_bytes()
    assert ref.total_wire_bytes() == fast.total_wire_bytes()
    assert ref.total_est_time_s() == fast.total_est_time_s()
    assert ref.overlapped_est_time_s() == fast.overlapped_est_time_s()


def test_ingest_op_stats_identical():
    ref, fast = ingest_pair(3)
    assert dataclasses.asdict(ref.op_stats) == dataclasses.asdict(fast.op_stats)


def test_ingest_comm_matrix_identical():
    import numpy as np

    from repro.core.topology import comm_matrix
    ref, fast = ingest_pair(5)
    np.testing.assert_allclose(comm_matrix(MESH, fast),
                               comm_matrix(MESH, list(ref.events)),
                               rtol=1e-12)


# -- batched annotate/attribute over an existing store ----------------------

@given(seed=st.integers(0, 500))
@settings(max_examples=6, deadline=None)
def test_annotate_store_matches_annotate_event(seed):
    """`annotate_store` + `attribute_store` on a store whose derived fields
    were wiped reproduces the per-event pipeline exactly."""
    tr = synthetic_trace(f"s{seed}", MESH, n_sites=300, seed=seed)
    ref_rows = tr.events
    store = TraceStore.from_events(ref_rows)
    # wipe the derived columns, then re-derive through the batched path
    from repro.core.store import Categorical
    n = store.n
    store.link_class = Categorical.constant(n)
    store.semantic = Categorical.constant(n)
    store.protocol = Categorical.constant(n)
    store.scope = Categorical.constant(n)
    store.jax_prim = Categorical.constant(n)
    store.wire_bytes_per_device = store.wire_bytes_per_device * 0.0
    store.est_time_s = store.est_time_s * 0.0
    costmodel.annotate_store(store, MESH, V5E)
    attribution.attribute_store(store)
    assert store.rows() == ref_rows


def test_parse_hlo_store_matches_parse_hlo():
    text = synthetic_hlo(n_sites=200, seed=9)
    events, stats = hlo_parser.parse_hlo(text, MESH.num_devices)
    store, fstats = hlo_parser.parse_hlo_store(text, MESH.num_devices)
    assert dataclasses.asdict(stats) == dataclasses.asdict(fstats)
    assert store.n == len(events)
    # parser-level fields (derived fields are blank on both sides here)
    for ev, row in zip(events, store.rows()):
        assert (ev.name, ev.kind, ev.async_start, ev.operand_bytes,
                ev.result_bytes, ev.dtype, ev.replica_groups, ev.group_size,
                ev.num_groups, ev.op_name, ev.computation, ev.multiplicity,
                ev.channel_id, ev.source_target_pairs) == \
               (row.name, row.kind, row.async_start, row.operand_bytes,
                row.result_bytes, row.dtype, row.replica_groups,
                row.group_size, row.num_groups, row.op_name, row.computation,
                row.multiplicity, row.channel_id, row.source_target_pairs)


def test_ingest_empty_module():
    text = "HloModule empty\n\nENTRY %main (x: f32[4]) -> f32[4] {\n" \
           "  %x = f32[4] parameter(0)\n  ROOT %y = f32[4] copy(%x)\n}\n"
    tr = trace_from_hlo(text, MESH, engine="columnar")
    assert tr.sites == 0
    assert tr.by_kind_and_link() == {}
    assert tr.by_semantic() == {}
    assert tr.events == []


# -- payload dedup + memoization --------------------------------------------

def test_store_payload_dedup():
    """Repeated replica-group attrs collapse into a handful of tables."""
    _ref, fast = ingest_pair(1, n_sites=500)
    s = fast.store
    assert s.n == 500
    assert len(s.group_tables) <= 10       # 7 rg attrs + default
    assert len(s.stp_tables) <= 2
    assert len(s.op_name.vocab) < 100      # heavy duplication preserved
    # per-row compatibility views still line up
    assert len(s.replica_groups) == s.n
    assert len(s.axes) == s.n
    assert len(s.op_names) == s.n


def test_resolve_iota_groups_memoized():
    a = resolve_iota_groups(2, 4, [8], None)
    b = resolve_iota_groups(2, 4, (8,), None)
    assert a == b == [[0, 1, 2, 3], [4, 5, 6, 7]]
    assert a is not b                      # lists are fresh (mutation-safe)
    b[0][0] = 99
    assert resolve_iota_groups(2, 4, [8], None)[0][0] == 0
    from repro.core.topology import _resolve_iota_cached
    assert _resolve_iota_cached.cache_info().hits >= 2


def test_resolve_iota_transposed_expansions_pinned():
    # [4,2]<=[2,4]T(1,0): column-major walk of the 2x4 grid -> stride-4 pairs
    assert resolve_iota_groups(4, 2, [2, 4], (1, 0)) == \
        [[0, 4], [1, 5], [2, 6], [3, 7]]
    # [2,4]<=[4,2]T(1,0): stride-2 interleave
    assert resolve_iota_groups(2, 4, [4, 2], (1, 0)) == \
        [[0, 2, 4, 6], [1, 3, 5, 7]]
    # identity transpose matches the plain form
    assert resolve_iota_groups(2, 4, [2, 4], (0, 1)) == \
        resolve_iota_groups(2, 4, [8], None)


def test_resolve_iota_malformed_raises():
    with pytest.raises(ValueError, match="prod"):
        resolve_iota_groups(3, 3, [8], None)          # 3*3 != 8
    with pytest.raises(ValueError, match="transpose"):
        resolve_iota_groups(4, 2, [2, 4], (0, 2))     # bad permutation


def _one_site_hlo(rg_attr: str) -> str:
    return (
        "HloModule malformed\n\n"
        "%add (a: f32[], b: f32[]) -> f32[] {\n"
        "  %a = f32[] parameter(0)\n"
        "  %b = f32[] parameter(1)\n"
        "  ROOT %r = f32[] add(%a, %b)\n"
        "}\n\n"
        "ENTRY %main (x: f32[128,128]) -> f32[128,128] {\n"
        "  %x = f32[128,128] parameter(0)\n"
        f"  %all-reduce.1 = f32[128,128] all-reduce(%x), channel_id=1, "
        f"{rg_attr}, to_apply=%add, "
        "metadata={op_name=\"jit(f)/psum\"}\n"
        "  ROOT %out = f32[128,128] add(%all-reduce.1, %x)\n"
        "}\n")


@pytest.mark.parametrize("rg_attr", [
    "replica_groups=[3,3]<=[8]",              # count*size != prod(dims)
    "replica_groups=[4,2]<=[2,4]T(0,2)",      # invalid transpose perm
    "replica_groups={}",                      # empty form
])
def test_malformed_iota_falls_back_full_range(rg_attr):
    """Both parser engines degrade malformed/empty replica_groups to the
    single full-range group instead of crashing mid-module."""
    text = _one_site_hlo(rg_attr)
    events, _ = hlo_parser.parse_hlo(text, MESH.num_devices)
    store, _ = hlo_parser.parse_hlo_store(text, MESH.num_devices)
    full = [list(range(MESH.num_devices))]
    assert [e.replica_groups for e in events] == [full]
    assert store.replica_groups == [full]


# -- store schema round-trip (v2) + v1 compat --------------------------------

def test_store_v2_roundtrip_after_fast_ingest():
    import json
    _ref, fast = ingest_pair(7, n_sites=150)
    d = json.loads(json.dumps(fast.store.to_dict()))
    assert d["version"] == 2
    store2 = TraceStore.from_dict(d)
    assert store2.rows() == fast.store.rows()


def test_store_v1_dict_still_loads():
    tr = synthetic_trace("v1", MESH, n_sites=60, seed=2)
    store = tr.store
    d = store.to_dict()
    # down-convert to the v1 layout (per-row payloads)
    v1 = {k: d[k] for k in ("n", "num")}
    v1["version"] = 1
    v1["cat"] = {k: v for k, v in d["cat"].items() if k != "op_name"}
    v1["names"] = store.names
    v1["op_names"] = store.op_names
    v1["axes"] = [list(a) for a in store.axes]
    v1["replica_groups"] = store.replica_groups
    v1["source_target_pairs"] = [
        None if p is None else [list(pair) for pair in p]
        for p in store.source_target_pairs]
    store2 = TraceStore.from_dict(v1)
    assert store2.rows() == store.rows()


# -- parallel multi-file session ingest --------------------------------------

@pytest.mark.parametrize("workers", [1, 2])
def test_session_from_hlo(workers):
    from repro.core.session import TraceSession
    items = [(f"cfg{i}", synthetic_hlo(n_sites=80, seed=i)) for i in range(3)]
    sess = TraceSession.from_hlo("sweep", items, MESH, max_workers=workers)
    assert sess.labels() == ["cfg0", "cfg1", "cfg2"]
    for (label, text), tr in zip(items, sess):
        ref = trace_from_hlo(text, MESH, label=label, engine="rows")
        assert tr.by_kind_and_link() == ref.by_kind_and_link()
        assert tr.by_semantic() == ref.by_semantic()


def test_session_ingest_cli(tmp_path, capsys):
    from repro.core.session import _main
    paths = []
    for i in range(2):
        p = tmp_path / f"run{i}.hlo"
        p.write_text(synthetic_hlo(n_sites=50, seed=i))
        paths.append(str(p))
    out = str(tmp_path / "sweep.json")
    assert _main(["ingest", out, *paths, "--mesh", "2,4",
                  "--axes", "data,model", "--workers", "1"]) == 0
    captured = capsys.readouterr().out
    assert "ingested 2 traces" in captured
    from repro.core.session import TraceSession
    assert TraceSession.load(out).labels() == ["run0", "run1"]
