"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                        scale=None):
    """q [B,H,Sq,D], k/v [B,K,Skv,D] -> [B,H,Sq,D] (fp32 softmax)."""
    B, H, Sq, D = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    kf = jnp.repeat(k, G, axis=1)
    vf = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    qi = (jnp.arange(Sq) + q_offset)[:, None]
    ki = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= ki <= qi
    if window and window > 0:
        ok &= (qi - ki) < window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32)
                      ).astype(q.dtype)


def mamba_scan_ref(a_bar, bx, c):
    """Sequential reference: h_t = a_t h_{t-1} + bx_t; y_t = <h_t, c_t>.

    a_bar/bx [B,S,Di,N] fp32, c [B,S,N] fp32 -> y [B,S,Di] fp32.
    """
    B, S, Di, N = a_bar.shape

    def step(h, t):
        a_t, bx_t, c_t = t
        h = a_t * h + bx_t                          # [B,Di,N]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((B, Di, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (a_bar.swapaxes(0, 1), bx.swapaxes(0, 1),
                          c.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)                        # [B,S,Di]
