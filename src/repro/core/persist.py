"""Atomic file persistence — write a same-directory temp file, then
`os.replace` it into place.

Every on-disk artifact this package produces (session saves, report
JSON/HTML, bench payloads, the watch daemon's rolling outputs) may be
read concurrently: the watch daemon re-emits them every poll while CI
artifact collection or a browser reload reads them.  A plain
`open(path, "w")` exposes truncated intermediate states to those
readers; renaming a fully-written sibling is atomic on POSIX, so a
reader sees either the old artifact or the new one — never a torn file.
"""
from __future__ import annotations

import contextlib
import os
import tempfile


@contextlib.contextmanager
def atomic_open(path: str, mode: str = "w"):
    """`open(path, mode)` with atomic-replace semantics.

    Yields a file object over a temp file created in `path`'s directory
    (same filesystem, so the final rename cannot cross a mount).  On
    clean exit the temp file is flushed, fsync'd, and renamed over
    `path`; on any error it is removed and `path` is left untouched.
    `mode` must be a write mode ("w" or "wb").
    """
    if mode not in ("w", "wb"):
        raise ValueError(f"atomic_open requires a write mode, got {mode!r}")
    target = os.path.abspath(path)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(target),
                               prefix=os.path.basename(target) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, mode) as f:
            yield f
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, target)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise
