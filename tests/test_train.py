"""Training-loop integration: convergence, bitwise resume, crash recovery,
straggler watchdog, optimizer correctness."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import ARCHS, smoke_config
from repro.data import DataConfig, SyntheticTokens
from repro.launch.presets import StepSettings
from repro.launch.train import Trainer
from repro.optim import AdamWConfig, adamw
from repro.training.watchdog import StragglerWatchdog

CFG = smoke_config(ARCHS["h2o-danube-3-4b"])


def make_trainer(tmp, **kw):
    kw.setdefault("steps", 8)
    kw.setdefault("batch", 2)
    kw.setdefault("seq", 64)
    kw.setdefault("ckpt_every", 4)
    return Trainer(CFG, ckpt_dir=str(tmp), **kw)


def test_loss_decreases(tmp_path):
    tr = make_trainer(tmp_path, steps=15, ckpt_every=0)
    log = tr.run()
    first = np.mean([m["loss"] for m in log[:3]])
    last = np.mean([m["loss"] for m in log[-3:]])
    assert last < first - 0.05, (first, last)


def test_resume_bitwise(tmp_path):
    """6 straight steps == 4 steps + restore + 2 steps (same data, params)."""
    a = make_trainer(tmp_path / "a", steps=6, ckpt_every=10)
    log_a = a.run()

    b1 = make_trainer(tmp_path / "b", steps=4, ckpt_every=4)
    b1.run()
    b2 = make_trainer(tmp_path / "b", steps=6, ckpt_every=4)
    log_b = b2.run()

    assert len(log_b) == 2   # resumed at step 4
    la = [m["loss"] for m in log_a[-2:]]
    lb = [m["loss"] for m in log_b]
    np.testing.assert_allclose(la, lb, rtol=0, atol=0)   # bitwise


def test_crash_injection_and_recovery(tmp_path):
    """Hard-crash at step 4 (exit 42), restart completes the run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "h2o-danube-3-4b", "--smoke", "--steps", "8", "--batch", "2",
            "--seq", "64", "--ckpt-dir", str(tmp_path), "--ckpt-every", "2"]
    res1 = subprocess.run(args + ["--fail-at-step", "4"], env=env,
                          capture_output=True, text=True, timeout=560)
    assert res1.returncode == 42
    assert "injected failure" in res1.stdout
    res2 = subprocess.run(args, env=env, capture_output=True, text=True,
                          timeout=560)
    assert res2.returncode == 0, res2.stderr[-2000:]
    assert "resumed from checkpoint at step 4" in res2.stdout
    assert "done" in res2.stdout


def test_watchdog_flags_stragglers():
    wd = StragglerWatchdog(window=50, sigma=4.0)
    for i in range(30):
        wd.observe(i, 0.100 + 0.001 * (i % 3))
    st_ = wd.observe(31, 0.5)      # 5x slower
    assert st_.flagged
    st2 = wd.observe(32, 0.101)
    assert not st2.flagged
    assert wd.hang_deadline_s() >= 0.5


def test_adamw_matches_reference():
    """One AdamW step against a hand-computed reference."""
    cfg = AdamWConfig(lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8,
                      weight_decay=0.0, clip_norm=0.0, warmup_steps=0,
                      total_steps=10**9, min_lr_ratio=1.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    state = adamw.init(cfg, p)
    new_p, new_state, _ = adamw.update(cfg, g, state, p)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    step = mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray(p["w"]) - 0.1 * step, rtol=1e-5)


@given(step=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_schedule_bounds(step):
    cfg = AdamWConfig(lr=3e-4, warmup_steps=100, total_steps=10_000)
    lr = float(adamw.schedule(cfg, jnp.asarray(step)))
    assert 0.0 <= lr <= cfg.lr * (1 + 1e-6)
    if step >= cfg.total_steps:
        assert lr <= cfg.lr * cfg.min_lr_ratio * (1 + 1e-4) + 1e-9


def test_data_determinism_and_seek():
    data = SyntheticTokens(CFG, DataConfig(4, 32, seed=7))
    b1 = data.batch_at(10)
    b2 = data.batch_at(10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = data.batch_at(11)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    it = data.iter_from(10)
    np.testing.assert_array_equal(next(it)["tokens"], b1["tokens"])
    assert b1["tokens"].min() >= 0
    assert b1["tokens"].max() < CFG.vocab_size


def test_grad_compression_still_trains(tmp_path):
    tr = Trainer(CFG, steps=6, batch=2, seq=64, ckpt_dir=None, ckpt_every=0,
                 settings=StepSettings(accum=1, remat="dots",
                                       grad_compression="bf16"))
    log = tr.run()
    assert np.isfinite([m["loss"] for m in log]).all()
