"""CLI --help regression pins: every subcommand's help matches behavior.

argparse exits 0 on --help, so each case runs `_main` under
`pytest.raises(SystemExit)` and asserts on the captured help text.
"""
import pytest

from repro.core.session import _main


def _help_of(argv, capsys):
    with pytest.raises(SystemExit) as ei:
        _main(argv + ["--help"])
    assert ei.value.code == 0
    return capsys.readouterr().out


def test_top_level_lists_every_subcommand(capsys):
    out = _help_of([], capsys)
    for cmd in ("demo", "ingest", "watch", "show", "table", "diff",
                "lint", "detect", "report", "whatif"):
        assert cmd in out


def test_report_help_mentions_by_site_views(capsys):
    # regression: the epilog omitted the per-site mode
    out = _help_of(["report"], capsys)
    assert "--by site" in out
    assert "--stream" in out and "--chunk-sites" in out


def test_whatif_help_documents_sweep_contract(capsys):
    out = _help_of(["whatif"], capsys)
    assert "--json" in out and "--top" in out
    assert "--mesh" in out and "--axes" in out
    assert "2 on input errors" in out


def test_ingest_help_documents_exit_codes(capsys):
    out = _help_of(["ingest"], capsys)
    for flag in ("--errors", "--retries", "--retry-backoff", "--timeout",
                 "--workers", "--shards", "--json"):
        assert flag in out
    assert "salvage" in out and "quarantined" in out


def test_watch_help_documents_daemon_flags(capsys):
    out = _help_of(["watch"], capsys)
    for flag in ("--fail-on", "--checkpoint", "--errors", "--once",
                 "--settle", "--interval", "--max-rounds"):
        assert flag in out
    assert "crash-resume" in out


def test_lint_and_detect_share_fail_on_contract(capsys):
    lint = _help_of(["lint"], capsys)
    det = _help_of(["detect"], capsys)
    for out in (lint, det):
        assert "--fail-on" in out and "--json" in out
    assert "critical" in lint     # lint default
    assert "never" in det         # detect default: advisory


def test_table_and_diff_document_site_mode(capsys):
    for cmd in ("table", "diff"):
        out = _help_of([cmd], capsys)
        assert "site" in out
