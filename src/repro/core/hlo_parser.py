"""HLO text parser: extract collective ops, shapes, replica groups, metadata.

This is the UCT-interception analogue.  UCX chooses transports at runtime so
ucTrace hooks the send functions; XLA chooses collectives at compile time so
we read them out of ``compiled.as_text()`` — an *exact* record of every
transfer the step will execute, including:

  * sync and async (`-start`/`-done`) collective forms,
  * iota (`[G,S]<=[dims]T(perm)`) and explicit (`{{0,1},..}`) replica groups,
  * per-op `metadata={op_name="..."}` — the compiled-in call-stack analogue,
  * while-loop trip counts, so collectives inside `lax.scan` bodies are
    counted `trip_count` times (log-processing analogue of matching
    repeated sends).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import CollectiveEvent, HloOpStats
from repro.core.topology import resolve_iota_groups

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_COMMENT_RE = re.compile(r"/\*.*?\*/")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_METADATA_RE = re.compile(r'op_name="([^"]*)"')
_CHANNEL_RE = re.compile(r"channel_id=(\d+)")
_IOTA_RG_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_EXPLICIT_RG_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)?\}")
_STP_RE = re.compile(r"source_target_pairs=\{(\{[^}]*\}(?:,\{[^}]*\})*)?\}")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")


def parse_type_bytes(type_str: str) -> Tuple[int, str]:
    """Total bytes + primary dtype of a (possibly tuple) HLO type string."""
    total = 0
    dtype = ""
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        count = 1
        if dims:
            for d in dims.split(","):
                count *= int(d)
        total += count * DTYPE_BYTES[dt]
        if not dtype:
            dtype = dt
    return total, dtype


@dataclass
class _Computation:
    name: str
    lines: List[str] = field(default_factory=list)


_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _line_scope(line: str) -> str:
    """Deepest named_scope component of the op's metadata (module label)."""
    md = _METADATA_RE.search(line)
    if not md:
        return ""
    from repro.core.attribution import split_op_name
    scope, _prim = split_op_name(md.group(1))
    return scope


def _dot_flops(line: str, type_str: str, shapes: Dict[str, str]) -> float:
    """FLOPs of one dot: 2 x prod(result dims) x prod(lhs contracting dims)."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0.0
    out_elems = 1
    if m.group(2):
        for d in m.group(2).split(","):
            out_elems *= int(d)
    cm = _DOT_DIMS_RE.search(line)
    contract = 1
    if cm is not None:
        # lhs operand shape
        rest = line.split("dot(", 1)[1]
        ops = _OPERANDS_RE.findall(rest.split(")")[0])
        if ops:
            lhs_type = shapes.get(ops[0], "")
            sm = _SHAPE_RE.search(lhs_type)
            if sm and sm.group(2):
                dims = [int(x) for x in sm.group(2).split(",")]
                idxs = [int(x) for x in cm.group(1).split(",")] if cm.group(1) else []
                for i in idxs:
                    if i < len(dims):
                        contract *= dims[i]
    return 2.0 * out_elems * contract


def _split_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    current: Optional[_Computation] = None
    entry_name = None
    for line in text.splitlines():
        stripped = line.strip()
        # computation headers: `[ENTRY] %name (params...) -> type {`
        if stripped.endswith("{") and "->" in stripped and "=" not in \
                stripped.split("(")[0]:
            head = stripped
            is_entry = head.startswith("ENTRY")
            if is_entry:
                head = head[len("ENTRY"):].lstrip()
            name = head.split("(")[0].strip().lstrip("%").strip()
            if name:
                current = _Computation(name)
                comps[name] = current
                if is_entry:
                    entry_name = name
                continue
        if current is not None:
            current.lines.append(line)
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond_comp: _Computation) -> int:
    """Heuristic while-loop trip count: largest int constant in condition."""
    best = 1
    for line in cond_comp.lines:
        for m in _CONST_INT_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def _multiplicities(comps: Dict[str, _Computation]) -> Dict[str, int]:
    """Execution multiplicity per computation (while bodies x trip count)."""
    entry = comps.get("__entry__")
    mult: Dict[str, int] = {}
    if entry is None:
        return {name: 1 for name in comps}
    mult[entry.name] = 1

    # propagate through call sites breadth-first
    changed = True
    passes = 0
    while changed and passes < 50:
        changed = False
        passes += 1
        for name, comp in comps.items():
            if name == "__entry__" or name not in mult:
                continue
            base = mult[name]
            for line in comp.lines:
                callees: List[Tuple[str, int]] = []
                wm = _WHILE_RE.search(line)
                cm = _COND_RE.search(line)
                if wm and cm and "while(" in line:
                    cond = comps.get(cm.group(1))
                    tc = _trip_count(cond) if cond else 1
                    callees.append((wm.group(1), tc))
                    callees.append((cm.group(1), tc))
                else:
                    for rx in (_CALLS_RE, _TO_APPLY_RE):
                        m = rx.search(line)
                        if m:
                            callees.append((m.group(1), 1))
                for callee, k in callees:
                    new = base * k
                    if callee in comps and mult.get(callee, 0) < new:
                        mult[callee] = new
                        changed = True
    return mult


def _parse_replica_groups(line: str, num_devices: int) -> List[List[int]]:
    m = _IOTA_RG_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) else None
        return resolve_iota_groups(g, s, dims, perm)
    m = _EXPLICIT_RG_RE.search(line)
    if m:
        body = m.group(1)
        if not body:
            return [list(range(num_devices))]
        groups = []
        for grp in re.findall(r"\{([^}]*)\}", body):
            if grp.strip():
                groups.append([int(x) for x in grp.split(",")])
        return groups or [list(range(num_devices))]
    return [list(range(num_devices))]


def _parse_stp(line: str) -> Optional[List[Tuple[int, int]]]:
    m = _STP_RE.search(line)
    if not m or not m.group(1):
        return None
    pairs = []
    for grp in re.findall(r"\{([^}]*)\}", m.group(1)):
        a, b = grp.split(",")
        pairs.append((int(a), int(b)))
    return pairs


def parse_hlo(text: str, num_devices: int) -> Tuple[List[CollectiveEvent], HloOpStats]:
    """Extract collective events (+program stats) from compiled HLO text.

    Also accumulates *loop-aware* FLOP and traffic totals (stats.flops /
    stats.bytes_accessed): `compiled.cost_analysis()` counts while-loop
    bodies ONCE, so for a scan-over-layers program it under-reports compute
    by ~num_layers x.  We re-derive both, multiplying by trip counts.
    """
    comps = _split_computations(text)
    mult = _multiplicities(comps)
    events: List[CollectiveEvent] = []
    stats = HloOpStats()

    # symbol tables (per computation) for operand-shape lookups, and the set
    # of fusion-body computations (excluded from byte accounting: their
    # traffic is the fusion op's operands/results at the call site).
    shapes_by_comp: Dict[str, Dict[str, str]] = {}
    kinds_by_comp: Dict[str, Dict[str, str]] = {}
    fusion_bodies: set = set()
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        table: Dict[str, str] = {}
        kinds: Dict[str, str] = {}
        for line in comp.lines:
            line = _COMMENT_RE.sub("", line)
            lm = _OPLINE_RE.match(line)
            if lm:
                table[lm.group(1)] = lm.group(2)
                kinds[lm.group(1)] = lm.group(3)
                if lm.group(3) == "fusion":
                    fm = _CALLS_RE.search(line)
                    if fm:
                        fusion_bodies.add(fm.group(1))
        shapes_by_comp[name] = table
        kinds_by_comp[name] = kinds

    _NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota", "reshape"}
    # elementwise/cheap ops: on TPU these fuse into producers/consumers, so
    # counting their operands would massively over-state HBM traffic (the
    # CPU host backend fuses far less aggressively than the TPU pipeline).
    _FUSED_ON_TPU = {
        "add", "subtract", "multiply", "divide", "negate", "abs", "exponential",
        "exponential-minus-one", "log", "log-plus-one", "rsqrt", "sqrt", "tanh",
        "logistic", "sign", "cosine", "sine", "floor", "ceil", "round-nearest-afz",
        "round-nearest-even", "maximum", "minimum", "compare", "select", "and",
        "or", "not", "xor", "clamp", "convert", "broadcast", "power", "is-finite",
        "shift-left", "shift-right-logical", "shift-right-arithmetic", "atan2",
        "remainder", "map", "reverse", "real", "imag", "erf", "expm1", "log1p",
        "popcnt", "clz", "slice", "pad", "concatenate", "copy", "transpose",
        "reduce", "broadcast-in-dim", "stochastic-convert", "cbrt",
    }

    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1)
        shapes = shapes_by_comp.get(name, {})
        kinds = kinds_by_comp.get(name, {})
        in_fusion_body = name in fusion_bodies
        for line in comp.lines:
            line = _COMMENT_RE.sub("", line)
            lm = _OPLINE_RE.match(line)
            if not lm:
                continue
            op_result, type_str, op_kind, rest = lm.groups()

            if op_kind == "dot":
                fl = _dot_flops(line, type_str, shapes) * m
                stats.flops += fl
                sc = _line_scope(line)
                stats.flops_by_scope[sc] = stats.flops_by_scope.get(sc, 0.0) + fl

            # HBM-traffic estimate: each materialized tensor is written once
            # (result bytes) and read about once downstream; parameter
            # (weight) operands are charged at the consuming op.  Counting
            # operand bytes of every op would double-count each fusion
            # boundary and inflate traffic ~10x at CPU-fusion granularity.
            if (not in_fusion_body and op_kind not in _NO_TRAFFIC
                    and op_kind not in _FUSED_ON_TPU):
                rb, _ = parse_type_bytes(type_str)
                pb = 0
                for op_ref in _OPERANDS_RE.findall(rest.split(")")[0]):
                    if kinds.get(op_ref) == "parameter":
                        b, _d = parse_type_bytes(shapes.get(op_ref, ""))
                        pb += b
                tb = (2 * rb + pb) * m
                stats.bytes_accessed += tb
                sc = _line_scope(line)
                stats.bytes_by_scope[sc] = stats.bytes_by_scope.get(sc, 0.0) + tb

            if op_kind in ("transpose", "copy") or op_kind.startswith("transpose"):
                stats.n_transpose += 1
                b, _ = parse_type_bytes(type_str)
                stats.transpose_bytes += b * m
                continue
            if op_kind == "fusion":
                stats.n_fusion += 1
                continue
            if op_kind == "convert":
                stats.n_convert += 1
                continue
            if op_kind in ("reshape", "bitcast"):
                stats.n_reshape += 1
                continue

            base = op_kind[:-6] if op_kind.endswith("-start") else op_kind
            if base not in COLLECTIVE_KINDS:
                continue
            if op_kind.endswith("-done"):
                continue

            result_bytes, dtype = parse_type_bytes(type_str)
            # operand bytes: for -start forms the result is a (operand, result)
            # tuple; approximate operand size from the paren list shapes if
            # present, else from result type arithmetic.
            operand_bytes = _operand_bytes(rest, type_str, base, line)
            groups = _parse_replica_groups(line, num_devices)
            stp = _parse_stp(line) if base == "collective-permute" else None
            md = _METADATA_RE.search(line)
            ch = _CHANNEL_RE.search(line)
            gsz = max(len(g) for g in groups) if groups else 1
            events.append(CollectiveEvent(
                name=op_result,
                kind=base,
                async_start=op_kind.endswith("-start"),
                operand_bytes=operand_bytes,
                result_bytes=result_bytes,
                dtype=dtype,
                replica_groups=groups,
                group_size=gsz,
                num_groups=len(groups),
                op_name=md.group(1) if md else "",
                computation=name,
                multiplicity=m,
                channel_id=int(ch.group(1)) if ch else None,
                source_target_pairs=stp,
            ))
    return events, stats


def _operand_bytes(rest: str, type_str: str, kind: str, line: str) -> int:
    """Payload (input) bytes of the collective."""
    result_bytes, _ = parse_type_bytes(type_str)
    if kind == "all-gather":
        # result = group_size x operand; report the *result* (gathered) size
        # as payload — matches the roofline "operand sizes" convention of
        # counting the logically-moved tensor once.
        return result_bytes
    if kind == "reduce-scatter":
        # operand = group_size x result; payload is the pre-scatter operand.
        m = _IOTA_RG_RE.search(line)
        if m:
            return result_bytes * int(m.group(2))
        return result_bytes
    # all-reduce / all-to-all / permute: operand size == result size
    # (-start tuples double-count operand+result; halve them)
    if type_str.strip().startswith("(") and kind == "all-reduce":
        return result_bytes // 2
    return result_bytes
