"""Fig 4 analogue: protocol regimes (eager vs rendezvous) across message
sizes and collective kinds.

The paper sweeps UCX configs to expose eager/rndv crossover and get/put
schemes; we sweep payload sizes per collective kind, measure host wall time
on an 8-device mesh, and derive the v5e cost-model completion time + regime
classification (latency- vs bandwidth-bound) from the tracer.
"""
from __future__ import annotations

import json

from _util import run_worker

WORKER = """
import functools
import json
import time
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import MeshSpec, trace_from_hlo

mesh = jax.make_mesh((8,), ("model",))
spec = MeshSpec((8,), ("model",))

KINDS = {
    "all-reduce": (lambda s: jax.lax.psum(s, "model"), P(None)),
    "all-gather": (lambda s: jax.lax.all_gather(s, "model"), P(None)),
    "reduce-scatter": (lambda s: jax.lax.psum_scatter(
        s.reshape(8, -1), "model", scatter_dimension=0), P("model")),
    "all-to-all": (lambda s: jax.lax.all_to_all(
        s.reshape(8, -1), "model", 0, 0), P("model")),
}

rows = []
for log2 in (10, 14, 18, 22, 26):
    nbytes = 1 << log2
    n_elems = max(nbytes // 4, 64)
    x = jnp.zeros((8, n_elems // 8), jnp.float32)
    xd = jax.device_put(x, NamedSharding(mesh, P("model")))
    for kind, (f, out_spec) in KINDS.items():
        fn = jax.jit(shard_map(f, mesh=mesh, in_specs=P("model"),
                               out_specs=out_spec, check_rep=False))
        compiled = fn.lower(xd).compile()
        for _ in range(2):
            out = fn(xd)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(xd)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 5 * 1e6
        tr = trace_from_hlo(compiled.as_text(), spec, label=kind)
        if tr.events:
            ev = max(tr.events, key=lambda e: e.operand_bytes)
            derived = f"v5e={ev.est_time_s*1e6:.2f}us|{ev.protocol}|{ev.link_class}"
        else:
            derived = "no-collective"
        rows.append((f"proto/{kind}/{nbytes}B", us, derived))
print("JSON" + json.dumps(rows))
"""


def run():
    out = run_worker(WORKER, devices=8)
    for line in out.splitlines():
        if line.startswith("JSON"):
            return [tuple(r) for r in json.loads(line[4:])]
    raise RuntimeError("no JSON output from worker")
