"""Synthetic trace generation — controlled workloads for sessions/benches.

The paper's comparison experiments need *many* traces from *different*
configurations.  On hardwareless CI we synthesize them: random-but-seeded
collective mixes laid out on a real `MeshSpec`, run through the real cost
model and attribution pipeline, so every derived field (link class, wire
bytes, protocol regime, semantic class) is produced by the same code paths
a compiled-HLO trace exercises.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import attribution, costmodel
from repro.core.events import CollectiveEvent, Trace
from repro.core.topology import Hardware, MeshSpec, V5E

# (kind, scope path, relative weight) — a train-step-shaped mix
_SITE_MIX: Tuple[Tuple[str, str, float], ...] = (
    ("all-reduce", "layer/mlp", 3.0),
    ("all-reduce", "opt_update", 2.0),
    ("all-gather", "layer/attn", 2.0),
    ("reduce-scatter", "opt_update", 1.5),
    ("all-to-all", "layer/moe/dispatch", 1.0),
    ("all-gather", "embed", 0.5),
    ("all-reduce", "loss", 0.5),
)

_BYTE_CHOICES = np.array([1 << 10, 1 << 14, 1 << 18, 1 << 21,
                          1 << 24, 1 << 26], dtype=np.int64)
_MULT_CHOICES = np.array([1, 1, 1, 4, 12], dtype=np.int64)


def _axis_groups(mesh: MeshSpec, axis_idx: int):
    """All replica groups spanning exactly mesh axis `axis_idx`."""
    ids = np.arange(mesh.num_devices).reshape(mesh.shape)
    ids = np.moveaxis(ids, axis_idx, -1).reshape(-1, mesh.shape[axis_idx])
    return [list(map(int, row)) for row in ids]


def synthetic_trace(label: str, mesh: MeshSpec, hw: Hardware = V5E,
                    n_sites: int = 1000, seed: int = 0,
                    backward_fraction: float = 0.4,
                    axis_weights: Optional[Sequence[float]] = None) -> Trace:
    """Build an annotated `Trace` of `n_sites` synthetic collective sites.

    `axis_weights` biases which mesh axis each collective spans (defaults
    to uniform) — e.g. weight the `data` axis to mimic a DP-heavy run.
    """
    rng = np.random.default_rng(seed)
    kinds = np.array([m[0] for m in _SITE_MIX])
    scopes = np.array([m[1] for m in _SITE_MIX])
    weights = np.array([m[2] for m in _SITE_MIX])
    mix = rng.choice(len(_SITE_MIX), size=n_sites, p=weights / weights.sum())
    axes_p = None
    if axis_weights is not None:
        axes_p = np.asarray(axis_weights, dtype=float)
        axes_p = axes_p / axes_p.sum()
    axis_pick = rng.choice(len(mesh.axes), size=n_sites, p=axes_p)
    nbytes = rng.choice(_BYTE_CHOICES, size=n_sites)
    mults = rng.choice(_MULT_CHOICES, size=n_sites)
    backward = rng.random(n_sites) < backward_fraction

    groups_by_axis = [_axis_groups(mesh, i) for i in range(len(mesh.shape))]
    events = []
    for i in range(n_sites):
        kind, scope = kinds[mix[i]], scopes[mix[i]]
        groups = groups_by_axis[axis_pick[i]]
        wrap = "transpose(core_fn)/" if backward[i] else ""
        op_name = f"jit(train_step)/{wrap}{scope}/{_PRIM_FOR.get(kind, 'psum')}"
        events.append(CollectiveEvent(
            name=f"{kind}.{i}",
            kind=kind,
            async_start=bool(rng.random() < 0.25),
            operand_bytes=int(nbytes[i]),
            result_bytes=int(nbytes[i]),
            dtype="bf16",
            replica_groups=groups,
            group_size=len(groups[0]),
            num_groups=len(groups),
            op_name=op_name,
            computation="main" if not backward[i] else "scan_body",
            multiplicity=int(mults[i]),
            channel_id=i + 1))
    for ev in events:
        costmodel.annotate_event(ev, mesh, hw)
    attribution.attribute_all(events)
    return Trace(label=label, mesh_shape=mesh.shape, mesh_axes=mesh.axes,
                 num_devices=mesh.num_devices, events=events)


_PRIM_FOR = {
    "all-reduce": "psum",
    "all-gather": "all_gather",
    "reduce-scatter": "psum_scatter",
    "all-to-all": "all_to_all",
    "collective-permute": "ppermute",
}
