"""falcon-mamba-7b — attention-free Mamba-1 LM. [arXiv:2410.05355]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                    # attention-free, no separate MLP (mamba block only)
    vocab_size=65024,
    rope="none",
    ssm_state=16,
    d_conv=4,
    expand=2,                  # d_inner = 8192
    notes="mamba-1 blocks only; O(1) state => long_500k applicable",
)
