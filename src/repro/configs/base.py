"""Config system: model architecture configs + input-shape specs.

Every assigned architecture is a `ModelConfig`; every assigned input shape is
a `ShapeSpec`.  A (config, shape) pair fully determines a compiled step
(train / prefill / decode) for the dry-run, the tracer and the roofline.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (superset over all assigned families)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # normalization / activation
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "silu"              # silu | gelu
    glu: bool = True               # gated (SwiGLU/GeGLU) vs plain MLP
    sandwich_norm: bool = False    # gemma3-style post-block norms
    qk_norm: bool = False          # qwen3-style per-head q/k RMSNorm

    # position encoding
    rope: str = "standard"         # standard | partial | mrope | learned | none
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0     # fraction of head_dim rotated (chatglm: 0.5)
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl (t, h, w) sections

    # attention locality
    window: int = 0                # 0 = full attention; >0 = sliding window
    # per-layer window pattern; e.g. gemma3: 5 local layers then 1 global.
    # tuple of (window_or_0) with len == num_layers, or () = uniform.
    window_pattern: Tuple[int, ...] = ()

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_group_size: int = 512      # routing-group tokens (dispatch ~ Sg^2)
    moe_table_dtype: str = "float32"   # dispatch/combine one-hot tensors
    moe_dispatch: str = "einsum"   # einsum (GShard baseline) | sort (EP)

    # SSM scan scheduling: precompute a_bar/bx for the full sequence or
    # per-chunk inside the scan (16x smaller live tensors)
    ssm_inloop: bool = False

    # SSM (mamba-1)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    source_len: int = 0            # fixed encoder length (whisper: 1500 frames)

    # embeddings
    tie_embeddings: bool = False
    max_positions: int = 32768     # learned-position table bound (whisper)

    # dtypes (strings to keep config hashable / serializable)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # notes for DESIGN.md §Arch-applicability
    notes: str = ""

    # ---- derived helpers -------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer attention window sizes (0 = full attention)."""
        if self.window_pattern:
            assert len(self.window_pattern) == self.num_layers
            return self.window_pattern
        return (self.window,) * self.num_layers

    @property
    def is_subquadratic(self) -> bool:
        """True if long-context decode is architecturally bounded.

        SSM / hybrid state is O(1); SWA archs retain a bounded KV window.
        gemma3 counts: only 1-in-6 layers is global.  Pure full-attention
        archs (and enc-dec audio) are excluded per the assignment.
        """
        if self.family in ("ssm", "hybrid"):
            return True
        if self.family == "encdec":
            return False
        windows = self.layer_windows()
        return any(w > 0 for w in windows)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape."""

    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    # decode with windowed KV retention (long_500k on SWA archs keeps only
    # the attention-reachable window per local layer).
    windowed_cache: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1, windowed_cache=True),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a (config, shape) cell runs, and the reason when skipped."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        if cfg.family == "encdec":
            return False, ("enc-dec audio: source fixed at %d frames, decoder "
                           "context <=448; 500k decode undefined" % cfg.source_len)
        return False, "pure full-attention arch: unbounded KV at 500k ctx (skip per assignment)"
    return True, ""


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    kw = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window_pattern=(),
        window=16 if cfg.window or cfg.window_pattern else 0,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, top_k=min(2, cfg.top_k), moe_d_ff=64)
    if cfg.ssm_state:
        kw.update(ssm_state=4, d_conv=4, expand=2)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2, source_len=24, max_positions=128)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(4, 2, 2))
    return cfg.replace(**kw)
