"""Fault-tolerant training driver.

Features exercised by the integration tests:
  * deterministic seekable data (restart reproduces batches bitwise),
  * periodic atomic checkpoints + resume from LATEST,
  * crash injection (`--fail-at-step`) for restart-continuity testing,
  * SIGTERM preemption handler (checkpoint then exit 0),
  * straggler watchdog with step-time stats,
  * optional mesh execution (`--mesh DxM`) over available devices.

Run e.g.:
    PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b --smoke \
        --steps 50 --batch 4 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 20
"""
from __future__ import annotations

import argparse
import os
import signal
import sys

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs import get_config, smoke_config
from repro.data import DataConfig, SyntheticTokens
from repro.distributed import sharding as shlib
from repro.distributed.autoshard import activation_sharding
from repro.launch.presets import StepSettings
from repro.launch.steps import make_train_step
from repro.models import api as model_api
from repro.optim import adamw
from repro.training.watchdog import StragglerWatchdog


class Trainer:
    def __init__(self, cfg, *, steps=100, batch=8, seq=256, ckpt_dir=None,
                 ckpt_every=50, mesh=None, settings=None, opt_cfg=None,
                 seed=0, fail_at_step=None, log_every=10, keep=3):
        self.cfg = cfg
        self.steps = steps
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.mesh = mesh
        self.fail_at_step = fail_at_step
        self.log_every = log_every
        self.keep = keep
        self.settings = settings or StepSettings(accum=1, remat="dots")
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(
            lr=1e-3, warmup_steps=20, total_steps=steps,
            state_dtype=self.settings.opt_state_dtype)
        self.data = SyntheticTokens(cfg, DataConfig(batch, seq, seed=seed))
        self.watchdog = StragglerWatchdog()
        self.metrics_log = []
        self._preempted = False

        self.step_fn = make_train_step(cfg, self.opt_cfg, self.settings)
        if mesh is not None:
            pspecs = shlib.param_pspecs(cfg, mesh)
            psh = shlib.named(mesh, pspecs)
            osh = shlib.named(mesh, {"m": pspecs, "v": pspecs,
                                     "count": jax.sharding.PartitionSpec()})
            self.jit_step = jax.jit(self.step_fn, donate_argnums=(0, 1),
                                    in_shardings=(psh, osh, None),
                                    out_shardings=(psh, osh, None))
            self.param_sh = psh
        else:
            self.jit_step = jax.jit(self.step_fn, donate_argnums=(0, 1))
            self.param_sh = None

    # ---- state ------------------------------------------------------------
    def init_state(self, seed=0):
        params = model_api.init_params(self.cfg, seed)
        if self.param_sh is not None:
            params = jax.device_put(params, self.param_sh)
        opt = adamw.init(self.opt_cfg, params)
        return params, opt, 0

    def restore_or_init(self, seed=0):
        if self.ckpt_dir and checkpoint.latest_step(self.ckpt_dir) is not None:
            params, opt, _ = self.init_state(seed)
            tree = {"params": params, "opt": opt}
            sh = None
            if self.param_sh is not None:
                sh = {"params": self.param_sh,
                      "opt": {"m": self.param_sh, "v": self.param_sh,
                              "count": jax.sharding.NamedSharding(
                                  self.mesh, jax.sharding.PartitionSpec())}}
            restored, extra = checkpoint.restore(self.ckpt_dir, tree,
                                                 shardings=sh)
            step = int(extra.get("next_step", 0))
            print(f"[train] resumed from checkpoint at step {step}")
            return restored["params"], restored["opt"], step
        return self.init_state(seed)

    def save_ckpt(self, params, opt, next_step):
        if not self.ckpt_dir:
            return
        checkpoint.save(self.ckpt_dir, next_step,
                        {"params": params, "opt": opt},
                        extra={"next_step": next_step,
                               "arch": self.cfg.name})
        checkpoint.prune_old(self.ckpt_dir, keep=self.keep)

    # ---- loop -------------------------------------------------------------
    def run(self, seed=0) -> list:
        params, opt, start = self.restore_or_init(seed)

        def on_sigterm(_sig, _frm):
            self._preempted = True
        old = signal.signal(signal.SIGTERM, on_sigterm)

        ctx = activation_sharding(self.mesh) if self.mesh is not None else None
        try:
            if ctx:
                ctx.__enter__()
            for step in range(start, self.steps):
                self.watchdog.start_step(step)
                batch = {k: jnp.asarray(v)
                         for k, v in self.data.batch_at(step).items()}
                params, opt, metrics = self.jit_step(params, opt, batch)
                loss = float(metrics["loss"])
                st = self.watchdog.end_step()
                self.metrics_log.append(
                    {"step": step, "loss": loss,
                     "grad_norm": float(metrics["grad_norm"]),
                     "sec": st.duration_s, "straggler": st.flagged})
                if step % self.log_every == 0 or step == self.steps - 1:
                    print(f"[train] step {step:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"({st.duration_s*1e3:.0f} ms)")
                next_step = step + 1
                if self.ckpt_every and next_step % self.ckpt_every == 0:
                    self.save_ckpt(params, opt, next_step)
                if self._preempted:
                    print("[train] SIGTERM: checkpointing and exiting")
                    self.save_ckpt(params, opt, next_step)
                    sys.exit(0)
                if self.fail_at_step is not None and next_step == self.fail_at_step:
                    print(f"[train] injected failure at step {next_step}",
                          flush=True)
                    os._exit(42)   # simulate a hard node crash
            self.save_ckpt(params, opt, self.steps)
        finally:
            if ctx:
                ctx.__exit__(None, None, None)
            signal.signal(signal.SIGTERM, old)
        return self.metrics_log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config for CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--mesh", default=None, help="DxM over available devices")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    tr = Trainer(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                 ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                 mesh=mesh, fail_at_step=args.fail_at_step,
                 settings=StepSettings(accum=args.accum, remat="dots"))
    log = tr.run(args.seed)
    losses = [m["loss"] for m in log]
    if losses:
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"({len(losses)} steps)")


if __name__ == "__main__":
    main()
