"""Fig 5 analogue: Allreduce algorithm comparison (ring / RSAG / recursive
doubling / XLA builtin) — traced signatures + modeled v5e times at 256 chips.

The paper contrasts Open MPI vs MPICH algorithm choices through their
communication graphs; here each algorithm is built explicitly from
shard_map+ppermute so the tracer shows its distinct wire pattern.
"""
from __future__ import annotations

import json

from _util import run_worker

WORKER = """
import json
import time
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import MeshSpec, trace_from_hlo
from repro.core.costmodel import allreduce_time
from repro.core.topology import V5E
from repro.distributed.algorithms import ALGORITHMS, allreduce_fn

mesh = jax.make_mesh((8,), ("data",))
spec = MeshSpec((8,), ("data",))
NB = 1 << 22          # 4 MiB payload
x = jnp.ones((8, NB // 4 // 8), jnp.float32)
xd = jax.device_put(x, NamedSharding(mesh, P("data")))

rows = []
for name in ALGORITHMS:
    fn = jax.jit(allreduce_fn(name, mesh))
    compiled = fn.lower(xd).compile()
    for _ in range(2):
        out = fn(xd)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(5):
        out = fn(xd)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / 5 * 1e6
    tr = trace_from_hlo(compiled.as_text(), spec, label=name)
    wire = tr.total_wire_bytes()
    n_ev = sum(e.multiplicity for e in tr.events)
    kinds = sorted({e.kind for e in tr.events})
    model_us = tr.total_est_time_s() * 1e6
    rows.append((f"allreduce/{name}/4MiB", us,
                 f"events={n_ev}|kinds={'+'.join(kinds)}|"
                 f"wireMB={wire/1e6:.1f}|v5e={model_us:.1f}us"))

# closed-form comparison at production scale (256 chips, 100 MB gradient)
for name in ("ring", "reduce_scatter_allgather", "recursive_doubling"):
    t = allreduce_time(name, 100e6, 256, V5E.ici_bw, V5E.ici_latency_s)
    rows.append((f"allreduce/model256/{name}/100MB", t * 1e6,
                 "closed-form v5e, 256-chip group"))
print("JSON" + json.dumps(rows))
"""


def run():
    out = run_worker(WORKER, devices=8)
    for line in out.splitlines():
        if line.startswith("JSON"):
            return [tuple(r) for r in json.loads(line[4:])]
    raise RuntimeError("no JSON output from worker")
