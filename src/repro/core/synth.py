"""Synthetic trace generation — controlled workloads for sessions/benches.

The paper's comparison experiments need *many* traces from *different*
configurations.  On hardwareless CI we synthesize them: random-but-seeded
collective mixes laid out on a real `MeshSpec`, run through the real cost
model and attribution pipeline, so every derived field (link class, wire
bytes, protocol regime, semantic class) is produced by the same code paths
a compiled-HLO trace exercises.
"""
from __future__ import annotations

import os
import re
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import attribution, costmodel
from repro.core.events import CollectiveEvent, Trace
from repro.core.topology import Hardware, MeshSpec, V5E

# (kind, scope path, relative weight) — a train-step-shaped mix
_SITE_MIX: Tuple[Tuple[str, str, float], ...] = (
    ("all-reduce", "layer/mlp", 3.0),
    ("all-reduce", "opt_update", 2.0),
    ("all-gather", "layer/attn", 2.0),
    ("reduce-scatter", "opt_update", 1.5),
    ("all-to-all", "layer/moe/dispatch", 1.0),
    ("all-gather", "embed", 0.5),
    ("all-reduce", "loss", 0.5),
)

_BYTE_CHOICES = np.array([1 << 10, 1 << 14, 1 << 18, 1 << 21,
                          1 << 24, 1 << 26], dtype=np.int64)
_MULT_CHOICES = np.array([1, 1, 1, 4, 12], dtype=np.int64)


def _axis_groups(mesh: MeshSpec, axis_idx: int):
    """All replica groups spanning exactly mesh axis `axis_idx`."""
    ids = np.arange(mesh.num_devices).reshape(mesh.shape)
    ids = np.moveaxis(ids, axis_idx, -1).reshape(-1, mesh.shape[axis_idx])
    return [list(map(int, row)) for row in ids]


def synthetic_trace(label: str, mesh: MeshSpec, hw: Hardware = V5E,
                    n_sites: int = 1000, seed: int = 0,
                    backward_fraction: float = 0.4,
                    axis_weights: Optional[Sequence[float]] = None) -> Trace:
    """Build an annotated `Trace` of `n_sites` synthetic collective sites.

    `axis_weights` biases which mesh axis each collective spans (defaults
    to uniform) — e.g. weight the `data` axis to mimic a DP-heavy run.
    """
    rng = np.random.default_rng(seed)
    kinds = np.array([m[0] for m in _SITE_MIX])
    scopes = np.array([m[1] for m in _SITE_MIX])
    weights = np.array([m[2] for m in _SITE_MIX])
    mix = rng.choice(len(_SITE_MIX), size=n_sites, p=weights / weights.sum())
    axes_p = None
    if axis_weights is not None:
        axes_p = np.asarray(axis_weights, dtype=float)
        axes_p = axes_p / axes_p.sum()
    axis_pick = rng.choice(len(mesh.axes), size=n_sites, p=axes_p)
    nbytes = rng.choice(_BYTE_CHOICES, size=n_sites)
    mults = rng.choice(_MULT_CHOICES, size=n_sites)
    backward = rng.random(n_sites) < backward_fraction

    groups_by_axis = [_axis_groups(mesh, i) for i in range(len(mesh.shape))]
    events = []
    for i in range(n_sites):
        kind, scope = kinds[mix[i]], scopes[mix[i]]
        groups = groups_by_axis[axis_pick[i]]
        wrap = "transpose(core_fn)/" if backward[i] else ""
        op_name = f"jit(train_step)/{wrap}{scope}/{_PRIM_FOR.get(kind, 'psum')}"
        events.append(CollectiveEvent(
            name=f"{kind}.{i}",
            kind=kind,
            async_start=bool(rng.random() < 0.25),
            operand_bytes=int(nbytes[i]),
            result_bytes=int(nbytes[i]),
            dtype="bf16",
            replica_groups=groups,
            group_size=len(groups[0]),
            num_groups=len(groups),
            op_name=op_name,
            computation="main" if not backward[i] else "scan_body",
            multiplicity=int(mults[i]),
            channel_id=i + 1))
    for ev in events:
        costmodel.annotate_event(ev, mesh, hw)
    attribution.attribute_all(events)
    return Trace(label=label, mesh_shape=mesh.shape, mesh_axes=mesh.axes,
                 num_devices=mesh.num_devices, events=events)


_PRIM_FOR = {
    "all-reduce": "psum",
    "all-gather": "all_gather",
    "reduce-scatter": "psum_scatter",
    "all-to-all": "all_to_all",
    "collective-permute": "ppermute",
}


# --------------------------------------------------------------------------
# ground-truth buggy traces — precision/recall workloads for commcheck
# --------------------------------------------------------------------------

# bug name -> the commcheck finding code it must produce
COMM_BUGS = {
    "deadlock_order": "deadlock_order",
    "group_coverage": "group_coverage",
    "channel_collision": "channel_collision",
    "shape_mismatch": "shape_mismatch",
    "degenerate_group": "degenerate_group",
    "sharding_mismatch": "group_mesh_mismatch",
}


def inject_comm_bugs(mesh: Optional[MeshSpec] = None, hw: Hardware = V5E,
                     n_sites: int = 64, seed: int = 0,
                     bugs: Sequence[str] = tuple(COMM_BUGS)):
    """A clean synthetic trace with labeled communication bugs spliced in.

    Returns `(trace, labels)` where `labels` maps each injected bug name
    to the commcheck finding code it must trigger (see `COMM_BUGS`).  The
    clean background sites come from `synthetic_trace` (unique channels,
    full-coverage axis groups), so every finding the analyzer reports is
    attributable to an injection — the ground truth for precision tests.
    """
    if mesh is None:
        mesh = MeshSpec((2, 4), ("data", "model"))
    nd = mesh.num_devices
    devs = list(range(nd))
    base = synthetic_trace("buggy", mesh, hw, n_sites=n_sites, seed=seed)
    events = list(base.events)
    ch = n_sites + 1000     # channel space disjoint from the clean sites

    def mk(name, kind, groups, channel, nbytes=1 << 22, dtype="f32"):
        return CollectiveEvent(
            name=name, kind=kind, async_start=False,
            operand_bytes=nbytes, result_bytes=nbytes, dtype=dtype,
            replica_groups=groups, group_size=len(groups[0]),
            num_groups=len(groups),
            op_name=f"jit(train_step)/bug/{name}/{_PRIM_FOR.get(kind, 'psum')}",
            computation="main", channel_id=channel)

    injected = []
    if "deadlock_order" in bugs:
        # two matched all-reduces: half the devices see an extra instance
        injected += [
            mk("bug.deadlock.a", "all-reduce", [devs[:nd // 2]], ch),
            mk("bug.deadlock.b", "all-reduce", [devs], ch),
        ]
    if "group_coverage" in bugs:
        injected.append(
            mk("bug.coverage", "all-reduce", [devs[:nd // 2]], ch + 1,
               nbytes=1 << 21))
    if "channel_collision" in bugs:
        injected += [
            mk("bug.collide.ar", "all-reduce", [devs], ch + 2,
               nbytes=1 << 20),
            mk("bug.collide.ag", "all-gather", [devs], ch + 2,
               nbytes=1 << 20),
        ]
    if "shape_mismatch" in bugs:
        injected += [
            mk("bug.shape.a", "all-reduce", [devs], ch + 3, nbytes=1 << 19),
            mk("bug.shape.b", "all-reduce", [devs], ch + 3, nbytes=1 << 18),
        ]
    if "sharding_mismatch" in bugs:
        # ragged groups: the spec carved the mesh into uneven pieces
        injected.append(
            mk("bug.ragged", "all-reduce", [devs[:3], devs[3:]], ch + 4,
               nbytes=1 << 17))
    if "degenerate_group" in bugs:
        injected.append(
            mk("bug.degenerate", "all-reduce", [[d] for d in devs], ch + 5,
               nbytes=1 << 16))

    for ev in injected:
        costmodel.annotate_event(ev, mesh, hw)
    events += injected
    attribution.attribute_all(events)
    trace = Trace(label="buggy", mesh_shape=mesh.shape, mesh_axes=mesh.axes,
                  num_devices=nd, events=events)
    return trace, {b: COMM_BUGS[b] for b in bugs}


def misconfigured_trace(n_sites: int = 400, seed: int = 3
                        ) -> Tuple[Trace, MeshSpec, str]:
    """A workload whose mesh factorization is the (planted) bug.

    Every collective spans the first axis of a `(2, 8) ("pod", "data")`
    mesh — bulk grad-sync traffic riding the slow inter-pod DCI.  The
    same device groups stay inside one ICI axis under the transposed
    factorization `(8, 2) ("data", "pod")` (device ids 0 and 8 are pod
    neighbors under the first mapping but data neighbors under the
    second), so the fix is purely a mesh reshape: no payload changes,
    ~2x modeled step time back.

    Returns `(trace, mesh, fix)` where `fix` is the scenario name
    `whatif.default_scenarios(mesh)` gives that reshape — a sweep must
    rank it first (the ground truth for tests and the docs example).
    """
    mesh = MeshSpec((2, 8), ("pod", "data"))
    trace = synthetic_trace("misconfigured", mesh, n_sites=n_sites,
                            seed=seed, axis_weights=(1.0, 0.0))
    return trace, mesh, "mesh:data,pod"


# --------------------------------------------------------------------------
# synthetic HLO text — ingest-pipeline workloads (parse -> annotate -> store)
# --------------------------------------------------------------------------

# replica-group attr repertoire for an 8-device mesh: iota forms (plain and
# transposed) and explicit lists — the duplication mirrors real unrolled
# HLO, where thousands of sites stamp the same handful of attrs.
_RG_ATTRS_8 = (
    "replica_groups=[2,4]<=[8]",
    "replica_groups=[4,2]<=[8]",
    "replica_groups=[1,8]<=[8]",
    "replica_groups=[4,2]<=[2,4]T(1,0)",
    "replica_groups=[2,4]<=[4,2]T(1,0)",
    "replica_groups={{0,1,2,3},{4,5,6,7}}",
    "replica_groups={{0,4},{1,5},{2,6},{3,7}}",
)

_STP_ATTR_8 = ("source_target_pairs={{0,1},{1,2},{2,3},{3,0},"
               "{4,5},{5,6},{6,7},{7,4}}")

_TYPES = ("bf16[256,512]", "bf16[1024,128]", "f32[128,128]", "f32[64,512]",
          "bf16[32,64]", "f32[2048,16]")

_SCOPES = ("layer/mlp", "layer/attn", "layer/moe/dispatch", "embed", "loss",
           "opt_update", "pipeline")


def synthetic_hlo(n_sites: int = 1000, seed: int = 0, trip_count: int = 12,
                  body_fraction: float = 0.25,
                  backward_fraction: float = 0.4,
                  n_computations: int = 1) -> str:
    """Generate compiled-HLO-shaped text with `n_sites` collective op sites.

    The module has the structure ingest cares about: an ENTRY computation,
    a while loop (condition constant => trip-count multiplicity for the
    `body_fraction` of sites placed in the body), async `-start`/`-done`
    pairs, permutes with explicit source/target pairs, and a mix of iota
    (plain + transposed) and explicit replica groups.  op_name metadata is
    drawn from a small vocabulary, heavily duplicated — the property the
    vocab-level attribution fast path exploits.

    `n_computations > 1` switches to the *multi-computation* shape the
    sharded-ingest path is built for (one giant module, many
    computations): the non-loop sites are spread over that many `%stage<k>`
    computations reached from the entry via `call(...) to_apply=` — the
    per-computation units `hlo_parser.split_hlo_module` partitions across
    workers.  `n_computations=1` keeps the classic single-entry layout.
    """
    rng = np.random.default_rng(seed)
    kinds = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
    kind_pick = rng.choice(len(kinds), size=n_sites,
                           p=(0.35, 0.2, 0.15, 0.15, 0.15))
    rg_pick = rng.choice(len(_RG_ATTRS_8), size=n_sites)
    ty_pick = rng.choice(len(_TYPES), size=n_sites)
    sc_pick = rng.choice(len(_SCOPES), size=n_sites)
    bwd = rng.random(n_sites) < backward_fraction
    is_async = rng.random(n_sites) < 0.2
    in_body = rng.random(n_sites) < body_fraction

    # op_name vocabulary: scope x fwd/bwd x primitive (small, duplicated)
    op_names = {}
    for si, scope in enumerate(_SCOPES):
        for b in (False, True):
            for kind in kinds:
                wrap = "transpose(core_fn)/" if b else ""
                op_names[(si, b, kind)] = (
                    f"jit(train_step)/{wrap}{scope}/"
                    f"{_PRIM_FOR.get(kind, 'psum')}")

    def site_lines(i: int) -> list:
        kind = kinds[kind_pick[i]]
        ty = _TYPES[ty_pick[i]]
        op_name = op_names[(int(sc_pick[i]), bool(bwd[i]), kind)]
        md = f'metadata={{op_name="{op_name}"}}'
        ch = f"channel_id={i + 1}"
        nm = f"%{kind}.{i}"
        if kind == "collective-permute":
            return [f"  {nm} = {ty} collective-permute(%x), {ch}, "
                    f"{_STP_ATTR_8}, {md}"]
        rg = _RG_ATTRS_8[rg_pick[i]]
        extra = ", use_global_device_ids=true, to_apply=%add" \
            if kind in ("all-reduce", "reduce-scatter") else ", dimensions={0}"
        if kind == "all-reduce" and is_async[i]:
            # async pair: tuple-typed -start plus its -done marker
            return [
                f"  {nm} = ({ty}, {ty}) all-reduce-start(%x), {ch}, "
                f"{rg}{extra}, {md}",
                f"  %done.{i} = {ty} all-reduce-done({nm}), {md}",
            ]
        return [f"  {nm} = {ty} {kind}(%x), {ch}, {rg}{extra}, {md}"]

    body_sites, entry_sites = [], []
    for i in range(n_sites):
        (body_sites if in_body[i] else entry_sites).append(i)

    lines = [
        "HloModule synth_ingest",
        "",
        "%add (a: f32[], b: f32[]) -> f32[] {",
        "  %a = f32[] parameter(0)",
        "  %b = f32[] parameter(1)",
        "  ROOT %r = f32[] add(%a, %b)",
        "}",
        "",
        "%cond (p: (s32[], bf16[256,512])) -> pred[] {",
        "  %p = (s32[], bf16[256,512]) parameter(0)",
        "  %i = s32[] get-tuple-element(%p), index=0",
        f"  %n = s32[] constant({trip_count})",
        "  ROOT %lt = pred[] compare(%i, %n), direction=LT",
        "}",
        "",
        "%body (p: (s32[], bf16[256,512])) -> (s32[], bf16[256,512]) {",
        "  %p = (s32[], bf16[256,512]) parameter(0)",
        "  %i = s32[] get-tuple-element(%p), index=0",
        "  %x = bf16[256,512] get-tuple-element(%p), index=1",
        "  %one = s32[] constant(1)",
        "  %i2 = s32[] add(%i, %one)",
    ]
    for i in body_sites:
        lines.extend(site_lines(i))
    lines += [
        "  ROOT %t = (s32[], bf16[256,512]) tuple(%i2, %x)",
        "}",
        "",
    ]

    n_stages = max(int(n_computations) - 1, 0)
    stage_sites: list = []
    if n_stages and entry_sites:
        n_stages = min(n_stages, len(entry_sites))
        step = (len(entry_sites) + n_stages - 1) // n_stages
        stage_sites = [entry_sites[j:j + step]
                       for j in range(0, len(entry_sites), step)]
        entry_sites = []
        for k, sites in enumerate(stage_sites):
            lines.append(f"%stage{k} (p{k}: bf16[256,512]) -> "
                         "bf16[256,512] {")
            lines.append("  %x = bf16[256,512] parameter(0)")
            for i in sites:
                lines.extend(site_lines(i))
            lines.append(f"  ROOT %r{k} = bf16[256,512] copy(%x)")
            lines.append("}")
            lines.append("")

    lines += [
        "ENTRY %main (x: bf16[256,512]) -> bf16[256,512] {",
        "  %x = bf16[256,512] parameter(0)",
        "  %zero = s32[] constant(0)",
        "  %init = (s32[], bf16[256,512]) tuple(%zero, %x)",
        "  %w = (s32[], bf16[256,512]) while(%init), condition=%cond, "
        "body=%body",
    ]
    for k in range(len(stage_sites)):
        lines.append(f"  %call{k} = bf16[256,512] call(%x), "
                     f"to_apply=%stage{k}")
    for i in entry_sites:
        lines.extend(site_lines(i))
    lines += [
        "  ROOT %out = bf16[256,512] get-tuple-element(%w), index=1",
        "}",
        "",
    ]
    return "\n".join(lines)


# --------------------------------------------------------------------------
# chaos fault injectors — corrupt dumps for the fault-tolerance test matrix
# --------------------------------------------------------------------------

# every injector `corrupt_hlo` supports; the chaos suite and CI smoke job
# iterate this matrix, so a new failure mode added here is exercised
# everywhere automatically
CORRUPT_MODES = ("truncate", "splice", "dup_lines", "drop_lines",
                 "mangle_rg", "binary")

_GARBAGE = ("@@@ CORRUPT <<<%%%>>> \x01\x02 not-an-hlo-line ((((\n"
            "ENTRY %mid (x: f -> TRUNCATED HEADER\n")


def corrupt_hlo(text: str, mode: str, seed: int = 0,
                at: Optional[int] = None):
    """Damage an HLO module the way real fleet ingest sees damage.

    Modes (see `CORRUPT_MODES`):
      * `truncate`   — cut the text at byte `at` (default: a seeded
        offset), the half-written/filesystem-truncated dump;
      * `splice`     — insert a block of garbage text mid-module, the
        interleaved-writer / corrupted-block case;
      * `dup_lines`  — duplicate a random ~10% of lines (a retrying
        writer appending twice);
      * `drop_lines` — delete a random ~10% of lines (lost writes);
      * `mangle_rg`  — corrupt a `replica_groups={{...}}` attr so the
        parser raises mid-computation (content-level corruption that
        salvage must isolate to one computation);
      * `binary`     — splice invalid UTF-8 bytes and return `bytes`
        (a non-text file in the dump dir; even salvage cannot read it,
        so it must be quarantined, not crash the ingest).

    Returns the damaged module as `str` (or `bytes` for `binary`).
    Deterministic in `(text, mode, seed, at)`.
    """
    rng = np.random.default_rng(seed)
    if mode == "truncate":
        k = int(at) if at is not None \
            else int(rng.integers(1, max(len(text), 2)))
        return text[:k]
    if mode == "splice":
        k = int(at) if at is not None \
            else int(rng.integers(0, max(len(text), 1)))
        return text[:k] + _GARBAGE + text[k:]
    if mode in ("dup_lines", "drop_lines"):
        lines = text.splitlines(keepends=True)
        pick = rng.random(len(lines)) < 0.1
        out = []
        for keep, line in zip(pick, lines):
            if mode == "dup_lines":
                out.append(line)
                if keep:
                    out.append(line)
            elif not keep:
                out.append(line)
        return "".join(out)
    if mode == "mangle_rg":
        m = re.search(r"replica_groups=\{\{(\d+)", text)
        if m is None:
            raise ValueError("module has no explicit replica_groups attr "
                             "to mangle")
        return text[:m.end(1)] + "x" + text[m.end(1):]
    if mode == "binary":
        k = int(at) if at is not None \
            else int(rng.integers(0, max(len(text), 1)))
        return text[:k].encode() + b"\xff\xfe\x00\xc3\x28garbage\xff" \
            + text[k:].encode()
    raise ValueError(f"unknown corruption mode {mode!r} "
                     f"(have {CORRUPT_MODES})")


def write_corrupt_dump(root: str, modes: Sequence[str] = CORRUPT_MODES,
                       sites_per_file: int = 120, seed: int = 0,
                       prefix: str = "corrupt") -> List[str]:
    """Materialize one damaged module per injector mode under `root`.

    The chaos-suite counterpart of `write_hlo_dump`: each file is a
    `synthetic_hlo` module run through one `corrupt_hlo` mode, named
    `{prefix}_{mode}.txt`.  Returns the paths written.
    """
    from repro.core.persist import atomic_open
    os.makedirs(root, exist_ok=True)
    paths = []
    for i, mode in enumerate(modes):
        text = synthetic_hlo(n_sites=sites_per_file, seed=seed + i)
        damaged = corrupt_hlo(text, mode, seed=seed + i)
        path = os.path.join(root, f"{prefix}_{mode}.txt")
        bmode = "wb" if isinstance(damaged, bytes) else "w"
        with atomic_open(path, bmode) as f:
            f.write(damaged)
        paths.append(path)
    return paths


def write_hlo_dump(root: str, n_files: int = 3, sites_per_file: int = 200,
                   seed: int = 0, prefix: str = "module", start: int = 0,
                   n_computations: int = 1) -> List[str]:
    """Materialize a compiler-dump-shaped directory of synthetic modules.

    Writes `n_files` `synthetic_hlo` modules (seeds `seed+start ..`) as
    `{prefix}_{i:04d}.txt` under `root` — the input shape the watch
    daemon tails.  `start` offsets both the numbering and the seed, so a
    second call extends an existing dump with *new* distinct modules
    (the grows-mid-run scenario).  Each file lands via an atomic
    replace, so a concurrently-polling watcher never sees a partial
    module.  Returns the paths written, in order.
    """
    from repro.core.persist import atomic_open
    os.makedirs(root, exist_ok=True)
    paths = []
    for i in range(start, start + n_files):
        text = synthetic_hlo(n_sites=sites_per_file, seed=seed + i,
                             n_computations=n_computations)
        path = os.path.join(root, f"{prefix}_{i:04d}.txt")
        with atomic_open(path, "w") as f:
            f.write(text)
        paths.append(path)
    return paths


def write_fleet_dump(root: str, n_hosts: int = 4, steps: int = 1,
                     sites_per_file: int = 120, seed: int = 0) -> List[str]:
    """Materialize a fleet-shaped dump: one module per host x step.

    Files follow the warehouse naming convention the query layer parses
    (`session.label_meta`): `host{h:03d}_step{s:03d}.txt`, each a
    distinct-seed `synthetic_hlo` module written atomically.  This is
    the input shape of the CI warehouse gate — synthesize N hosts,
    tree-merge, query/diff a slice.  Returns the paths written, hosts
    outer, steps inner.
    """
    from repro.core.persist import atomic_open
    os.makedirs(root, exist_ok=True)
    paths = []
    for h in range(n_hosts):
        for s in range(steps):
            text = synthetic_hlo(n_sites=sites_per_file,
                                 seed=seed + h * steps + s)
            path = os.path.join(root, f"host{h:03d}_step{s:03d}.txt")
            with atomic_open(path, "w") as f:
                f.write(text)
            paths.append(path)
    return paths
