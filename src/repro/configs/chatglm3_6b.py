"""chatglm3-6b — dense GQA decoder with 2d (partial) RoPE. [arXiv:2406.12793]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rope="partial",
    rope_fraction=0.5,         # 2d rope: rotate half of head_dim
    notes="pure full attention => long_500k skipped per assignment",
)
