"""Deterministic synthetic token pipeline.

Production-shaped: an infinite, seekable, shardable stream.  Each (step,
host) pair derives its batch purely from the seed — restart at step N
reproduces the exact batch (bitwise), which the fault-tolerance tests rely
on.  A real deployment swaps `SyntheticTokens` for a file-backed source
with identical iterator semantics.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.api import n_image_patches


@dataclass(frozen=True)
class DataConfig:
    batch_size: int
    seq_len: int
    seed: int = 1234
    # markov-ish synthetic text: token t+1 depends on token t (so a model
    # can actually reduce loss, giving the integration tests signal)
    structure: float = 0.8


class SyntheticTokens:
    """Seekable deterministic LM token stream."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.data.seed, step]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg, d = self.cfg, self.data
        rng = self._rng(step)
        B, S = d.batch_size, d.seq_len
        V = cfg.vocab_size
        # structured stream: x_{t+1} = (a * x_t + noise) % V_eff
        v_eff = min(V, 4096)
        start = rng.integers(0, v_eff, (B, 1))
        toks = [start]
        for _ in range(S - 1):
            prev = toks[-1]
            nxt = (prev * 31 + 7) % v_eff
            mask = rng.random((B, 1)) < d.structure
            rand = rng.integers(0, v_eff, (B, 1))
            toks.append(np.where(mask, nxt, rand))
        tokens = np.concatenate(toks, axis=1).astype(np.int32)

        batch: Dict[str, np.ndarray] = {"tokens": tokens}
        if cfg.family == "encdec":
            batch["frame_embeds"] = rng.standard_normal(
                (B, cfg.source_len, cfg.d_model), dtype=np.float32)
        if cfg.family == "vlm":
            n_img = n_image_patches(cfg, S)
            batch["tokens"] = tokens[:, : S - n_img]
            batch["patch_embeds"] = rng.standard_normal(
                (B, n_img, cfg.d_model), dtype=np.float32)
            pos = np.broadcast_to(np.arange(S, dtype=np.int32), (3, B, S))
            batch["positions"] = np.ascontiguousarray(pos)
        return batch

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1


def shard_batch(batch: Dict[str, np.ndarray], shardings) -> Dict[str, jax.Array]:
    """Device-put a host batch with the step's input shardings."""
    if shardings is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}
