"""Decoder-LM assembly for all families: dense / moe / ssm / hybrid / vlm.

Layers run under `lax.scan` over stacked parameters (fast compiles, uniform
remat); per-layer attention windows ride along as scan inputs (gemma3's 5:1
local:global pattern, hymba's 3 global layers).  Decode is an unrolled
python loop so per-layer caches may have heterogeneous lengths (windowed
retention at 500k context).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.autoshard import constrain_residual
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.meta import ParamMeta, tree_map_meta


# --------------------------------------------------------------------------
# parameter trees
# --------------------------------------------------------------------------

def block_meta(cfg) -> Dict[str, Any]:
    fam = cfg.family
    if fam == "ssm":
        return {"norm1": L.norm_meta(cfg), "ssm": ssm_mod.ssm_meta(cfg)}
    m: Dict[str, Any] = {"norm1": L.norm_meta(cfg),
                         "attn": attn_mod.attention_meta(cfg),
                         "norm2": L.norm_meta(cfg)}
    if fam == "moe":
        m["moe"] = moe_mod.moe_meta(cfg)
    else:
        m["mlp"] = L.mlp_meta(cfg)
    if fam == "hybrid":
        m["ssm"] = ssm_mod.ssm_meta(cfg)
    if cfg.sandwich_norm:
        m["post_norm1"] = L.norm_meta(cfg)
        m["post_norm2"] = L.norm_meta(cfg)
    return m


def stack_meta(tree, n: int):
    """Prepend a stacked `layers` dim to every leaf."""
    return tree_map_meta(
        lambda _p, m: ParamMeta((n,) + m.shape, ("layers",) + m.logical,
                                init=m.init, scale=m.scale, dtype=m.dtype),
        tree)


def model_meta(cfg) -> Dict[str, Any]:
    m = {"embed": L.embed_meta(cfg),
         "layers": stack_meta(block_meta(cfg), cfg.num_layers),
         "final_norm": L.norm_meta(cfg)}
    return m


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def apply_block(cfg, p, x, positions, window, *, attn_impl="auto",
                collect_cache=False):
    """One layer. Returns (x, aux, cache_entry_or_None)."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    cache: Dict[str, jax.Array] = {}

    if fam == "ssm":
        h = L.apply_norm(cfg, p["norm1"], x)
        if collect_cache:
            y, st = _ssm_with_state(cfg, p["ssm"], h)
            cache.update(st)
        else:
            y = ssm_mod.apply_ssm(cfg, p["ssm"], h)
        return x + y, aux, cache or None

    h = L.apply_norm(cfg, p["norm1"], x)
    q, k, v = attn_mod.project_qkv(cfg, p["attn"], h, h, positions, positions)
    with jax.named_scope("attn"):
        out = attn_mod.attend(cfg, q, k, v, causal=True, window=window,
                              impl=attn_impl)
        attn_out = jnp.einsum("bsz,zd->bsd", out.reshape(*out.shape[:2], -1),
                              p["attn"]["wo"].astype(x.dtype))
    if collect_cache:
        cache["k"], cache["v"] = k, v

    if fam == "hybrid":
        if collect_cache:
            ssm_out, st = _ssm_with_state(cfg, p["ssm"], h)
            cache.update(st)
        else:
            ssm_out = ssm_mod.apply_ssm(cfg, p["ssm"], h)
        attn_out = 0.5 * (attn_out + ssm_out)   # parallel heads, mean-fused

    if cfg.sandwich_norm:
        attn_out = L.apply_norm(cfg, p["post_norm1"], attn_out)
    x = x + attn_out

    h2 = L.apply_norm(cfg, p["norm2"], x)
    if fam == "moe":
        ff, aux = moe_mod.apply_moe(cfg, p["moe"], h2)
    else:
        ff = L.apply_mlp(cfg, p["mlp"], h2)
    if cfg.sandwich_norm:
        ff = L.apply_norm(cfg, p["post_norm2"], ff)
    return x + ff, aux, cache or None


def _ssm_with_state(cfg, p, h):
    """Full-seq SSM that also returns the terminal (conv, ssm) state."""
    y = ssm_mod.apply_ssm(cfg, p, h)
    # terminal states, recomputed cheaply:
    dt = h.dtype
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(dt))
    x_in, _ = jnp.split(xz, 2, axis=-1)
    conv_state = x_in[:, -(cfg.d_conv - 1):, :].astype(jnp.float32)
    # ssm terminal state via a cheap rerun of the chunked scan
    xc = jax.nn.silu(ssm_mod._conv1d_causal(cfg, p, x_in))
    a_bar, bx, _c = ssm_mod._ssm_inputs(cfg, p, xc, cfg.d_model)
    def step(hc, t):
        a_t, b_t = t
        return a_t * hc + b_t, None
    B = h.shape[0]
    di = cfg.expand * cfg.d_model
    h0 = jnp.zeros((B, di, cfg.ssm_state), jnp.float32)
    h_last, _ = jax.lax.scan(step, h0, (a_bar.transpose(1, 0, 2, 3),
                                        bx.transpose(1, 0, 2, 3)))
    return y, {"conv": conv_state, "ssm": h_last}


# --------------------------------------------------------------------------
# full forward (train / prefill)
# --------------------------------------------------------------------------

REMAT_POLICIES = {
    "none": None,
    "dots": "dots_with_no_batch_dims_saveable",
    "full": "nothing_saveable",
}


def _maybe_remat(fn, policy: str):
    if policy == "none":
        return fn
    pol = getattr(jax.checkpoint_policies, REMAT_POLICIES[policy])
    return jax.checkpoint(fn, policy=pol)


def apply_layers(cfg, stacked, x, positions, *, attn_impl="auto",
                 remat="none", collect_cache=False):
    """Scan over stacked layer params. Returns (x, aux_sum, stacked_cache)."""
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    def body(carry, layer_in):
        xc, aux = carry
        p, w = layer_in
        with jax.named_scope("layer"):
            xc = constrain_residual(xc)
            xn, a, cache = apply_block(cfg, p, xc, positions, w,
                                       attn_impl=attn_impl,
                                       collect_cache=collect_cache)
            xn = constrain_residual(xn)
        return (xn, aux + a), cache

    body = _maybe_remat(body, remat)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    (stacked, windows))
    return x, aux, caches


def forward_hidden(cfg, params, batch, *, attn_impl="auto", remat="none",
                   embed_impl="gather"):
    """Forward to final-norm hidden states [B,S,D]. Returns (hidden, aux)."""
    x, positions = embed_inputs(cfg, params, batch, embed_impl=embed_impl)
    x, aux, _ = apply_layers(cfg, params["layers"], x, positions,
                             attn_impl=attn_impl, remat=remat)
    return L.apply_norm(cfg, params["final_norm"], x), aux


def forward(cfg, params, batch, *, attn_impl="auto", remat="none"):
    """Full forward to logits. batch is a dict (family-specific).

    Returns (logits [B,S,V], aux_loss).
    """
    x, aux = forward_hidden(cfg, params, batch, attn_impl=attn_impl,
                            remat=remat)
    logits = L.logits_head(cfg, params["embed"], x)
    return logits, aux


def embed_inputs(cfg, params, batch, embed_impl="gather"):
    """Family-specific input embedding. Returns (x [B,S,D], positions)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(jnp.dtype(cfg.compute_dtype))
        tok_x = L.embed_tokens(cfg, params["embed"], tokens, impl=embed_impl)
        with jax.named_scope("vision_stub"):
            x = jnp.concatenate([patches, tok_x], axis=1)
        positions = batch["positions"]          # [3, B, S] m-rope ids
        return x, positions
    S = tokens.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(cfg, params["embed"], tokens, positions=positions,
                       impl=embed_impl)
    return x, positions


# --------------------------------------------------------------------------
# decode (unrolled layers; heterogeneous per-layer caches)
# --------------------------------------------------------------------------

def layer_params(stacked, i: int):
    return jax.tree.map(lambda a: a[i], stacked)


def uniform_cache(cfg, windowed: bool) -> bool:
    """True when all layers share one KV length (stacked+scanned decode)."""
    if cfg.family == "ssm":
        return True
    if not windowed:
        return True
    ws = set(cfg.layer_windows())
    return len(ws) == 1


def init_cache(cfg, batch_size: int, seq_len: int, *, windowed: bool,
               dtype=jnp.bfloat16):
    """Decode cache: stacked dict {k: [L,B,Sc,K,Dh], ...} when all layers
    share a KV length (scanned decode, single-layer buffer liveness), else
    a per-layer list (heterogeneous windowed retention at 500k ctx)."""
    windows = cfg.layer_windows()
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    Ln = cfg.num_layers
    if uniform_cache(cfg, windowed):
        entry: Dict[str, jax.Array] = {}
        if cfg.family != "ssm":
            w = windows[0]
            sc = min(seq_len, w) if (windowed and w > 0) else seq_len
            entry["k"] = jnp.zeros((Ln, batch_size, sc, K, Dh), dtype)
            entry["v"] = jnp.zeros((Ln, batch_size, sc, K, Dh), dtype)
        if cfg.family in ("ssm", "hybrid"):
            st = ssm_mod.init_ssm_state(cfg, batch_size)
            entry["conv"] = jnp.broadcast_to(st["conv"][None],
                                             (Ln,) + st["conv"].shape).copy()
            entry["ssm"] = jnp.broadcast_to(st["ssm"][None],
                                            (Ln,) + st["ssm"].shape).copy()
        return entry
    caches = []
    for li in range(Ln):
        entry = {}
        if cfg.family != "ssm":
            w = windows[li]
            sc = min(seq_len, w) if (windowed and w > 0) else seq_len
            entry["k"] = jnp.zeros((batch_size, sc, K, Dh), dtype)
            entry["v"] = jnp.zeros((batch_size, sc, K, Dh), dtype)
        if cfg.family in ("ssm", "hybrid"):
            st = ssm_mod.init_ssm_state(cfg, batch_size)
            entry["conv"], entry["ssm"] = st["conv"], st["ssm"]
        caches.append(entry)
    return caches


def decode_step(cfg, params, cache, tokens, pos, *, positions=None):
    """One decode step. tokens [B,1] -> (logits [B,1,V], new_cache).

    `cache` is either a stacked dict (scanned layers — one layer's buffers
    live at a time, fast compiles) or a per-layer list (unrolled —
    heterogeneous cache lengths).  `pos` scalar int32; `positions`
    overrides rope ids (m-rope [3,B,1]).
    """
    B = tokens.shape[0]
    if positions is None:
        positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.rope == "learned":
        x = L.embed_tokens(cfg, params["embed"], tokens,
                           positions=positions + cfg.source_len)
    else:
        x = L.embed_tokens(cfg, params["embed"], tokens)

    if isinstance(cache, dict):
        x, new_cache = _decode_scan(cfg, params, cache, x, pos, positions)
    else:
        windows = cfg.layer_windows()
        new_cache = []
        for li in range(cfg.num_layers):
            p = layer_params(params["layers"], li)
            entry = dict(cache[li])
            with jax.named_scope(f"layer_{li}"):
                x = constrain_residual(x)
                x, entry = _decode_block(cfg, p, x, entry, pos, windows[li],
                                         positions)
            new_cache.append(entry)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.logits_head(cfg, params["embed"], x)
    return logits, new_cache


def _decode_scan(cfg, params, cache, x, pos, positions):
    """Scanned decode over stacked per-layer cache (uniform KV length)."""
    windows_static = cfg.layer_windows()
    windows = jnp.asarray(windows_static, jnp.int32)
    sc = cache["k"].shape[2] if "k" in cache else 0
    # static: cache allocated at exactly the (uniform) window size
    windowed = (cfg.family != "ssm" and len(set(windows_static)) == 1
                and windows_static[0] > 0 and sc == windows_static[0])

    def body(carry, layer_in):
        xc = carry
        p, entry, w = layer_in
        with jax.named_scope("layer"):
            xc = constrain_residual(xc)
            xn, entry = _decode_block(cfg, p, xc, dict(entry), pos, w,
                                      positions, windowed_static=windowed)
        return xn, entry

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, windows))
    return x, new_cache


def _decode_block(cfg, p, x, entry, pos, window, positions,
                  windowed_static=None):
    fam = cfg.family
    if fam == "ssm":
        h = L.apply_norm(cfg, p["norm1"], x)
        y, st = ssm_mod.decode_ssm(cfg, p["ssm"], h,
                                   {"conv": entry["conv"], "ssm": entry["ssm"]})
        entry.update(st)
        return x + y, entry

    h = L.apply_norm(cfg, p["norm1"], x)
    # windowed retention: the cache was allocated at exactly the window size
    if windowed_static is None:
        windowed_static = window > 0 and entry["k"].shape[1] == window
    attn_out, entry["k"], entry["v"] = attn_mod.decode_attention(
        cfg, p["attn"], h, entry["k"], entry["v"], pos,
        window=window, windowed_cache=windowed_static, positions=positions)
    if fam == "hybrid":
        y, st = ssm_mod.decode_ssm(cfg, p["ssm"], h,
                                   {"conv": entry["conv"], "ssm": entry["ssm"]})
        entry.update(st)
        attn_out = 0.5 * (attn_out + y)
    if cfg.sandwich_norm:
        attn_out = L.apply_norm(cfg, p["post_norm1"], attn_out)
    x = x + attn_out
    h2 = L.apply_norm(cfg, p["norm2"], x)
    if fam == "moe":
        ff, _ = moe_mod.apply_moe(cfg, p["moe"], h2)
    else:
        ff = L.apply_mlp(cfg, p["mlp"], h2)
    if cfg.sandwich_norm:
        ff = L.apply_norm(cfg, p["post_norm2"], ff)
    return x + ff, entry


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def prefill(cfg, params, batch, *, attn_impl="auto", cache_len=None):
    """Process a prompt; return (logits_last [B,1,V], cache list).

    `cache_len` reserves headroom for subsequent decode steps (the KV cache
    is padded with zeros past the prompt; decode masks by position).
    """
    x, positions = embed_inputs(cfg, params, batch)
    x, _aux, caches = apply_layers(cfg, params["layers"], x, positions,
                                   attn_impl=attn_impl, collect_cache=True)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.logits_head(cfg, params["embed"], x[:, -1:])
    caches = _pad_kv(caches, cache_len)
    # stacked cache dict {k: [L,B,Sc,K,Dh], ...} — decode scans over layers
    return logits, caches


def _pad_kv(caches, cache_len):
    if cache_len is None:
        return caches
    def pad_one(name, a):
        if name in ("k", "v") and a.shape[2] < cache_len:
            padw = [(0, 0)] * a.ndim
            padw[2] = (0, cache_len - a.shape[2])
            return jnp.pad(a, padw)
        return a
    return {k: pad_one(k, v) for k, v in caches.items()}
