"""End-to-end training driver: train a small LM for a few hundred steps on
CPU with checkpointing, crash recovery and the straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py                # ~20M params
    PYTHONPATH=src python examples/train_lm.py --full         # ~100M params
    PYTHONPATH=src python examples/train_lm.py --steps 50     # quick look

Resume after interruption is automatic (same --ckpt-dir).
"""
import argparse

from repro.configs import ARCHS
from repro.launch.presets import StepSettings
from repro.launch.train import Trainer
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = ARCHS["h2o-danube-3-4b"]
    if args.full:   # ~100M-param llama-style config
        cfg = base.replace(num_layers=12, d_model=768, num_heads=12,
                           num_kv_heads=4, head_dim=64, d_ff=2048,
                           vocab_size=32000, window=0, window_pattern=())
    else:           # ~20M params: a few seconds per step on one CPU core
        cfg = base.replace(num_layers=6, d_model=384, num_heads=6,
                           num_kv_heads=2, head_dim=64, d_ff=1024,
                           vocab_size=8192, window=0, window_pattern=())

    from repro.models import api
    print(f"training {cfg.name}-derived LM: {api.param_count(cfg)/1e6:.1f}M "
          f"params, {args.steps} steps, batch {args.batch} x seq {args.seq}")
    tr = Trainer(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                 ckpt_dir=args.ckpt_dir, ckpt_every=50,
                 settings=StepSettings(accum=1, remat="dots"),
                 opt_cfg=adamw.AdamWConfig(lr=6e-4, warmup_steps=30,
                                           total_steps=args.steps))
    log = tr.run()
    losses = [m["loss"] for m in log]
    if losses:
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}; "
              f"stragglers flagged: {sum(m['straggler'] for m in log)}")


if __name__ == "__main__":
    main()
