"""Performance-bug detectors — the paper's Fig 7 (NUMA misbinding) analogue.

On an IB/GPU cluster the classic silent misconfiguration is traffic taking a
host detour because of process placement.  On a TPU mesh the analogue is
traffic taking an *axis* detour because of bad PartitionSpecs.  Each detector
inspects the assembled trace and returns human-actionable findings; where the
cost model can price the fix, the finding carries a quantified
`recommendation` ("est X ms/step saved") backed by the what-if engine
(`repro.core.whatif`) — re-pricing the implicated rows under the fixed
configuration, not a heuristic guess.

Detectors scan the columnar `TraceStore`: candidate filtering is a numpy
mask over interned code columns, and only the (few) survivors are
materialized as rows for message construction — on 100k-event traces the
scans no longer walk Python objects.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import HloOpStats, Trace
from repro.core.topology import Hardware, MeshSpec, V5E
from repro.core.whatif import axis_reprice, dci_saving, fmt_time

# severity -> rank; lower sorts first.  Shared by the dynamic detectors
# below and the static analyzer (commcheck) — one ordering, one schema.
SEVERITY_RANK: Dict[str, int] = {"critical": 0, "warn": 1, "info": 2}


@dataclass
class Finding:
    """One diagnostic, shared between the dynamic detectors and the
    static analyzer (`commcheck`).

    `detector` doubles as the stable finding code (`session lint --json`
    / `session detect --json` key consumers match on), `site` anchors the
    finding to an op / channel / spec path, and `wasted_bytes` /
    `time_at_risk_s` carry the cost-model ranking weight.
    `recommendation` states the fix with the time it is worth;
    `est_saved_s` is that figure as a number — for the dynamic detectors
    it comes from re-pricing the trace under the fix scenario
    (`core.whatif`), for the static analyzer it is the modeled time the
    broken collectives block.
    """

    detector: str
    severity: str          # info | warn | critical
    message: str
    wasted_bytes: float = 0.0
    site: str = ""
    time_at_risk_s: float = 0.0
    recommendation: str = ""
    est_saved_s: float = 0.0

    def __str__(self):
        return f"[{self.severity}] {self.detector}: {self.message}"

    def to_dict(self) -> Dict[str, object]:
        """The stable JSON schema (identical for `lint` and `detect`)."""
        return {
            "analyzer": self.detector,
            "severity": self.severity,
            "site": self.site,
            "message": self.message,
            "wasted_bytes": float(self.wasted_bytes),
            "time_at_risk_s": float(self.time_at_risk_s),
            "recommendation": self.recommendation,
            "est_saved_s": float(self.est_saved_s),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "Finding":
        """Inverse of `to_dict` (watch-daemon checkpoint restore).

        Tolerant of the pre-recommendation schema: checkpoints written
        before the what-if fields existed restore with empty defaults.
        """
        return cls(detector=d["analyzer"], severity=d["severity"],
                   message=d["message"],
                   wasted_bytes=float(d.get("wasted_bytes", 0.0)),
                   site=d.get("site", ""),
                   time_at_risk_s=float(d.get("time_at_risk_s", 0.0)),
                   recommendation=d.get("recommendation", ""),
                   est_saved_s=float(d.get("est_saved_s", 0.0)))


def rank_findings(findings: List[Finding]) -> List[Finding]:
    """Severity-major, wire-bytes-at-risk-minor ordering (stable)."""
    return sorted(findings,
                  key=lambda f: (SEVERITY_RANK.get(f.severity, 99),
                                 -f.wasted_bytes))


# -- finding constructors ----------------------------------------------------
# Shared by the batch detectors below and the streaming `DetectorState`:
# one message format, so incremental findings are string-identical to a
# batch run over the same union of rows.  The quantified `recommendation`
# comes from re-pricing the implicated rows under the fix scenario
# (`core.whatif`); batch and streaming runs feed the same per-row sums
# into these constructors.

def _f_redundant(count: int, kind: str, nbytes: int, link: str, scope: str,
                 comp: str, mult: int, time_s: float = 0.0) -> Finding:
    saved = (count - 1) / count * time_s
    return Finding(
        "redundant_collective", "warn",
        f"{count}x identical {kind} of {nbytes/1e6:.1f} MB "
        f"on {link} "
        f"(scope '{scope or '-'}', "
        f"comp '{comp}') — candidates for CSE "
        f"or re-materialization of the gathered value",
        wasted_bytes=(count - 1) * nbytes * mult, site=scope,
        recommendation=f"deduplicate: {count - 1} of {count} sites move the "
                       f"same value — est {fmt_time(saved)}/step reclaimable "
                       f"(CSE scenario)",
        est_saved_s=saved)


def _f_detour(sem: str, kind: str, nbytes: int, axes, want: str, scope: str,
              mult: int, saved_s: float = 0.0) -> Finding:
    return Finding(
        "axis_detour", "warn",
        f"{sem} {kind} "
        f"({nbytes/1e6:.1f} MB) spans "
        f"axes {axes}, expected only '{want}' — check the "
        f"PartitionSpec feeding scope '{scope or '-'}'",
        wasted_bytes=nbytes * mult, site=scope,
        recommendation=f"keep {sem} on '{want}': est {fmt_time(saved_s)}/step "
                       f"saved (payload re-priced on the expected axis)",
        est_saved_s=saved_s)


def _f_eager(n: int, lat: float, hw: Hardware) -> Finding:
    return Finding(
        "eager_flood", "info",
        f"{n} latency-bound collectives/step (< {hw.rndv_threshold/1024:.0f} KiB "
        f"payload/shard), ~{lat*1e6:.0f} us serialized latency — consider "
        f"fusing/batching small collectives or increasing scan body size",
        time_at_risk_s=lat,
        recommendation=f"fuse/batch the small collectives: up to "
                       f"{fmt_time(lat)}/step of eager-protocol time "
                       f"reclaimable (full-fusion ceiling)",
        est_saved_s=lat)


def _f_layout(op_stats: HloOpStats, hw: Hardware = V5E) -> Finding:
    saved = op_stats.transpose_bytes / hw.hbm_bw
    return Finding(
        "layout_thrash", "info",
        f"{op_stats.transpose_bytes/1e9:.2f} GB of transpose/copy traffic "
        f"({op_stats.n_transpose} ops) — review operand layouts or "
        f"einsum dimension orders adjacent to collectives",
        recommendation=f"align operand layouts to delete the transposes: "
                       f"est {fmt_time(saved)}/step of HBM traffic "
                       f"reclaimable",
        est_saved_s=saved)


def _f_cross_pod(total: float, count: int, saved_s: float = 0.0) -> Finding:
    return Finding(
        "cross_pod_bulk", "warn",
        f"{total/1e9:.2f} GB/step crosses the inter-pod DCI "
        f"({count} collectives) — hierarchical reduction "
        f"(in-pod reduce-scatter, cross-pod exchange of 1/pod_size) or "
        f"gradient compression recommended",
        recommendation=f"keep bulk traffic intra-pod: est "
                       f"{fmt_time(saved_s)}/step saved (all-ICI ceiling "
                       f"scenario)",
        est_saved_s=saved_s)


def _trace_mesh(trace: Trace) -> Optional[MeshSpec]:
    try:
        return MeshSpec(tuple(trace.mesh_shape), tuple(trace.mesh_axes))
    except (AssertionError, TypeError):
        return None     # malformed mesh metadata: skip quantification


def detect_redundant_gathers(trace: Trace) -> List[Finding]:
    """Same tensor gathered more than once per execution context.

    (ucTrace: repeated identical UCT transfers within one MPI call.)
    """
    s = trace.store
    cand = s.kind.mask_of("all-gather", "all-reduce") \
        & (s.operand_bytes > (1 << 20))
    idx = np.flatnonzero(cand)
    if len(idx) < 2:
        return []
    # composite (kind, bytes, link, scope, computation) key per candidate
    key = np.zeros(len(idx), dtype=np.int64)
    for cat in (s.kind, s.link_class, s.scope, s.computation):
        key = key * len(cat.vocab) + cat.codes[idx]
    _, uniq_bytes = np.unique(s.operand_bytes[idx], return_inverse=True)
    key = key * (uniq_bytes.max() + 1) + uniq_bytes
    uniq, inv, counts = np.unique(key, return_inverse=True, return_counts=True)
    out = []
    for g in np.flatnonzero(counts > 1):
        members = idx[inv == g]
        last = int(members[-1])
        time_s = float((s.est_time_s[members] * s.weights[members]).sum())
        out.append(_f_redundant(
            int(counts[g]), s.kind.value(last), int(s.operand_bytes[last]),
            s.link_class.value(last), s.scope.value(last),
            s.computation.value(last), int(s.multiplicity[last]), time_s))
    return out


def detect_axis_detours(trace: Trace, expected: Dict[str, str],
                        min_bytes: int = 1 << 20,
                        hw: Hardware = V5E) -> List[Finding]:
    """Collectives spanning mesh axes their semantic class should not touch.

    `expected` maps semantic class -> axis name it should stay on
    (e.g. {"grad_sync": "data", "moe_dispatch": "model"}).  A grad-sync that
    crosses `model`, or TP traffic crossing `pod`, is the sharding analogue
    of NUMA-misbound traffic routed through remote NICs.  Sub-MB payloads
    (scalar metric reductions, grad-norm psums) are exempt.
    """
    s = trace.store
    mesh = _trace_mesh(trace)
    cand = s.semantic.mask_of(*expected) \
        & (s.operand_bytes * s.multiplicity >= min_bytes)
    out = []
    for i in np.flatnonzero(cand):
        axes = s.axes[i]
        if not axes:
            continue
        want = expected[s.semantic.value(i)]
        if any(a != want for a in axes):
            mult = int(s.multiplicity[i])
            saved = axis_reprice(s, int(i), want, mesh, hw) * mult \
                if mesh is not None else 0.0
            out.append(_f_detour(
                s.semantic.value(i), s.kind.value(i),
                int(s.operand_bytes[i]), axes, want, s.scope.value(i),
                mult, saved))
    return out


def detect_eager_floods(trace: Trace, hw: Hardware = V5E,
                        min_count: int = 64) -> List[Finding]:
    """Many tiny latency-bound transfers (the eager-protocol flood).

    (ucTrace Fig 4/6: am_short floods where rendezvous would batch.)
    """
    s = trace.store
    mask = s.protocol.mask_of("eager")
    n = int(s.multiplicity[mask].sum())
    if n >= min_count:
        lat = float((s.est_time_s[mask] * s.weights[mask]).sum())
        return [_f_eager(n, lat, hw)]
    return []


def detect_layout_thrash(trace: Trace, threshold_bytes: float = 1 << 30,
                         hw: Hardware = V5E) -> List[Finding]:
    """Heavy transpose/copy traffic around sharded ops (layout mismatch)."""
    tb = trace.op_stats.transpose_bytes
    if tb > threshold_bytes:
        return [_f_layout(trace.op_stats, hw)]
    return []


def _safe_dci_saving(store, mesh: Optional[MeshSpec], hw: Hardware) -> float:
    """`whatif.dci_saving`, tolerating un-annotatable stores (chaos dumps
    with out-of-range device ids cannot be re-priced — quantify as 0)."""
    if mesh is None:
        return 0.0
    try:
        return dci_saving(store, mesh, hw)
    except (ValueError, IndexError, KeyError):
        return 0.0


def detect_cross_pod_bulk(trace: Trace, hw: Hardware = V5E) -> List[Finding]:
    """Bulk traffic on the slow inter-pod DCI that could stay intra-pod."""
    s = trace.store
    mask = s.link_class.mask_prefix(("dci", "xpod"))
    total = float((s.wire_total[mask] * s.weights[mask]).sum())
    out = []
    if total > 1 << 30:
        saved = _safe_dci_saving(s, _trace_mesh(trace), hw)
        out.append(_f_cross_pod(total, int(mask.sum()), saved))
    return out


def run_all(trace: Trace, expected_axes: Dict[str, str] | None = None,
            hw: Hardware = V5E) -> List[Finding]:
    """All detectors, ranked critical > warn > info, bytes-at-risk within."""
    findings = []
    findings += detect_redundant_gathers(trace)
    if expected_axes:
        findings += detect_axis_detours(trace, expected_axes, hw=hw)
    findings += detect_eager_floods(trace, hw)
    findings += detect_layout_thrash(trace, hw=hw)
    findings += detect_cross_pod_bulk(trace, hw)
    return rank_findings(findings)


class DetectorState:
    """Streaming `run_all`: fold ingested chunks in, render fresh findings.

    `update(trace)` absorbs one file/chunk; `findings()` then returns
    what `run_all` would report over the *union* of every chunk seen so
    far, without rescanning old rows — per-detector sufficient
    statistics (composite-key counts for redundant collectives, eager /
    cross-pod sums, merged op stats) are all that is retained, so state
    is sized by unique keys, not rows.  Messages reuse the same
    constructors as the batch detectors and are string-identical; the
    accumulated float sums group per chunk, so they are close (not
    bitwise) to a single batch pass, and equal-severity/equal-bytes ties
    may order differently under `rank_findings`' stable sort.
    """

    def __init__(self, expected_axes: Optional[Dict[str, str]] = None,
                 hw: Hardware = V5E, min_count: int = 64,
                 thrash_threshold: float = 1 << 30):
        self.expected_axes = expected_axes
        self.hw = hw
        self.min_count = min_count
        self.thrash_threshold = thrash_threshold
        # (kind, link, scope, comp, bytes) -> {count, time, mult-of-last}
        self._redundant: Dict[Tuple, Dict[str, float]] = {}
        self._detours: List[Finding] = []
        self._eager_n = 0
        self._eager_lat = 0.0
        self._op = HloOpStats()
        self._xpod_total = 0.0
        self._xpod_count = 0
        self._xpod_saved = 0.0

    def update(self, trace: Trace) -> None:
        s = trace.store
        self._update_redundant(s)
        if self.expected_axes:
            self._detours += detect_axis_detours(trace, self.expected_axes,
                                                 hw=self.hw)
        mask = s.protocol.mask_of("eager")
        self._eager_n += int(s.multiplicity[mask].sum())
        self._eager_lat += float((s.est_time_s[mask] * s.weights[mask]).sum())
        self._op = HloOpStats.merged([self._op, trace.op_stats])
        mask = s.link_class.mask_prefix(("dci", "xpod"))
        self._xpod_total += float((s.wire_total[mask] * s.weights[mask]).sum())
        self._xpod_count += int(mask.sum())
        if mask.any():
            # the all-ICI re-pricing delta is row-local, so per-chunk
            # accumulation matches a batch pass over the union
            self._xpod_saved += _safe_dci_saving(s, _trace_mesh(trace),
                                                 self.hw)

    def _update_redundant(self, s) -> None:
        # same candidate filter + composite key as the batch detector,
        # folded by *value* (codes are chunk-local) — a lone candidate
        # kept here may pair with a duplicate arriving chunks later
        cand = s.kind.mask_of("all-gather", "all-reduce") \
            & (s.operand_bytes > (1 << 20))
        idx = np.flatnonzero(cand)
        if not len(idx):
            return
        key = np.zeros(len(idx), dtype=np.int64)
        for cat in (s.kind, s.link_class, s.scope, s.computation):
            key = key * len(cat.vocab) + cat.codes[idx]
        _, uniq_bytes = np.unique(s.operand_bytes[idx], return_inverse=True)
        key = key * (uniq_bytes.max() + 1) + uniq_bytes
        _, inv, counts = np.unique(key, return_inverse=True,
                                   return_counts=True)
        for g in range(len(counts)):
            members = idx[inv == g]
            last = int(members[-1])
            vkey = (s.kind.value(last), s.link_class.value(last),
                    s.scope.value(last), s.computation.value(last),
                    int(s.operand_bytes[last]))
            rec = self._redundant.setdefault(
                vkey, {"count": 0, "time": 0.0, "mult": 1})
            rec["count"] += int(counts[g])
            rec["time"] += float(
                (s.est_time_s[members] * s.weights[members]).sum())
            rec["mult"] = int(s.multiplicity[last])

    def findings(self) -> List[Finding]:
        out = []
        for (kind, link, scope, comp, nbytes), rec in self._redundant.items():
            if rec["count"] > 1:
                out.append(_f_redundant(int(rec["count"]), kind, nbytes, link,
                                        scope, comp, int(rec["mult"]),
                                        rec["time"]))
        out += self._detours
        if self._eager_n >= self.min_count:
            out.append(_f_eager(self._eager_n, self._eager_lat, self.hw))
        if self._op.transpose_bytes > self.thrash_threshold:
            out.append(_f_layout(self._op, self.hw))
        if self._xpod_total > 1 << 30:
            out.append(_f_cross_pod(self._xpod_total, self._xpod_count,
                                    self._xpod_saved))
        return rank_findings(out)
