"""Benchmark helpers: timing + multi-device subprocess workers.

benchmarks/run.py itself stays on the real device count (1 CPU); benches
needing a device mesh spawn a subprocess with its own
--xla_force_host_platform_device_count, so nothing leaks.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_worker(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise RuntimeError(f"bench worker failed:\n{res.stderr[-3000:]}")
    return res.stdout


def time_us(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
