#!/usr/bin/env python
"""CI chaos gate: corrupt-dump matrix through batch ingest and the watch
daemon — controlled exit codes, no crashes, full provenance.

Builds a dump directory holding one clean module plus every
`synth.CORRUPT_MODES` fault injection, then drives the two fleet entry
points over it as real subprocesses:

  1. `session ingest --errors=salvage` must exit 3 (degraded, not
     fatal), write the session, and account for every input in the
     machine-readable ingest report — with the undecodable file
     quarantined and the clean file byte-identical to a solo ingest.
  2. `session watch --once --fail-on critical` must exit with a
     controlled code (1 alerts / 3 degraded), quarantine the
     undecodable file in its summary, and never crash.

Run from the repo root:  python scripts/chaos_smoke.py
"""
import json
import os
import shutil
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
sys.path.insert(0, SRC)

from repro.core.session import TraceSession            # noqa: E402
from repro.core.synth import synthetic_hlo, write_corrupt_dump  # noqa: E402
from repro.core.topology import MeshSpec               # noqa: E402
from repro.core.tracer import trace_from_hlo           # noqa: E402

WORK = os.path.join(ROOT, "results", "chaos_smoke")
MESH = MeshSpec((2, 4), ("data", "model"))
ENV = dict(os.environ, PYTHONPATH=SRC + os.pathsep
           + os.environ.get("PYTHONPATH", ""))


def run(args):
    return subprocess.run([sys.executable, "-m", "repro.core.session",
                           *args], env=ENV, capture_output=True, text=True)


def fail(msg):
    print(f"chaos_smoke: FAIL — {msg}", file=sys.stderr)
    raise SystemExit(1)


def main():
    shutil.rmtree(WORK, ignore_errors=True)
    dump = os.path.join(WORK, "dump")
    os.makedirs(dump)
    clean_text = synthetic_hlo(n_sites=200, seed=17)
    with open(os.path.join(dump, "clean.txt"), "w") as f:
        f.write(clean_text)
    write_corrupt_dump(dump, seed=9)
    files = sorted(os.path.join(dump, f) for f in os.listdir(dump))
    print(f"chaos_smoke: {len(files)} inputs "
          f"({[os.path.basename(p) for p in files]})")

    # -- batch ingest: exit 3, session written, everything accounted for
    out = os.path.join(WORK, "chaos.json")
    r = run(["ingest", out, *files, "--workers", "1", "--errors", "salvage",
             "--retries", "0", "--retry-backoff", "0", "--json"])
    if r.returncode != 3:
        fail(f"ingest --errors=salvage exited {r.returncode}, expected 3\n"
             f"{r.stdout}\n{r.stderr}")
    report = json.loads(r.stdout)
    if [rec["source"] for rec in report["records"]] != files:
        fail(f"ingest report does not cover every input: {report}")
    statuses = {os.path.basename(rec["source"]): rec["status"]
                for rec in report["records"]}
    if statuses["clean.txt"] != "ok":
        fail(f"clean module degraded: {statuses}")
    if statuses["corrupt_binary.txt"] != "quarantined":
        fail(f"undecodable module not quarantined: {statuses}")
    for rec in report["records"]:
        if rec["status"] != "ok" and not rec["error"]:
            fail(f"degraded input with no recorded reason: {rec}")
    sess = TraceSession.load(out)
    solo = trace_from_hlo(clean_text, MESH, label="clean")
    if not sess.get("clean").store.identical(solo.store):
        fail("clean module not byte-identical through the chaos ingest")
    print(f"chaos_smoke: ingest ok (exit 3, "
          f"{sum(1 for s in statuses.values() if s != 'ok')} degraded, "
          f"clean module byte-identical)")

    # -- watch daemon: controlled exit, quarantine in the summary
    summary = os.path.join(WORK, "summary.json")
    ckpt = os.path.join(WORK, "watch.npz")
    r = run(["watch", dump, "--once", "--quiet", "--settle", "0",
             "--interval", "0.01", "--retry-backoff", "0",
             "--fail-on", "critical", "--summary", summary,
             "--checkpoint", ckpt])
    if r.returncode not in (0, 1, 3):
        fail(f"watch --once exited {r.returncode} (crash?)\n{r.stderr}")
    with open(summary) as f:
        summ = json.load(f)
    quarantined = [os.path.basename(p)
                   for p in summ["ingest"]["quarantined"]]
    if "corrupt_binary.txt" not in quarantined:
        fail(f"daemon summary missing the quarantined file: {summ['ingest']}")
    recorded = {os.path.basename(rec["source"])
                for rec in summ["ingest"]["records"]}
    if recorded != {os.path.basename(p) for p in files}:
        fail(f"daemon records incomplete: {sorted(recorded)}")
    print(f"chaos_smoke: watch ok (exit {r.returncode}, "
          f"quarantined={quarantined})")

    # -- resume on the daemon's checkpoint: zero re-parses
    r = run(["watch", dump, "--once", "--quiet", "--settle", "0",
             "--interval", "0.01", "--retry-backoff", "0",
             "--summary", summary, "--checkpoint", ckpt])
    if r.returncode not in (0, 1, 3):
        fail(f"watch resume exited {r.returncode}\n{r.stderr}")
    with open(summary) as f:
        summ = json.load(f)
    if summ["ingest"]["parse_count"] != 0:
        fail(f"resumed daemon re-parsed "
             f"{summ['ingest']['parse_count']} file(s)")
    print("chaos_smoke: resume ok (0 re-parses)")
    print("chaos_smoke: PASS")


if __name__ == "__main__":
    main()
