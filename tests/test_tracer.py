"""Unit + property tests for the tracer core (the paper's contribution)."""
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import attribution, costmodel, hlo_parser, topology
from repro.core.events import CollectiveEvent, Trace
from repro.core.topology import MeshSpec, V5E


def mk_event(**kw):
    base = dict(name="ar", kind="all-reduce", async_start=False,
                operand_bytes=1 << 20, result_bytes=1 << 20, dtype="f32",
                replica_groups=[[0, 1, 2, 3]], group_size=4, num_groups=1,
                op_name="", computation="main")
    base.update(kw)
    return CollectiveEvent(**base)


# --------------------------------------------------------------------------
# hlo_parser
# --------------------------------------------------------------------------

SYNTH_HLO = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %ar = f32[8,16] all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add, metadata={op_name="jit(f)/while/body/layer/mlp/psum"}
  ROOT %t = (s32[], f32[8,16]) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %x)
  %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  %ag = f32[64,16] all-gather(%x), channel_id=2, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}, metadata={op_name="jit(f)/embed/all_gather"}
  %cp = f32[8,16] collective-permute(%x), channel_id=3, source_target_pairs={{0,1},{1,2},{2,3},{3,0}}, metadata={op_name="jit(f)/pipeline/ppermute"}
  ROOT %out = f32[8,16] get-tuple-element(%w), index=1
}
"""


def test_parse_synthetic_hlo():
    events, stats = hlo_parser.parse_hlo(SYNTH_HLO, 8)
    by_kind = {e.kind: e for e in events}
    assert set(by_kind) == {"all-reduce", "all-gather", "collective-permute"}

    ar = by_kind["all-reduce"]
    assert ar.multiplicity == 12                 # while trip count
    assert ar.operand_bytes == 8 * 16 * 4
    assert ar.num_groups == 2 and ar.group_size == 4
    assert ar.replica_groups[0] == [0, 1, 2, 3]
    assert "layer/mlp" in ar.op_name

    ag = by_kind["all-gather"]
    assert ag.multiplicity == 1
    assert ag.operand_bytes == 64 * 16 * 4       # gathered size convention
    assert ag.replica_groups == [[0, 1, 2, 3, 4, 5, 6, 7]]

    cp = by_kind["collective-permute"]
    assert cp.source_target_pairs == [(0, 1), (1, 2), (2, 3), (3, 0)]


def test_parse_type_bytes():
    assert hlo_parser.parse_type_bytes("f32[4,8]{1,0}") == (128, "f32")
    assert hlo_parser.parse_type_bytes("bf16[10]") == (20, "bf16")
    b, d = hlo_parser.parse_type_bytes("(f32[4], s32[2])")
    assert b == 16 + 8 and d == "f32"
    assert hlo_parser.parse_type_bytes("token[]")[0] == 0


@given(g=st.integers(1, 8), s=st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_iota_groups_partition(g, s):
    """Iota replica groups exactly partition the device set."""
    n = g * s
    groups = topology.resolve_iota_groups(g, s, [n], None)
    flat = sorted(d for grp in groups for d in grp)
    assert flat == list(range(n))
    assert all(len(grp) == s for grp in groups)


def test_iota_groups_transposed():
    # [4,2]<=[2,4]T(1,0): groups are columns of the 2x4 row-major layout
    groups = topology.resolve_iota_groups(4, 2, [2, 4], [1, 0])
    assert groups == [[0, 4], [1, 5], [2, 6], [3, 7]]


# --------------------------------------------------------------------------
# topology / link classes
# --------------------------------------------------------------------------

def test_link_classes():
    mesh = MeshSpec.multi_pod()   # (2,16,16) pod,data,model
    # group varying only along model
    grp = list(range(16))         # devices 0..15 share pod 0, data 0
    assert topology.varying_axes(mesh, grp) == ("model",)
    assert topology.link_class(mesh, ("model",)) == "ici.model"
    assert topology.link_class(mesh, ("pod",)) == "dci.pod"
    assert topology.link_class(mesh, ("data", "model")) == "ici.mixed(data+model)"
    assert topology.link_class(mesh, ("pod", "model")).startswith("xpod")
    assert topology.link_class(mesh, ()) == "local"


def test_comm_matrix_conservation():
    mesh = MeshSpec((2, 4), ("data", "model"))
    ev = mk_event(replica_groups=[[0, 1, 2, 3], [4, 5, 6, 7]],
                  group_size=4, num_groups=2)
    costmodel.annotate_event(ev, mesh, V5E)
    mat = topology.comm_matrix(mesh, [ev])
    # ring traffic: every group member sends wire_bytes to its next neighbor
    assert mat.sum() == pytest.approx(ev.wire_bytes_per_device * 8)
    assert (mat.diagonal() == 0).all()


# --------------------------------------------------------------------------
# cost model properties
# --------------------------------------------------------------------------

@given(nbytes=st.integers(1, 1 << 30), n=st.integers(2, 256))
@settings(max_examples=60, deadline=None)
def test_wire_bytes_bounds(nbytes, n):
    for kind in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all"):
        w = costmodel.wire_bytes_per_device(kind, nbytes, n)
        assert 0 <= w <= 2 * nbytes
    assert costmodel.wire_bytes_per_device("all-reduce", nbytes, 1) == 0


@given(nbytes=st.integers(1, 1 << 28), n1=st.integers(2, 64))
@settings(max_examples=40, deadline=None)
def test_allreduce_monotonic_in_bytes(nbytes, n1):
    t1 = costmodel.allreduce_time("ring", nbytes, n1, 50e9, 1e-6)
    t2 = costmodel.allreduce_time("ring", 2 * nbytes, n1, 50e9, 1e-6)
    assert t2 >= t1
    # RSAG beats ring on latency for large groups, same bandwidth term
    t_ring = costmodel.allreduce_time("ring", 1024, 64, 50e9, 1e-6)
    t_rsag = costmodel.allreduce_time("reduce_scatter_allgather", 1024, 64,
                                      50e9, 1e-6)
    assert t_rsag <= t_ring


def test_protocol_regimes():
    mesh = MeshSpec.single_pod()
    small = mk_event(operand_bytes=1024,
                     replica_groups=[list(range(16))], group_size=16)
    big = mk_event(operand_bytes=1 << 28,
                   replica_groups=[list(range(16))], group_size=16)
    costmodel.annotate_event(small, mesh, V5E)
    costmodel.annotate_event(big, mesh, V5E)
    assert small.protocol == "eager"
    assert big.protocol == "rndv"
    assert big.est_time_s > small.est_time_s


# --------------------------------------------------------------------------
# attribution
# --------------------------------------------------------------------------

def test_split_op_name():
    scope, prim = attribution.split_op_name(
        "jit(step)/transpose(jvp(mlp))/while/body/layer/attn/dot_general")
    assert "layer/attn" in scope and "mlp" in scope
    assert prim == "dot_general"


def test_semantic_classification():
    assert attribution.classify("layer/attn", "dot_general", "all-gather",
                                in_backward=False) == "attention"
    assert attribution.classify("layer/moe/dispatch", "einsum", "all-to-all",
                                in_backward=False) == "moe_dispatch"
    # backward DP-only reduction => grad_sync regardless of module scope
    assert attribution.classify("layer/mlp", "dot_general", "all-reduce",
                                in_backward=True, axes=("data",)) == "grad_sync"
    assert attribution.classify("layer/mlp", "dot_general", "all-reduce",
                                in_backward=True, axes=("model",)) == "ffn"


# --------------------------------------------------------------------------
# detectors
# --------------------------------------------------------------------------

def test_detect_axis_detour():
    from repro.core import detect
    mesh = MeshSpec.single_pod()
    ev = mk_event(op_name="jit(f)/transpose(jvp(x))/optimizer/psum",
                  replica_groups=[list(range(256))], group_size=256)
    costmodel.annotate_event(ev, mesh, V5E)
    attribution.attribute_event(ev)
    tr = Trace("t", mesh.shape, mesh.axes, 256, [ev])
    finds = detect.detect_axis_detours(tr, {"grad_sync": "data"})
    assert len(finds) == 1 and "model" in str(finds[0])
