"""Jitted wrappers around the Pallas kernels.

`flash_attention` adapts the model's [B, S, H, D] layout + GQA + head-dim
padding (h2o-danube's 120 -> 128) to the kernel's [B, H, S, D] tiles.
On this CPU container the wrappers run with interpret=True; on TPU the same
call sites compile the Mosaic kernels.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import mamba_scan as ms


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(cfg, q, k, v, *, causal=True, window=0, q_offset=0,
                    interpret=None):
    """Model-layout wrapper: q [B,S,H,Dh], k/v [B,S,K,Dh] -> [B,S,H,Dh]."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, Sq, H, Dh = q.shape
    scale = cfg.head_dim ** -0.5 if cfg is not None else Dh ** -0.5
    pad = (-Dh) % 128
    if pad:
        padw = [(0, 0), (0, 0), (0, 0), (0, pad)]
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = fa.flash_attention(qt, kt, vt, causal=causal,
                             window=int(window) if window else 0,
                             q_offset=q_offset, scale=scale,
                             interpret=interpret)
    out = out.transpose(0, 2, 1, 3)
    if pad:
        out = out[..., :Dh]
    return out


def mamba_scan(a_bar, bx, c, *, interpret=None, chunk=256, di_block=512):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return ms.mamba_scan(a_bar.astype(jnp.float32), bx.astype(jnp.float32),
                         c.astype(jnp.float32), chunk=chunk,
                         di_block=di_block, interpret=interpret)
