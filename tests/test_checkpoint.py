"""Checkpoint store: roundtrip, atomicity, pruning, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro import checkpoint


def tree(seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.standard_normal((4, 8)), jnp.float32),
            "b": {"w": jnp.asarray(r.standard_normal((3,)), jnp.bfloat16),
                  "n": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = tree()
    checkpoint.save(str(tmp_path), 5, t, extra={"next_step": 5})
    restored, extra = checkpoint.restore(str(tmp_path), t)
    assert extra["next_step"] == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_prune(tmp_path):
    t = tree()
    for step in (1, 2, 3, 4):
        checkpoint.save(str(tmp_path), step, t)
    assert checkpoint.latest_step(str(tmp_path)) == 4
    checkpoint.prune_old(str(tmp_path), keep=2)
    names = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert names == ["step_00000003", "step_00000004"]
    assert checkpoint.latest_step(str(tmp_path)) == 4


def test_crash_mid_write_never_corrupts(tmp_path):
    """A leftover .tmp dir (simulated crash) is invisible to restore."""
    t = tree()
    checkpoint.save(str(tmp_path), 1, t, extra={"next_step": 1})
    # simulate a crashed write of step 2
    os.makedirs(tmp_path / "step_00000002.tmp")
    with open(tmp_path / "step_00000002.tmp" / "arr_00000.npy", "w") as f:
        f.write("garbage")
    assert checkpoint.latest_step(str(tmp_path)) == 1
    restored, extra = checkpoint.restore(str(tmp_path), t)
    assert extra["next_step"] == 1


def test_shape_mismatch_rejected(tmp_path):
    t = tree()
    checkpoint.save(str(tmp_path), 1, t)
    bad = {"a": jnp.zeros((4, 9)), "b": t["b"]}
    with pytest.raises(ValueError):
        checkpoint.restore(str(tmp_path), bad)


@given(seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_roundtrip_property(tmp_path_factory, seed):
    d = tmp_path_factory.mktemp(f"ck{seed}")
    t = tree(seed)
    checkpoint.save(str(d), 0, t)
    restored, _ = checkpoint.restore(str(d), t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer(tmp_path):
    """Async save overlaps serialization; wait() surfaces results + errors."""
    from repro.checkpoint import AsyncCheckpointer
    t = tree()
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3):
        ck.save(step, t, extra={"next_step": step})
    ck.wait()
    assert checkpoint.latest_step(str(tmp_path)) == 3
    restored, extra = checkpoint.restore(str(tmp_path), t)
    assert extra["next_step"] == 3
    names = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(names) == 2       # pruned to keep=2


def test_elastic_restore_across_meshes(subproc):
    """Save sharded on a (2,4) mesh, restore onto (4,2) and (8,1) meshes."""
    out = subproc("""
import jax
import jax.numpy as jnp
import numpy as np
import tempfile
import os
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import checkpoint

d = tempfile.mkdtemp()
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
checkpoint.save(d, 1, {"x": xa})

for shape in [(4, 2), (8, 1), (1, 8)]:
    mesh_b = jax.make_mesh(shape, ("data", "model"))
    sh = {"x": NamedSharding(mesh_b, P("data", "model"))}
    restored, _ = checkpoint.restore(d, {"x": x}, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
    assert restored["x"].sharding.mesh.shape["data"] == shape[0]
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out
