import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Round 6: hymba-1.5b train (worst useful ratio 0.14, mfu 0.002).
# Parallel attn+SSM heads mean BOTH kernels apply; d_model=1600 at 256
# chips is also just small — measure how far kernels take it.
import json
from hillclimb2 import run_variant
from hillclimb import attn_kernel_bytes, ssm_kernel_bytes, TOKENS
from repro.configs import get_config


def both_kernels(arch, st):
    return attn_kernel_bytes(arch, st) + ssm_kernel_bytes(arch, st)


HERE = os.path.dirname(os.path.abspath(__file__))
rows = []
rows.append(run_variant("hymba-1.5b", "train_4k", "baseline", {}, {}, None))
rows.append(run_variant("hymba-1.5b", "train_4k", "H23_both_kernels",
                        {"ssm_inloop": True}, {},
                        (r"/(ssm|attn)", both_kernels), "train"))
rows.append(run_variant("hymba-1.5b", "train_4k", "H24_kernels+accum1",
                        {"ssm_inloop": True}, {"accum": 1},
                        (r"/(ssm|attn)", both_kernels), "train"))
with open(os.path.join(HERE, "hillclimb6.json"), "w") as f:
    json.dump(rows, f, indent=1)
