"""Per-detector coverage: each fires on a crafted trace and stays silent
on a benign one (the Fig 7 misconfiguration-detection analogue)."""
from repro.core import detect
from repro.core.events import CollectiveEvent, HloOpStats, Trace


def mk_event(**kw):
    base = dict(name="ar", kind="all-reduce", async_start=False,
                operand_bytes=1 << 22, result_bytes=1 << 22, dtype="f32",
                replica_groups=[[0, 1, 2, 3]], group_size=4, num_groups=1,
                op_name="", computation="main", link_class="ici.data",
                axes=("data",), protocol="rndv", wire_bytes_per_device=1 << 21,
                est_time_s=1e-4)
    base.update(kw)
    return CollectiveEvent(**base)


def mk_trace(events, **kw):
    return Trace(label="t", mesh_shape=(2, 2), mesh_axes=("data", "model"),
                 num_devices=4, events=events, **kw)


# -- redundant_collective ---------------------------------------------------

def test_redundant_gathers_fires_on_duplicates():
    evs = [mk_event(name=f"ag{i}", kind="all-gather", scope="layer/attn")
           for i in range(3)]
    findings = detect.detect_redundant_gathers(mk_trace(evs))
    assert len(findings) == 1
    f = findings[0]
    assert f.detector == "redundant_collective"
    assert "3x identical all-gather" in f.message
    assert f.wasted_bytes == 2 * (1 << 22)      # (count-1) x bytes x mult


def test_redundant_gathers_silent_on_distinct_scopes():
    evs = [mk_event(name=f"ag{i}", kind="all-gather", scope=f"layer{i}")
           for i in range(3)]
    assert detect.detect_redundant_gathers(mk_trace(evs)) == []


def test_redundant_gathers_ignores_small_payloads():
    evs = [mk_event(name=f"ag{i}", kind="all-gather",
                    operand_bytes=1 << 10, scope="s") for i in range(4)]
    assert detect.detect_redundant_gathers(mk_trace(evs)) == []


# -- axis_detour ------------------------------------------------------------

def test_axis_detour_fires_on_wrong_axis():
    ev = mk_event(semantic="grad_sync", axes=("model",),
                  link_class="ici.model")
    out = detect.detect_axis_detours(mk_trace([ev]), {"grad_sync": "data"})
    assert len(out) == 1
    assert out[0].detector == "axis_detour"
    assert "expected only 'data'" in out[0].message


def test_axis_detour_silent_on_expected_axis():
    ev = mk_event(semantic="grad_sync", axes=("data",))
    assert detect.detect_axis_detours(mk_trace([ev]),
                                      {"grad_sync": "data"}) == []


def test_axis_detour_exempts_small_payloads():
    ev = mk_event(semantic="grad_sync", axes=("model",),
                  operand_bytes=1 << 10)
    assert detect.detect_axis_detours(mk_trace([ev]),
                                      {"grad_sync": "data"}) == []


# -- eager_flood ------------------------------------------------------------

def test_eager_flood_fires_on_many_tiny_transfers():
    evs = [mk_event(name=f"e{i}", protocol="eager", operand_bytes=1 << 8,
                    multiplicity=8) for i in range(10)]
    out = detect.detect_eager_floods(mk_trace(evs))
    assert len(out) == 1
    assert out[0].detector == "eager_flood"
    assert "80 latency-bound" in out[0].message


def test_eager_flood_silent_below_threshold():
    evs = [mk_event(name=f"e{i}", protocol="eager") for i in range(3)]
    assert detect.detect_eager_floods(mk_trace(evs)) == []


# -- layout_thrash ----------------------------------------------------------

def test_layout_thrash_fires_on_heavy_transposes():
    stats = HloOpStats(n_transpose=100, transpose_bytes=2 << 30)
    out = detect.detect_layout_thrash(mk_trace([], op_stats=stats))
    assert len(out) == 1
    assert out[0].detector == "layout_thrash"


def test_layout_thrash_silent_below_threshold():
    stats = HloOpStats(n_transpose=3, transpose_bytes=1 << 20)
    assert detect.detect_layout_thrash(mk_trace([], op_stats=stats)) == []


# -- cross_pod_bulk ---------------------------------------------------------

def test_cross_pod_bulk_fires_on_heavy_dci():
    ev = mk_event(link_class="dci.pod", axes=("pod",),
                  wire_bytes_per_device=1 << 29)   # x4 devices > 1 GB
    out = detect.detect_cross_pod_bulk(mk_trace([ev]))
    assert len(out) == 1
    assert out[0].detector == "cross_pod_bulk"


def test_cross_pod_bulk_silent_on_ici_traffic():
    ev = mk_event(link_class="ici.data", wire_bytes_per_device=1 << 29)
    assert detect.detect_cross_pod_bulk(mk_trace([ev])) == []


# -- run_all ----------------------------------------------------------------

def test_run_all_combines_detectors():
    evs = [mk_event(name=f"ag{i}", kind="all-gather", scope="layer/attn")
           for i in range(2)]
    evs += [mk_event(name=f"e{i}", protocol="eager", multiplicity=16,
                     operand_bytes=1 << 8) for i in range(8)]
    evs.append(mk_event(semantic="grad_sync", axes=("model",),
                        link_class="ici.model"))
    tr = mk_trace(evs, op_stats=HloOpStats(transpose_bytes=2 << 30))
    findings = detect.run_all(tr, expected_axes={"grad_sync": "data"})
    detectors = {f.detector for f in findings}
    assert {"redundant_collective", "axis_detour", "eager_flood",
            "layout_thrash"} <= detectors


def test_detectors_empty_trace():
    assert detect.run_all(mk_trace([])) == []
