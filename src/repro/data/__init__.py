from repro.data.pipeline import DataConfig, SyntheticTokens, shard_batch

__all__ = ["DataConfig", "SyntheticTokens", "shard_batch"]
