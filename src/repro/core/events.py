"""Event model for the multi-layer trace (the ucTrace data model, TPU-ified).

Layer mapping (see DESIGN.md §2):
  MPI  function   -> `semantic`   (grad_sync / attention / moe_dispatch / ...)
  UCP  operation  -> `jax_prim`   (the jax-level primitive from op_name)
  UCT  send       -> `CollectiveEvent` (one compiled HLO collective op)
  UCT  transport  -> `link_class` (ici.<axis> / dci.pod / mixed / local)
  completion time -> `est_time_s` (cost model; xplane-fed on real hardware)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class CollectiveEvent:
    """One HLO collective op instance (the UCT-layer record)."""

    name: str                      # HLO op name (%all-reduce.1)
    kind: str                      # all-reduce | all-gather | reduce-scatter |
                                   # all-to-all | collective-permute
    async_start: bool              # -start form (overlappable)
    operand_bytes: int             # sum of operand payload bytes
    result_bytes: int
    dtype: str
    replica_groups: List[List[int]]    # resolved device ids per group
    group_size: int
    num_groups: int
    op_name: str                   # HLO metadata op_name (call-stack analogue)
    computation: str               # enclosing HLO computation
    multiplicity: int = 1          # executions per step (while-loop trip counts)
    channel_id: Optional[int] = None
    source_target_pairs: Optional[List[Tuple[int, int]]] = None  # permutes

    # derived (filled by attribution/topology/cost model)
    link_class: str = ""           # ici.data | ici.model | dci.pod | mixed(..) | local
    axes: Tuple[str, ...] = ()     # mesh axes the groups span
    semantic: str = ""             # MPI-function analogue
    jax_prim: str = ""             # UCP-operation analogue
    scope: str = ""                # named_scope path prefix
    protocol: str = ""             # eager | rndv  (latency- vs bandwidth-bound)
    wire_bytes_per_device: float = 0.0
    est_time_s: float = 0.0

    @property
    def total_wire_bytes(self) -> float:
        """Wire traffic summed over participating devices, per execution."""
        return self.wire_bytes_per_device * self.group_size * self.num_groups


@dataclass
class HloOpStats:
    """Non-collective per-program stats used by detectors/roofline."""

    n_transpose: int = 0
    n_fusion: int = 0
    n_convert: int = 0
    n_reshape: int = 0
    transpose_bytes: int = 0
    # loop-aware totals (x while trip counts) — cost_analysis counts loop
    # bodies once, so these are the authoritative roofline inputs.
    flops: float = 0.0
    bytes_accessed: float = 0.0
    # per-named_scope attribution (module-level rollups + kernel-adjusted
    # rooflines: e.g. subtract `attn` score traffic when the Pallas flash
    # kernel replaces the XLA blocked path)
    bytes_by_scope: Dict[str, float] = field(default_factory=dict)
    flops_by_scope: Dict[str, float] = field(default_factory=dict)


@dataclass
class Trace:
    """A complete multi-layer communication trace of one compiled step."""

    label: str
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    num_devices: int
    events: List[CollectiveEvent] = field(default_factory=list)
    op_stats: HloOpStats = field(default_factory=HloOpStats)

    # compiled-artifact numbers (cost_analysis / memory_analysis)
    hlo_flops: float = 0.0
    hlo_bytes: float = 0.0
    per_device_memory_bytes: float = 0.0
    argument_bytes: float = 0.0
    output_bytes: float = 0.0

    # ---- aggregate views ---------------------------------------------------
    def total_collective_bytes(self) -> float:
        """Sum of operand sizes x multiplicity (roofline definition)."""
        return float(sum(e.operand_bytes * e.multiplicity for e in self.events))

    def total_wire_bytes(self) -> float:
        return float(sum(e.total_wire_bytes * e.multiplicity for e in self.events))

    def total_est_time_s(self) -> float:
        return float(sum(e.est_time_s * e.multiplicity for e in self.events))

    def overlapped_est_time_s(self) -> float:
        """Lower bound on collective time with perfect cross-link overlap.

        Different link classes (ici.data vs ici.model vs dci.pod) use
        disjoint physical links, so a latency-hiding scheduler can run them
        concurrently: the bound is the max per-class serialized time, not
        the sum.  Together with total_est_time_s() this brackets reality.
        """
        per_class: Dict[str, float] = {}
        for e in self.events:
            per_class[e.link_class] = per_class.get(e.link_class, 0.0) \
                + e.est_time_s * e.multiplicity
        return max(per_class.values()) if per_class else 0.0

    def by(self, key_fn) -> Dict[str, Dict[str, float]]:
        """Aggregate {key: {bytes, wire_bytes, count, time_s}}."""
        agg: Dict[str, Dict[str, float]] = {}
        for e in self.events:
            k = key_fn(e)
            a = agg.setdefault(k, {"bytes": 0.0, "wire_bytes": 0.0,
                                   "count": 0.0, "time_s": 0.0})
            a["bytes"] += e.operand_bytes * e.multiplicity
            a["wire_bytes"] += e.total_wire_bytes * e.multiplicity
            a["count"] += e.multiplicity
            a["time_s"] += e.est_time_s * e.multiplicity
        return agg

    def by_kind_and_link(self):
        return self.by(lambda e: f"{e.kind}|{e.link_class}")

    def by_semantic(self):
        return self.by(lambda e: e.semantic or "other")
