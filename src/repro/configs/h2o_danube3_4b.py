"""h2o-danube-3-4b — llama+mistral mix dense decoder with SWA. [arXiv:2401.16818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,              # 3840/32 — NOT 128-aligned; einsum attention path
    d_ff=10240,
    vocab_size=32000,
    window=4096,               # mistral-style sliding window
    notes="head_dim 120 is not MXU-aligned: flash kernel pads to 128",
)
