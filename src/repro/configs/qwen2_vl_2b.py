"""qwen2-vl-2b — VLM backbone with M-RoPE. [arXiv:2409.12191]

Vision frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings alongside text tokens; M-RoPE takes (3, seq)
position ids (t / h / w).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    rope="mrope",
    mrope_sections=(16, 24, 24),   # t/h/w sections of head_dim/2
    rope_theta=1_000_000.0,
    notes="backbone only; patch embeddings precomputed (stub frontend); "
          "pure full attention => long_500k skipped per assignment",
)
