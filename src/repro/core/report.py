"""Renderers: ASCII / JSON / self-contained HTML (the Fig 3 visualizer).

Views (paper analogues):
  * top-contenders table   — Table II: bytes% (count%) per kind x link class
  * communication matrix   — Fig 3b heatmap over mesh coordinates
  * device view            — Fig 3d: per-link-class traffic graph
  * timeline               — Fig 3a: modeled serialized collective schedule
  * semantic breakdown     — the MPI-function layer rollup
"""
from __future__ import annotations

import html as html_mod
import json
from typing import Dict, List, Optional

import numpy as np

from repro.core.events import Trace
from repro.core.topology import MeshSpec, comm_matrix, reduce_matrix


# --------------------------------------------------------------------------
# ASCII
# --------------------------------------------------------------------------

def top_contenders_table(trace: Trace, by: str = "kind_link") -> str:
    """Bytes% (count%) per (collective kind x link class) — Table II analogue."""
    agg = trace.by_kind_and_link() if by == "kind_link" else trace.by_semantic()
    tot_b = sum(a["bytes"] for a in agg.values()) or 1.0
    tot_c = sum(a["count"] for a in agg.values()) or 1.0
    rows = sorted(agg.items(), key=lambda kv: -kv[1]["bytes"])
    lines = [f"{'key':44s} {'bytes%':>8s} {'count%':>8s} {'GB':>10s} "
             f"{'count':>8s} {'est_ms':>8s}"]
    for k, a in rows:
        lines.append(
            f"{k:44s} {100*a['bytes']/tot_b:7.1f}% {100*a['count']/tot_c:7.1f}% "
            f"{a['bytes']/1e9:10.3f} {int(a['count']):8d} {a['time_s']*1e3:8.3f}")
    lines.append(f"{'total':44s} {'100.0%':>8s} {'100.0%':>8s} "
                 f"{tot_b/1e9:10.3f} {int(tot_c):8d} "
                 f"{trace.total_est_time_s()*1e3:8.3f}")
    return "\n".join(lines)


def semantic_table(trace: Trace) -> str:
    return top_contenders_table(trace, by="semantic")


def ascii_matrix(mat: np.ndarray, labels: Optional[List[str]] = None,
                 width: int = 9) -> str:
    n = mat.shape[0]
    labels = labels or [str(i) for i in range(n)]
    peak = mat.max() or 1.0
    shades = " .:-=+*#%@"
    out = []
    for i in range(n):
        row = "".join(shades[min(int(mat[i, j] / peak * (len(shades) - 1)),
                                 len(shades) - 1)] for j in range(n))
        out.append(f"{labels[i]:>6s} |{row}|")
    return "\n".join(out)


def timeline(trace: Trace, top: int = 30) -> str:
    """Modeled serialized schedule of the heaviest collectives (Fig 3a)."""
    s = trace.store
    order = np.argsort(-(s.est_time_s * s.weights), kind="stable")[:top]
    t = 0.0
    lines = [f"{'t_start_us':>10s} {'dur_us':>9s} {'x':>5s} {'kind':18s} "
             f"{'link':16s} {'semantic':14s} scope"]
    for i in order:
        dur = s.est_time_s[i] * 1e6
        lines.append(f"{t*1e6:10.1f} {dur:9.2f} {int(s.multiplicity[i]):5d} "
                     f"{s.kind.value(i):18s} {s.link_class.value(i):16s} "
                     f"{s.semantic.value(i):14s} "
                     f"{s.scope.value(i)[:48]}")
        t += s.est_time_s[i] * s.multiplicity[i]
    return "\n".join(lines)


def summary(trace: Trace) -> str:
    n_ev = int(trace.store.multiplicity.sum())
    return (
        f"trace '{trace.label}': mesh {trace.mesh_shape} axes {trace.mesh_axes}\n"
        f"  collectives/step: {n_ev} ({trace.store.n} sites)\n"
        f"  collective bytes (operand conv): {trace.total_collective_bytes()/1e9:.3f} GB/device\n"
        f"  wire bytes: {trace.total_wire_bytes()/1e9:.3f} GB total\n"
        f"  modeled collective time: {trace.total_est_time_s()*1e3:.3f} ms (serialized)\n"
        f"  HLO flops/device: {trace.hlo_flops/1e12:.3f} T, bytes: {trace.hlo_bytes/1e9:.2f} GB\n"
        f"  per-device memory: {trace.per_device_memory_bytes/1e9:.2f} GB")


# --------------------------------------------------------------------------
# n-way session comparison (the "Allreduce across MPI libraries" table)
# --------------------------------------------------------------------------

def session_table(traces, by: str = "kind_link", metric: str = "bytes",
                  top: int = 24) -> str:
    """N-way comparison: one row per traffic class, one column per trace.

    `traces` is any sequence of Trace (a TraceSession iterates as one).
    `metric` selects the cell value: bytes (GB), time (ms), or count.
    The paper's cross-run experiment shape (UCX settings / MPI libraries /
    NUMA bindings) as a single table — `diff.render_diff` stays the
    two-column deep-dive.
    """
    from repro.core.diff import diff_n
    traces = list(traces)
    if not traces:
        return "(empty session)"
    rows = diff_n(traces, by)
    labels = [t.label for t in traces]
    scale, unit = {"bytes": (1e-9, "GB"), "time": (1e3, "ms"),
                   "count": (1.0, "x")}[metric]
    width = max(10, max(len(l) for l in labels) + 1)
    head = f"{'key (' + unit + ')':42s} " + \
        " ".join(f"{l[:width-1]:>{width}s}" for l in labels) + "  verdict"
    lines = [f"session comparison ({len(traces)} traces, by {by})", head]
    for r in rows[:top]:
        vals = {"bytes": r.bytes_, "time": r.times, "count": r.counts}[metric]
        cells = " ".join(f"{v*scale:{width}.3f}" for v in vals)
        lines.append(f"{r.key:42s} {cells}  {r.verdict()}")
    if len(rows) > top:
        lines.append(f"... ({len(rows) - top} more classes)")
    totals = [t.total_est_time_s() * 1e3 for t in traces]
    lines.append(f"{'TOTAL modeled collective ms':42s} " +
                 " ".join(f"{v:{width}.3f}" for v in totals) +
                 ("  best=" + labels[int(np.argmin(totals))] if totals else ""))
    return "\n".join(lines)


# --------------------------------------------------------------------------
# JSON / HTML
# --------------------------------------------------------------------------

def to_json(trace: Trace) -> str:
    return json.dumps({
        "label": trace.label,
        "mesh_shape": trace.mesh_shape,
        "mesh_axes": trace.mesh_axes,
        "hlo_flops": trace.hlo_flops,
        "hlo_bytes": trace.hlo_bytes,
        "per_device_memory_bytes": trace.per_device_memory_bytes,
        "events": [{
            "name": e.name, "kind": e.kind, "bytes": e.operand_bytes,
            "mult": e.multiplicity, "link": e.link_class,
            "axes": e.axes, "semantic": e.semantic, "scope": e.scope,
            "prim": e.jax_prim, "protocol": e.protocol,
            "group_size": e.group_size, "num_groups": e.num_groups,
            "est_time_us": e.est_time_s * 1e6,
        } for e in trace.events],
    }, indent=1)


_HTML_HEAD = """<!doctype html><meta charset="utf-8">
<title>repro trace: %s</title>
<style>
 body{font:13px monospace;background:#111;color:#ddd;margin:24px}
 h2{color:#7fd} table{border-collapse:collapse;margin:12px 0}
 td,th{border:1px solid #333;padding:3px 8px;text-align:right}
 th{background:#222;color:#7fd} td.l{text-align:left}
 .hm td{width:14px;height:14px;padding:0;border:1px solid #222}
 .bar{background:#167;display:inline-block;height:10px}
</style>"""


def to_html(trace: Trace, mesh: MeshSpec) -> str:
    """Self-contained HTML report (the interactive-visualizer analogue)."""
    parts = [_HTML_HEAD % html_mod.escape(trace.label)]
    parts.append(f"<h1>trace: {html_mod.escape(trace.label)}</h1>")
    parts.append("<pre>" + html_mod.escape(summary(trace)) + "</pre>")

    # top contenders
    parts.append("<h2>top contenders (kind x link) — Table II analogue</h2>")
    parts.append("<pre>" + html_mod.escape(top_contenders_table(trace)) + "</pre>")
    parts.append("<h2>semantic (MPI-layer analogue)</h2>")
    parts.append("<pre>" + html_mod.escape(semantic_table(trace)) + "</pre>")

    # comm matrix heatmaps per axis
    mat = comm_matrix(mesh, trace)
    for axis in mesh.axes:
        red = reduce_matrix(mat, mesh, axis)
        peak = red.max() or 1.0
        parts.append(f"<h2>comm matrix over axis '{axis}' (GB)</h2>")
        rows = ["<table class='hm'>"]
        for i in range(red.shape[0]):
            cells = []
            for j in range(red.shape[1]):
                v = red[i, j] / peak
                col = f"rgb({int(20+v*40)},{int(30+v*160)},{int(60+v*180)})"
                cells.append(f"<td style='background:{col}' "
                             f"title='{i}->{j}: {red[i,j]/1e9:.3f} GB'></td>")
            rows.append("<tr>" + "".join(cells) + "</tr>")
        rows.append("</table>")
        parts.append("".join(rows))

    # timeline
    parts.append("<h2>modeled timeline (top collectives)</h2>")
    parts.append("<pre>" + html_mod.escape(timeline(trace)) + "</pre>")
    return "\n".join(parts)
