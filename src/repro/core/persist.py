"""Atomic file persistence — write a same-directory temp file, then
`os.replace` it into place — plus the warehouse npz container.

Every on-disk artifact this package produces (session saves, report
JSON/HTML, bench payloads, the watch daemon's rolling outputs) may be
read concurrently: the watch daemon re-emits them every poll while CI
artifact collection or a browser reload reads them.  A plain
`open(path, "w")` exposes truncated intermediate states to those
readers; renaming a fully-written sibling is atomic on POSIX, so a
reader sees either the old artifact or the new one — never a torn file.

`write_npz` / `open_npz_mmap` are the fleet-scale replacements for
`np.savez_compressed` / `np.load` on session artifacts:

  * `write_npz` emits a *deterministic* `np.load`-compatible zip —
    member timestamps pinned to the DOS epoch, no extra fields, fixed
    member order — so saving the same session twice yields the same
    bytes (`np.savez_compressed` stamps wall-clock member times, which
    made byte-level artifact comparison flaky).  Members DEFLATE in a
    thread pool: `zlib` releases the GIL, so per-trace compression
    overlaps across cores while a single writer assembles the archive.
  * `open_npz_mmap` opens an *uncompressed* `write_npz` archive
    zero-copy: each member's array data is `np.memmap`'d read-only at
    its offset inside the zip, so a 10M-site session "loads" without
    materializing a byte of column data until it is touched.
"""
from __future__ import annotations

import contextlib
import io
import os
import struct
import tempfile
import zlib
from typing import Dict, Iterator, Mapping, Optional

import numpy as np


def _fsync_dir(dirpath: str) -> None:
    """fsync a directory so a just-renamed entry survives power loss.

    `os.replace` makes the rename atomic for concurrent *readers*, but
    the new directory entry itself lives in the page cache until the
    directory inode is flushed — a crash between the rename and that
    flush can resurrect the old file (or neither).  Checkpoint/resume
    correctness (the watch daemon) needs the rename to be durable, not
    just atomic.  Filesystems that cannot fsync a directory fd (or
    platforms without O_DIRECTORY) are tolerated silently.
    """
    flags = os.O_RDONLY | getattr(os, "O_DIRECTORY", 0)
    try:
        fd = os.open(dirpath, flags)
    except OSError:
        return
    try:
        with contextlib.suppress(OSError):
            os.fsync(fd)
    finally:
        os.close(fd)


@contextlib.contextmanager
def atomic_open(path: str, mode: str = "w"):
    """`open(path, mode)` with atomic-replace semantics.

    Yields a file object over a temp file created in `path`'s directory
    (same filesystem, so the final rename cannot cross a mount).  On
    clean exit the temp file is flushed, fsync'd, renamed over `path`,
    and the parent directory is fsync'd (the rename is durable, not
    just atomic); on any error it is removed and `path` is left
    untouched.  `mode` must be a write mode ("w" or "wb").
    """
    if mode not in ("w", "wb"):
        raise ValueError(f"atomic_open requires a write mode, got {mode!r}")
    target = os.path.abspath(path)
    parent = os.path.dirname(target)
    fd, tmp = tempfile.mkstemp(dir=parent,
                               prefix=os.path.basename(target) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, mode) as f:
            yield f
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, target)
        _fsync_dir(parent)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise


# --------------------------------------------------------------------------
# deterministic npz container (parallel compress, mmap-able when stored)
# --------------------------------------------------------------------------

# pinned member timestamp: the DOS epoch (1980-01-01 00:00:00).  Zip has
# no "no timestamp" encoding, so determinism means pinning it.
_DOS_DATE = (1 << 5) | 1
_DOS_TIME = 0
_ZIP64_LIMIT = 0xFFFFFFFF - 1


def _npy_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    # np.ascontiguousarray would promote 0-d members (the JSON side-car
    # strings) to 1-d; write_array copies non-contiguous input itself.
    np.lib.format.write_array(buf, np.asarray(arr), allow_pickle=False)
    return buf.getvalue()


def _prep_member(name: str, arr: np.ndarray, compress: bool, level: int):
    """Serialize + (optionally) deflate one member: CPU-bound, GIL-free
    in the zlib portion, so members prep concurrently in threads."""
    raw = _npy_bytes(arr)
    crc = zlib.crc32(raw) & 0xFFFFFFFF
    if compress:
        co = zlib.compressobj(level, zlib.DEFLATED, -15)   # raw DEFLATE
        data = co.compress(raw) + co.flush()
        method = 8
    else:
        data, method = raw, 0
    return name, method, crc, len(raw), data


def write_npz(fp, arrays: Mapping[str, np.ndarray], *, compress: bool = True,
              level: int = 6, workers: Optional[int] = None) -> None:
    """Write `arrays` to `fp` as a deterministic `np.load`-compatible npz.

    Unlike `np.savez_compressed`, the output is a pure function of the
    array contents: member order follows the dict, timestamps are pinned
    to the DOS epoch, and no platform-dependent extra fields are
    emitted — saving the same session twice is byte-identical (pinned by
    tests/test_warehouse.py).  With `compress=True` members DEFLATE in a
    thread pool (`workers`, default one per core capped at 8) while this
    single writer assembles the archive in order; `compress=False`
    stores members raw, the layout `open_npz_mmap` maps zero-copy.

    Archives stay in classic zip territory (no zip64): a member or the
    archive crossing 4 GiB raises rather than silently corrupting.
    """
    items = [(f"{key}.npy", arr) for key, arr in arrays.items()]
    if len(items) >= 0xFFFF:
        raise ValueError(f"too many npz members for zip ({len(items)})")
    if workers is None:
        workers = min(os.cpu_count() or 1, 8)
    if compress and workers > 1 and len(items) > 1:
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=workers) as ex:
            prepped = list(ex.map(
                lambda it: _prep_member(it[0], it[1], compress, level),
                items))
    else:
        prepped = [_prep_member(n, a, compress, level) for n, a in items]

    offset = 0
    central = []
    for name, method, crc, usize, data in prepped:
        fn = name.encode("ascii")
        csize = len(data)
        if max(csize, usize, offset) > _ZIP64_LIMIT:
            raise ValueError(
                f"npz member {name!r} needs zip64 (>4GiB), unsupported")
        fp.write(struct.pack("<4s5H3I2H", b"PK\x03\x04", 20, 0, method,
                             _DOS_TIME, _DOS_DATE, crc, csize, usize,
                             len(fn), 0))
        fp.write(fn)
        fp.write(data)
        central.append((fn, method, crc, csize, usize, offset))
        offset += 30 + len(fn) + csize
    cd_start = offset
    for fn, method, crc, csize, usize, off in central:
        fp.write(struct.pack("<4s6H3I5H2I", b"PK\x01\x02", 20, 20, 0,
                             method, _DOS_TIME, _DOS_DATE, crc, csize,
                             usize, len(fn), 0, 0, 0, 0, 0, off))
        fp.write(fn)
        offset += 46 + len(fn)
    fp.write(struct.pack("<4s4H2IH", b"PK\x05\x06", 0, 0, len(central),
                         len(central), offset - cd_start, cd_start, 0))


def _read_npy_header(f):
    """(shape, fortran_order, dtype, data_offset) of the npy at f's pos."""
    version = np.lib.format.read_magic(f)
    if version == (1, 0):
        shape, fortran, dtype = np.lib.format.read_array_header_1_0(f)
    elif version == (2, 0):
        shape, fortran, dtype = np.lib.format.read_array_header_2_0(f)
    else:
        raise ValueError(f"unsupported npy format version {version}")
    return shape, fortran, dtype, f.tell()


class MmapNpz(Mapping):
    """Read-only zero-copy view of an uncompressed npz archive.

    Member arrays are `np.memmap`'d (mode="r") at their data offset
    inside the zip on first access and cached; nothing is read up front
    beyond the member directory.  The maps are not writeable — mutating
    consumers (`TraceStore.append`, `Categorical.extend`) already seed
    fresh buffers when a column does not alias their own capacity
    buffer, so copy-on-write falls out of the existing append contract.
    Non-numeric members (the 0-d JSON side-car strings) are decoded
    eagerly — they are small by design.
    """

    def __init__(self, path: str):
        import zipfile
        self.path = os.path.abspath(path)
        self._members: Dict[str, int] = {}
        self._cache: Dict[str, np.ndarray] = {}
        with zipfile.ZipFile(self.path) as zf:
            for zi in zf.infolist():
                if zi.compress_type != zipfile.ZIP_STORED:
                    raise ValueError(
                        f"{path}: member {zi.filename!r} is compressed — "
                        f"mmap load needs an uncompressed save "
                        f"(session save with compress=False / "
                        f"`session ingest --no-compress`)")
                key = zi.filename[:-4] if zi.filename.endswith(".npy") \
                    else zi.filename
                self._members[key] = zi.header_offset

    def __getitem__(self, key: str) -> np.ndarray:
        arr = self._cache.get(key)
        if arr is not None:
            return arr
        header_offset = self._members[key]     # raises KeyError
        with open(self.path, "rb") as f:
            f.seek(header_offset)
            hdr = f.read(30)
            if len(hdr) != 30 or hdr[:4] != b"PK\x03\x04":
                raise ValueError(f"{self.path}: bad zip member at "
                                 f"{header_offset} ({key!r})")
            fnlen, extralen = struct.unpack("<HH", hdr[26:30])
            f.seek(header_offset + 30 + fnlen + extralen)
            shape, fortran, dtype, data_off = _read_npy_header(f)
            n_items = 1
            for d in shape:
                n_items *= d
            if dtype.hasobject or dtype.kind in "USV" or n_items == 0:
                # side-car strings / empty columns: tiny, read eagerly
                f.seek(header_offset + 30 + fnlen + extralen)
                arr = np.lib.format.read_array(f, allow_pickle=False)
            else:
                arr = np.memmap(self.path, dtype=dtype, mode="r",
                                offset=data_off, shape=shape,
                                order="F" if fortran else "C")
        self._cache[key] = arr
        return arr

    def __contains__(self, key) -> bool:
        return key in self._members

    def __iter__(self) -> Iterator[str]:
        return iter(self._members)

    def __len__(self) -> int:
        return len(self._members)


def open_npz_mmap(path: str) -> MmapNpz:
    """Open an uncompressed `write_npz` archive for zero-copy reads."""
    return MmapNpz(path)
