"""Encoder-decoder transformer (whisper-tiny backbone).

The conv/audio frontend is a STUB by assignment: the model consumes
precomputed frame embeddings [B, source_len, d_model].  The encoder is a
bidirectional transformer; the decoder adds cross-attention against cached
encoder K/V.  Learned absolute positions (whisper style).
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.distributed.autoshard import constrain_residual
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models.transformer import stack_meta, _maybe_remat, layer_params


def encoder_block_meta(cfg):
    return {"norm1": L.norm_meta(cfg), "attn": attn_mod.attention_meta(cfg),
            "norm2": L.norm_meta(cfg), "mlp": L.mlp_meta(cfg)}


def decoder_block_meta(cfg):
    return {"norm1": L.norm_meta(cfg), "attn": attn_mod.attention_meta(cfg),
            "norm2": L.norm_meta(cfg), "cross": attn_mod.attention_meta(cfg),
            "norm3": L.norm_meta(cfg), "mlp": L.mlp_meta(cfg)}


def model_meta(cfg) -> Dict[str, Any]:
    return {
        "embed": L.embed_meta(cfg),
        "enc_layers": stack_meta(encoder_block_meta(cfg), cfg.encoder_layers),
        "enc_norm": L.norm_meta(cfg),
        "layers": stack_meta(decoder_block_meta(cfg), cfg.num_layers),
        "final_norm": L.norm_meta(cfg),
    }


# --------------------------------------------------------------------------

def encode(cfg, params, frame_embeds, *, remat="none"):
    """Encoder over stub frame embeddings [B, Sm, D]."""
    with jax.named_scope("encoder"):
        x = frame_embeds.astype(jnp.dtype(cfg.compute_dtype))
        B, Sm, _ = x.shape
        pos = jnp.arange(Sm, dtype=jnp.int32)
        pe = jnp.take(params["embed"]["pos_table"], pos, axis=0)
        x = x + pe.astype(x.dtype)[None]

        def body(carry, p):
            xc = constrain_residual(carry)
            h = L.apply_norm(cfg, p["norm1"], xc)
            a = attn_mod.apply_attention(cfg, p["attn"], h, None, causal=False)
            xc = xc + a
            h2 = L.apply_norm(cfg, p["norm2"], xc)
            return constrain_residual(xc + L.apply_mlp(cfg, p["mlp"], h2)), None

        body = _maybe_remat(body, remat)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return L.apply_norm(cfg, params["enc_norm"], x)


def _decoder_layers(cfg, params, x, positions, memory, *, remat="none",
                    collect_cache=False):
    def body(carry, p):
        xc, aux = carry
        xc = constrain_residual(xc)
        h = L.apply_norm(cfg, p["norm1"], xc)
        q, k, v = attn_mod.project_qkv(cfg, p["attn"], h, h, None, None)
        with jax.named_scope("self_attn"):
            out = attn_mod.attend(cfg, q, k, v, causal=True)
            a = jnp.einsum("bsz,zd->bsd", out.reshape(*out.shape[:2], -1),
                           p["attn"]["wo"].astype(xc.dtype))
        xc = xc + a
        h2 = L.apply_norm(cfg, p["norm2"], xc)
        mem_kv = attn_mod.encode_memory_kv(cfg, p["cross"], memory)
        xc = xc + attn_mod.apply_cross_attention(cfg, p["cross"], h2, mem_kv)
        h3 = L.apply_norm(cfg, p["norm3"], xc)
        xc = xc + L.apply_mlp(cfg, p["mlp"], h3)
        cache = {"k": k, "v": v, "cross_k": mem_kv[0], "cross_v": mem_kv[1]} \
            if collect_cache else None
        return (xc, aux), cache

    body = _maybe_remat(body, remat)
    (x, _), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    return x, caches


def forward_hidden(cfg, params, batch, *, attn_impl="auto", remat="none",
                   embed_impl="gather"):
    """Teacher-forced forward to decoder hidden states [B,S,D]."""
    memory = encode(cfg, params, batch["frame_embeds"], remat=remat)
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(cfg, params["embed"], tokens,
                       positions=positions + cfg.source_len, impl=embed_impl)
    x, _ = _decoder_layers(cfg, params, x, positions, memory, remat=remat)
    return L.apply_norm(cfg, params["final_norm"], x), jnp.zeros((), jnp.float32)


def forward(cfg, params, batch, *, attn_impl="auto", remat="none"):
    x, aux = forward_hidden(cfg, params, batch, attn_impl=attn_impl,
                            remat=remat)
    return L.logits_head(cfg, params["embed"], x), aux


def prefill(cfg, params, batch, *, attn_impl="auto", cache_len=None):
    memory = encode(cfg, params, batch["frame_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(cfg, params["embed"], tokens,
                       positions=positions + cfg.source_len)
    x, caches = _decoder_layers(cfg, params, x, positions, memory,
                                collect_cache=True)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.logits_head(cfg, params["embed"], x[:, -1:])
    if cache_len is not None and S < cache_len:
        pad = [(0, 0), (0, 0), (0, cache_len - S), (0, 0), (0, 0)]
        caches = {k: (jnp.pad(v, pad) if k in ("k", "v") else v)
                  for k, v in caches.items()}
    cache_list = [jax.tree.map(lambda a: a[i], caches)
                  for i in range(cfg.num_layers)]
    return logits, cache_list


def decode_step(cfg, params, cache: List[Dict[str, jax.Array]], tokens, pos,
                *, positions=None):
    """One decoder token against self-KV + cached cross-KV."""
    B = tokens.shape[0]
    pos_ids = jnp.full((B, 1), pos, jnp.int32)
    x = L.embed_tokens(cfg, params["embed"], tokens,
                       positions=pos_ids + cfg.source_len)
    new_cache = []
    for li in range(cfg.num_layers):
        p = layer_params(params["layers"], li)
        entry = dict(cache[li])
        with jax.named_scope(f"layer_{li}"):
            h = L.apply_norm(cfg, p["norm1"], x)
            a, entry["k"], entry["v"] = attn_mod.decode_attention(
                cfg, p["attn"], h, entry["k"], entry["v"], pos)
            x = x + a
            h2 = L.apply_norm(cfg, p["norm2"], x)
            c = attn_mod.apply_cross_attention(
                cfg, p["cross"], h2, (entry["cross_k"], entry["cross_v"]))
            x = x + c
            h3 = L.apply_norm(cfg, p["norm3"], x)
            x = x + L.apply_mlp(cfg, p["mlp"], h3)
        new_cache.append(entry)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.logits_head(cfg, params["embed"], x), new_cache
