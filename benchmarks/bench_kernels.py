"""Kernel harness: Pallas flash-attention / mamba-scan vs XLA reference
paths.  On this CPU container the kernels run in interpret mode (correctness
only, not perf); the XLA paths give real wall times and the derived column
carries the v5e analytic expectation (score traffic removed -> memory-bound
attention becomes compute-bound; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import time

import numpy as np


def run():
    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, smoke_config
    from repro.kernels.flash_attention import flash_attention as fa
    from repro.kernels.mamba_scan import mamba_scan as ms
    from repro.kernels.ref import flash_attention_ref, mamba_scan_ref
    from repro.models.attention import attend_blocked, attend_naive

    rows = []
    rng = np.random.default_rng(0)
    cfg = smoke_config(ARCHS["chatglm3-6b"]).replace(head_dim=128)

    # flash attention: correctness delta + XLA path wall times
    B, H, K, S, D = 1, 4, 2, 512, 128
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, K, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, K, S, D)), jnp.float32)
    t0 = time.perf_counter()
    out = fa(q, k, v, causal=True, interpret=True)
    t_interp = (time.perf_counter() - t0) * 1e6
    ref = flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append((f"kernel/flash_attn/B{B}H{H}S{S}D{D}/interpret", t_interp,
                 f"maxerr={err:.1e}"))

    qm = q.transpose(0, 2, 1, 3)
    km = k.transpose(0, 2, 1, 3)
    vm = v.transpose(0, 2, 1, 3)
    for name, fn in (("naive", lambda: attend_naive(cfg, qm, km, vm,
                                                    causal=True)),
                     ("blocked", lambda: attend_blocked(cfg, qm, km, vm,
                                                        causal=True,
                                                        kv_chunk=128))):
        jfn = jax.jit(fn)
        jax.block_until_ready(jfn())
        t0 = time.perf_counter()
        for _ in range(5):
            out = jfn()
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 5 * 1e6
        # v5e derived: score HBM traffic per call for the XLA path
        score_bytes = 2 * B * H * S * S * 4 * (1 if name == "naive" else 2)
        rows.append((f"kernel/xla_attn_{name}/B{B}H{H}S{S}D{D}", us,
                     f"v5e_score_traffic={score_bytes/1e6:.1f}MB"
                     f"(pallas:0MB, stays in VMEM)"))

    # mamba scan
    Bm, Sm, Di, N = 1, 512, 128, 16
    a = jnp.asarray(np.exp(-np.abs(rng.standard_normal((Bm, Sm, Di, N)))),
                    jnp.float32)
    bx = jnp.asarray(rng.standard_normal((Bm, Sm, Di, N)) * 0.1, jnp.float32)
    c = jnp.asarray(rng.standard_normal((Bm, Sm, N)), jnp.float32)
    t0 = time.perf_counter()
    y = ms(a, bx, c, chunk=128, di_block=64, interpret=True)
    t_interp = (time.perf_counter() - t0) * 1e6
    errm = float(jnp.max(jnp.abs(y - mamba_scan_ref(a, bx, c))))
    rows.append((f"kernel/mamba_scan/B{Bm}S{Sm}Di{Di}N{N}/interpret",
                 t_interp, f"maxerr={errm:.1e}"))

    jref = jax.jit(lambda: mamba_scan_ref(a, bx, c))
    jax.block_until_ready(jref())
    t0 = time.perf_counter()
    for _ in range(5):
        y = jref()
    jax.block_until_ready(y)
    us = (time.perf_counter() - t0) / 5 * 1e6
    h_traffic = Bm * Sm * Di * N * 4 * 2
    rows.append((f"kernel/xla_mamba_ref/B{Bm}S{Sm}Di{Di}N{N}", us,
                 f"v5e_h_history_traffic={h_traffic/1e6:.1f}MB"
                 f"(pallas: h stays in VMEM)"))
    return rows
