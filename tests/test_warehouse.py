"""Trace-warehouse layer: tree-reduction merge, deterministic npz,
memory-mapped load, and the `session query` slice CLI.

The invariants pinned here are the ones the fleet workflow leans on:

  * `TraceStore.merge_tree` is `identical` to the flat `merge` for any
    tree shape (associativity of first-seen interning) — property-tested
    over arity/count via the hypothesis shim.
  * `save` is byte-deterministic: saving the same session twice yields
    byte-equal files (the npz writer pins zip member metadata instead of
    inheriting `savez_compressed`'s wall-clock timestamps).
  * `load(mmap=True)` is read-only + copy-on-write: columns adopt
    read-only maps, mutation copies, the file bytes never change, and
    query/diff output is byte-identical to an eager load.
  * `session query` follows the detect/lint CLI contract: exit 0 on
    success (an empty slice is a valid empty answer), 2 on input errors.
"""
import json
import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.persist import open_npz_mmap, write_npz
from repro.core.session import TraceSession, label_meta, parse_slice
from repro.core.store import LazyNames, TraceStore, pack_names
from repro.core.synth import synthetic_trace, write_fleet_dump
from repro.core.topology import MeshSpec

MESH = MeshSpec((2, 4), ("data", "model"))


def host_trace(h: int, step: int = 0, n: int = 80):
    return synthetic_trace(f"host{h:03d}_step{step:03d}", MESH,
                           n_sites=n, seed=h * 7 + step)


def fleet_session(n_hosts: int = 4, steps: int = 1, n: int = 80):
    return TraceSession("fleet", [host_trace(h, s, n)
                                  for h in range(n_hosts)
                                  for s in range(steps)])


# -- tree-reduction merge ----------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(n=st.integers(min_value=1, max_value=13),
       arity=st.integers(min_value=2, max_value=5))
def test_merge_tree_identical_to_flat_merge(n, arity):
    stores = [host_trace(h, n=30).store for h in range(n)]
    flat = TraceStore.merge(stores)
    tree = TraceStore.merge_tree(stores, arity=arity)
    assert tree.identical(flat)
    # ... and through the process pool (falls back to serial when the
    # box can't fork/spawn — same result either way)
    pooled = TraceStore.merge_tree(stores, arity=arity, workers=2)
    assert pooled.identical(flat)


def test_merge_tree_matches_any_manual_bracketing():
    stores = [host_trace(h, n=25).store for h in range(6)]
    flat = TraceStore.merge(stores)
    # a deliberately lopsided shape: ((0,1),2,((3,4),5))
    left = TraceStore.merge([TraceStore.merge(stores[:2]), stores[2]])
    right = TraceStore.merge([TraceStore.merge(stores[3:5]), stores[5]])
    assert TraceStore.merge([left, right]).identical(flat)
    # the serial left fold is a bracketing too
    acc = stores[0]
    for s in stores[1:]:
        acc = TraceStore.merge([acc, s])
    assert acc.identical(flat)


def test_merge_tree_edges():
    with pytest.raises(ValueError):
        TraceStore.merge_tree([TraceStore.empty()], arity=1)
    assert TraceStore.merge_tree([]).n == 0
    solo = host_trace(0, n=20).store
    # single input passes through (the zero-copy slice-merge fast path)
    assert TraceStore.merge_tree([solo]) is solo


# -- deterministic persistence ----------------------------------------------

@pytest.mark.parametrize("ext", ["npz", "json"])
def test_save_twice_is_byte_identical(tmp_path, ext):
    sess = fleet_session(n_hosts=2, n=60)
    p1 = sess.save(str(tmp_path / f"a.{ext}"))
    p2 = sess.save(str(tmp_path / f"b.{ext}"))
    with open(p1, "rb") as f1, open(p2, "rb") as f2:
        assert f1.read() == f2.read()


def test_write_npz_is_np_load_compatible(tmp_path):
    arrs = {
        "floats": np.linspace(0.0, 1.0, 17),
        "codes": np.arange(5, dtype=np.int32),
        "empty": np.zeros(0, dtype=np.int64),
        "fortran": np.asfortranarray(np.arange(6.0).reshape(2, 3)),
        "meta": np.array(json.dumps({"n": 17})),
    }
    for compress in (True, False):
        path = str(tmp_path / f"c{compress}.npz")
        with open(path, "wb") as f:
            write_npz(f, arrs, compress=compress, workers=4)
        with np.load(path) as loaded:
            assert sorted(loaded.files) == sorted(arrs)
            for k, v in arrs.items():
                got = loaded[k]
                assert got.shape == np.asarray(v).shape
                assert np.array_equal(got, v)


# -- mmap load: read-only, copy-on-write, byte-identical answers -------------

def test_mmap_load_is_zero_copy_and_cow(tmp_path):
    sess = fleet_session(n_hosts=3, n=70)
    path = sess.save(str(tmp_path / "fleet.npz"), compress=False)
    before = open(path, "rb").read()

    lazy = TraceSession.load(path, mmap=True)
    eager = TraceSession.load(path)
    store = lazy.get(lazy.labels()[0]).store
    # columns adopt read-only zero-copy views of the file's maps
    # (`np.asarray` drops the memmap subclass but not the mapping) —
    # writing through must be impossible
    col = store.operand_bytes
    assert not col.flags.writeable and not col.flags.owndata
    assert isinstance(col.base, np.memmap) or isinstance(
        getattr(col.base, "base", None), np.memmap)
    with pytest.raises((ValueError, RuntimeError)):
        store.operand_bytes[0] = 1.0

    # a mapped session answers byte-identically to an eager one
    q_lazy = json.dumps(lazy.query(host="00*"), sort_keys=True)
    q_eager = json.dumps(eager.query(host="00*"), sort_keys=True)
    assert q_lazy == q_eager
    d_lazy = lazy.diff("host=001", "host=002", as_json=True)
    d_eager = eager.diff("host=001", "host=002", as_json=True)
    assert d_lazy == d_eager

    # mutation copies: append grows a private buffer, never the file
    extra = host_trace(9, n=15).store
    n0 = store.n
    store.append(extra)
    assert store.n == n0 + extra.n
    assert open(path, "rb").read() == before


def test_mmap_rejects_compressed_and_json(tmp_path):
    sess = fleet_session(n_hosts=2, n=40)
    zp = sess.save(str(tmp_path / "fleet.npz"))       # compressed default
    with pytest.raises(ValueError, match="no-compress"):
        TraceSession.load(zp, mmap=True)
    jp = sess.save(str(tmp_path / "fleet.json"))
    with pytest.raises(ValueError, match="uncompressed"):
        TraceSession.load(jp, mmap=True)


# -- packed names ------------------------------------------------------------

def test_lazy_names_semantics():
    names = ["ar.1", "", "rs.2", "ag.3"]
    lazy = LazyNames(pack_names(names), len(names))
    assert len(lazy) == 4 and list(lazy) == names
    assert lazy[2] == "rs.2"
    assert lazy == names and names == list(lazy)
    assert LazyNames(pack_names([]), 0) == []
    assert LazyNames(pack_names([""]), 1) == [""]
    with pytest.raises(ValueError):
        LazyNames(pack_names(["a", "b"]), 3)._materialize()


def test_pre_warehouse_sidecar_names_still_load():
    store = host_trace(0, n=30).store
    arrs = store.npz_arrays()
    # rewrite the archive the way pre-warehouse sessions stored names:
    # in the JSON side-car, with no packed member
    side = json.loads(str(arrs.pop("meta")))
    side["names"] = list(store.names)
    del arrs["names"]
    arrs["meta"] = np.array(json.dumps(side))
    assert TraceStore.from_npz_arrays(arrs).identical(store)


# -- slice specs and the query layer -----------------------------------------

def test_label_meta_and_parse_slice():
    assert label_meta("host012_step003") == {"host": "012", "step": 3}
    assert label_meta("run5-host7") == {"host": "7"}
    assert label_meta("dp8-baseline") == {}
    assert parse_slice("host=00*,step=1") == {"host": "00*", "step": "1"}
    for bad in ("host=1,port=2", "justaword", "op="):
        with pytest.raises(ValueError):
            parse_slice(bad)


def test_select_rows_match_per_event_reference():
    sess = fleet_session(n_hosts=3, steps=2, n=90)
    sel = sess.select(host="00[01]", step="1", kind="all-reduce*")
    assert sel.labels() == ["host000_step001", "host001_step001"]
    for label in sel.labels():
        ref = sess.get(label)
        want = [e for e in ref.events if e.kind.startswith("all-reduce")]
        got = sel.get(label)
        assert got.store.n == len(want)
        kinds = got.store.kind
        assert all(kinds.vocab[c].startswith("all-reduce")
                   for c in np.asarray(kinds.codes))
    # unfiltered traces are shared, not copied
    assert sess.select(host="*").get("host000_step000").store \
        is sess.get("host000_step000").store


def test_query_totals_match_merged_trace():
    sess = fleet_session(n_hosts=4, n=60)
    payload = sess.query(host="00*", by="kind_link")
    assert payload["traces"] == [f"host{h:03d}_step000" for h in range(4)]
    merged = sess.merged()
    assert payload["sites"] == merged.store.n
    assert payload["totals"]["bytes"] == pytest.approx(
        float(np.sum(merged.store.operand_bytes
                     * merged.store.multiplicity)))
    assert payload["totals"]["time_s"] == pytest.approx(
        merged.total_est_time_s())
    # empty slice: a valid, empty answer — not an error
    empty = sess.query(host="zzz*")
    assert empty["traces"] == [] and empty["sites"] == 0
    assert empty["totals"]["bytes"] == 0.0


def test_fleet_diff_slice_equals_manual_merge():
    sess = fleet_session(n_hosts=4, steps=2, n=70)
    out = json.loads(sess.diff("host=00[01]", "host=00[23]", as_json=True))
    a = TraceSession("a", [sess.get(f"host{h:03d}_step{s:03d}")
                           for h in (0, 1) for s in (0, 1)]).merged()
    b = TraceSession("b", [sess.get(f"host{h:03d}_step{s:03d}")
                           for h in (2, 3) for s in (0, 1)]).merged()
    from repro.core.diff import diff_json
    ref = diff_json(a, b)
    assert out["rows"] == ref["rows"]
    assert out["slice"] == {"a": {"spec": "host=00[01]", "traces": 4},
                            "b": {"spec": "host=00[23]", "traces": 4}}


# -- CLI: exit codes and schema ----------------------------------------------

@pytest.fixture()
def fleet_npz(tmp_path):
    from repro.core.session import _main
    dump = write_fleet_dump(str(tmp_path / "dump"), n_hosts=3, steps=1,
                            sites_per_file=30, seed=0)
    out = str(tmp_path / "fleet.npz")
    assert _main(["ingest", out, *dump, "--mesh", "2,4",
                  "--axes", "data,model", "--no-compress"]) == 0
    return out


def test_query_cli_json_schema_and_exit_codes(fleet_npz, tmp_path, capsys):
    from repro.core.session import _main
    assert _main(["query", fleet_npz, "--host", "00*", "--json",
                  "--mmap"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert set(payload) >= {"session", "slice", "traces", "sites",
                            "totals", "rollup"}
    assert payload["slice"]["host"] == "00*"
    assert payload["rollup"]["by"] == "kind_link"
    assert payload["ingest"]["records"] == 3
    assert payload["ingest"]["degraded"] == 0

    # text mode renders the same slice
    assert _main(["query", fleet_npz, "--host", "00*"]) == 0
    txt = capsys.readouterr().out
    assert "slice host=00*" in txt and "3 trace(s)" in txt

    # empty slice: exit 0 (a valid empty answer, like detect on a clean
    # trace); bad path and compressed-with---mmap: exit 2
    assert _main(["query", fleet_npz, "--host", "zzz*", "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["traces"] == []
    assert _main(["query", str(tmp_path / "nope.npz"), "--json"]) == 2
    compressed = str(tmp_path / "c.npz")
    TraceSession.load(fleet_npz).save(compressed)
    assert _main(["query", compressed, "--mmap"]) == 2
    assert "no-compress" in capsys.readouterr().err


def test_query_and_diff_cli_identical_eager_vs_mmap(fleet_npz, capsys):
    from repro.core.session import _main
    outs = {}
    for flag in ([], ["--mmap"]):
        assert _main(["query", fleet_npz, "--kind", "all-*", "--json",
                      *flag]) == 0
        q = capsys.readouterr().out
        assert _main(["diff", fleet_npz, "host=000", "host=001",
                      "--json", *flag]) == 0
        outs["mmap" if flag else "eager"] = (q, capsys.readouterr().out)
    assert outs["eager"] == outs["mmap"]


def test_report_accepts_slice_spec(fleet_npz, capsys):
    from repro.core.session import _main
    assert _main(["report", fleet_npz, "host=00*", "--format", "json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["label"] == "host=00*"
    assert _main(["report", fleet_npz, "host=zzz"]) == 2
    assert "matches no traces" in capsys.readouterr().err


def test_ingest_records_carry_host_and_step(fleet_npz):
    sess = TraceSession.load(fleet_npz)
    recs = sess.ingest_report.records
    assert [(r.host, r.step) for r in recs] == \
        [(f"{h:03d}", 0) for h in range(3)]
    rt = [type(recs[0]).from_dict(r.to_dict()) for r in recs]
    assert [(r.host, r.step) for r in rt] == [(r.host, r.step)
                                              for r in recs]
