"""Performance-bug detectors — the paper's Fig 7 (NUMA misbinding) analogue.

On an IB/GPU cluster the classic silent misconfiguration is traffic taking a
host detour because of process placement.  On a TPU mesh the analogue is
traffic taking an *axis* detour because of bad PartitionSpecs.  Each detector
inspects the assembled trace and returns human-actionable findings.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List

from repro.core.events import Trace
from repro.core.topology import Hardware, V5E


@dataclass
class Finding:
    detector: str
    severity: str          # info | warn | critical
    message: str
    wasted_bytes: float = 0.0

    def __str__(self):
        return f"[{self.severity}] {self.detector}: {self.message}"


def detect_redundant_gathers(trace: Trace) -> List[Finding]:
    """Same tensor gathered more than once per execution context.

    (ucTrace: repeated identical UCT transfers within one MPI call.)
    """
    seen: Dict[tuple, int] = defaultdict(int)
    bytes_by_key: Dict[tuple, float] = defaultdict(float)
    for e in trace.events:
        if e.kind not in ("all-gather", "all-reduce"):
            continue
        key = (e.kind, e.operand_bytes, e.link_class, e.scope, e.computation)
        seen[key] += 1
        bytes_by_key[key] = e.operand_bytes * e.multiplicity
    out = []
    for key, count in seen.items():
        if count > 1 and key[1] > (1 << 20):
            kind, nbytes, link, scope, comp = key
            wasted = (count - 1) * bytes_by_key[key]
            out.append(Finding(
                "redundant_collective", "warn",
                f"{count}x identical {kind} of {nbytes/1e6:.1f} MB on {link} "
                f"(scope '{scope or '-'}', comp '{comp}') — candidates for CSE "
                f"or re-materialization of the gathered value",
                wasted_bytes=wasted))
    return out


def detect_axis_detours(trace: Trace, expected: Dict[str, str],
                        min_bytes: int = 1 << 20) -> List[Finding]:
    """Collectives spanning mesh axes their semantic class should not touch.

    `expected` maps semantic class -> axis name it should stay on
    (e.g. {"grad_sync": "data", "moe_dispatch": "model"}).  A grad-sync that
    crosses `model`, or TP traffic crossing `pod`, is the sharding analogue
    of NUMA-misbound traffic routed through remote NICs.  Sub-MB payloads
    (scalar metric reductions, grad-norm psums) are exempt.
    """
    out = []
    for e in trace.events:
        want = expected.get(e.semantic)
        if want is None or not e.axes:
            continue
        if e.operand_bytes * e.multiplicity < min_bytes:
            continue
        extra = [a for a in e.axes if a != want]
        if extra:
            out.append(Finding(
                "axis_detour", "warn",
                f"{e.semantic} {e.kind} ({e.operand_bytes/1e6:.1f} MB) spans "
                f"axes {e.axes}, expected only '{want}' — check the "
                f"PartitionSpec feeding scope '{e.scope or '-'}'",
                wasted_bytes=e.operand_bytes * e.multiplicity))
    return out


def detect_eager_floods(trace: Trace, hw: Hardware = V5E,
                        min_count: int = 64) -> List[Finding]:
    """Many tiny latency-bound transfers (the eager-protocol flood).

    (ucTrace Fig 4/6: am_short floods where rendezvous would batch.)
    """
    eager = [e for e in trace.events if e.protocol == "eager"]
    n = sum(e.multiplicity for e in eager)
    if n >= min_count:
        lat = sum(e.est_time_s * e.multiplicity for e in eager)
        return [Finding(
            "eager_flood", "info",
            f"{n} latency-bound collectives/step (< {hw.rndv_threshold/1024:.0f} KiB "
            f"payload/shard), ~{lat*1e6:.0f} us serialized latency — consider "
            f"fusing/batching small collectives or increasing scan body size")]
    return []


def detect_layout_thrash(trace: Trace, threshold_bytes: float = 1 << 30) -> List[Finding]:
    """Heavy transpose/copy traffic around sharded ops (layout mismatch)."""
    tb = trace.op_stats.transpose_bytes
    if tb > threshold_bytes:
        return [Finding(
            "layout_thrash", "info",
            f"{tb/1e9:.2f} GB of transpose/copy traffic "
            f"({trace.op_stats.n_transpose} ops) — review operand layouts or "
            f"einsum dimension orders adjacent to collectives")]
    return []


def detect_cross_pod_bulk(trace: Trace) -> List[Finding]:
    """Bulk traffic on the slow inter-pod DCI that could stay intra-pod."""
    out = []
    dci = [e for e in trace.events if e.link_class.startswith(("dci", "xpod"))]
    total = sum(e.total_wire_bytes * e.multiplicity for e in dci)
    if total > 1 << 30:
        out.append(Finding(
            "cross_pod_bulk", "warn",
            f"{total/1e9:.2f} GB/step crosses the inter-pod DCI "
            f"({len(dci)} collectives) — hierarchical reduction "
            f"(in-pod reduce-scatter, cross-pod exchange of 1/pod_size) or "
            f"gradient compression recommended"))
    return out


def run_all(trace: Trace, expected_axes: Dict[str, str] | None = None,
            hw: Hardware = V5E) -> List[Finding]:
    findings = []
    findings += detect_redundant_gathers(trace)
    if expected_axes:
        findings += detect_axis_detours(trace, expected_axes)
    findings += detect_eager_floods(trace, hw)
    findings += detect_layout_thrash(trace)
    findings += detect_cross_pod_bulk(trace)
    return findings
