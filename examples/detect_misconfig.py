"""Fig 7 walkthrough: catch a silent sharding misconfiguration.

    PYTHONPATH=src python examples/detect_misconfig.py

Two numerically-identical programs; one has a stale sharding annotation on
alternate layers.  Both compile and train fine — only the traced wire
pattern shows that activations ping-pong across the mesh every layer.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import MeshSpec, detect, trace_from_hlo
from repro.core.report import top_contenders_table

L, B, S, D, F = 8, 8, 256, 512, 1024


def make_step(mesh, bug: bool):
    good = NamedSharding(mesh, P("data", None, None))
    bad = NamedSharding(mesh, P("model", None, None))

    def step(w1, w2, x):
        h = x
        for i in range(L):
            with jax.named_scope("layer"):
                h = jax.lax.with_sharding_constraint(
                    h, bad if (bug and i % 2 == 1) else good)
                with jax.named_scope("mlp"):
                    z = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, w1[i]))
                    h = h + jnp.einsum("bsf,fd->bsd", z, w2[i])
        return (h.astype(jnp.float32) ** 2).mean()
    return step


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    spec = MeshSpec((2, 4), ("data", "model"))
    for label in ("good", "bad"):
        g = jax.jit(jax.value_and_grad(make_step(mesh, label == "bad"),
                                       argnums=(0, 1)),
                    in_shardings=(NamedSharding(mesh, P(None, None, "model")),
                                  NamedSharding(mesh, P(None, "model", None)),
                                  NamedSharding(mesh, P("data", None, None))))
        with mesh:
            compiled = g.lower(
                jax.ShapeDtypeStruct((L, D, F), jnp.bfloat16),
                jax.ShapeDtypeStruct((L, F, D), jnp.bfloat16),
                jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16)).compile()
        tr = trace_from_hlo(compiled.as_text(), spec, label=label)
        print(f"\n=== {label} config ===")
        print(top_contenders_table(tr))
        print(f"modeled collective time: {tr.total_est_time_s()*1e6:.0f} us, "
              f"wire {tr.total_wire_bytes()/1e6:.1f} MB")
        for f in detect.run_all(tr, expected_axes={"grad_sync": "data",
                                                   "ffn": "model"})[:5]:
            print(" ", f)


if __name__ == "__main__":
    main()
