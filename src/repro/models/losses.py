"""Loss functions. Cross-entropy is chunked over sequence so the fp32
log-softmax never materializes a full [B, S, V] fp32 tensor (matters for
128k–262k vocabs at 4k seq)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _xent_block(logits, targets, mask):
    """logits [B,C,V] (any float), targets [B,C] int, mask [B,C] -> (sum, cnt)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    return nll.sum(), mask.sum()


def cross_entropy(logits: jax.Array, targets: jax.Array, mask=None,
                  chunk: int = 512):
    """Mean token NLL. logits [B,S,V], targets [B,S]."""
    B, S, V = logits.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mask = mask.astype(jnp.float32)
    with jax.named_scope("loss"):
        if S * V <= (1 << 23) or S % chunk:
            tot, cnt = _xent_block(logits, targets, mask)
            return tot / jnp.maximum(cnt, 1.0)
        n = S // chunk
        resh = lambda t: t.reshape(B, n, chunk, *t.shape[2:]).swapaxes(0, 1)

        def step(carry, blk):
            tot, cnt = carry
            lg, tg, mk = blk
            t, c = _xent_block(lg, tg, mk)
            return (tot + t, cnt + c), None

        (tot, cnt), _ = jax.lax.scan(
            step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (resh(logits), resh(targets), resh(mask)))
        return tot / jnp.maximum(cnt, 1.0)


def fused_lm_head_loss(cfg, embed_params, hidden, targets, mask=None,
                       chunk: int = 512):
    """LM head + cross-entropy fused per sequence chunk under remat.

    Avoids ever materializing [B, S, V] logits (6.8 GB/device for whisper's
    51865 vocab at 4k seq x batch 16, 3x that with fp32 copies): each chunk
    computes its logits, reduces to (nll_sum, count), and is rematerialized
    in the backward pass.
    """
    from repro.distributed.autoshard import constrain
    B, S, D = hidden.shape
    table = embed_params["in_table"].T if cfg.tie_embeddings \
        else embed_params["out_head"]
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mask = mask.astype(jnp.float32)
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk
    resh = lambda t: t.reshape(B, n, chunk, *t.shape[2:]).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_step(carry, blk):
        tot, cnt = carry
        x_c, t_c, m_c = blk
        with jax.named_scope("logits"):
            logits = jnp.einsum("bcd,dv->bcv", x_c, table.astype(x_c.dtype))
            logits = constrain(logits, ("batch", None, "model"))
        t, c = _xent_block(logits, t_c, m_c)
        return (tot + t, cnt + c), None

    with jax.named_scope("loss"):
        (tot, cnt), _ = jax.lax.scan(
            chunk_step,
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (resh(hidden), resh(targets), resh(mask)))
        return tot / jnp.maximum(cnt, 1.0)


def fused_next_token_loss(cfg, embed_params, hidden, batch, aux):
    """Family-aware next-token loss on final hidden states [B,S,D].

    Targets are rolled (not sliced) so the chunked head keeps a
    power-of-two sequence length; the final position is masked out.
    """
    tokens = batch["tokens"]
    B, S, _ = hidden.shape
    if cfg.family == "vlm":
        n_img = S - tokens.shape[1]
        h = hidden[:, n_img:]
    else:
        h = hidden
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones(tokens.shape, jnp.float32).at[:, -1].set(0.0)
    loss = fused_lm_head_loss(cfg, embed_params, h, targets, mask)
    if cfg.num_experts:
        loss = loss + cfg.router_aux_coef * aux / max(cfg.num_layers, 1)
    return loss


def lm_loss(cfg, logits, batch, aux):
    """Next-token LM loss (+ MoE aux) with family-specific masking."""
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        # logits cover [patches | text]; predict text tokens only.
        n_img = logits.shape[1] - tokens.shape[1]
        text_logits = logits[:, n_img:-1]
        loss = cross_entropy(text_logits, tokens[:, 1:])
    else:
        loss = cross_entropy(logits[:, :-1], tokens[:, 1:])
    if cfg.num_experts:
        loss = loss + cfg.router_aux_coef * aux / max(cfg.num_layers, 1)
    return loss
