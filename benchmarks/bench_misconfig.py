"""Fig 7 analogue: detecting a sharding misconfiguration.

The paper catches a NUMA misbinding that silently routed GPU traffic through
host processes (~5x slowdown).  The TPU analogue we reproduce: **inconsistent
activation annotations** — a copy-pasted `with_sharding_constraint` puts
alternate layers' residuals on different mesh axes, so every layer boundary
re-shards the activations across the full mesh.  The program is numerically
identical and compiles clean; only the traced wire pattern exposes the bug.
"""
from __future__ import annotations

import json

from _util import run_worker

WORKER = """
import json
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import MeshSpec, trace_from_hlo, detect

D_AX, M_AX = 2, 4
mesh = jax.make_mesh((D_AX, M_AX), ("data", "model"))
spec = MeshSpec((D_AX, M_AX), ("data", "model"))
L, B, S, D, F = 8, 8, 256, 512, 1024

def make_step(bug: bool):
    good = NamedSharding(mesh, P("data", None, None))
    bad = NamedSharding(mesh, P("model", None, None))
    def step(w1, w2, x):
        h = x
        for i in range(L):   # unrolled: static per-layer annotations
            with jax.named_scope("layer"):
                # stale copy-pasted annotation on alternate layers
                sh = bad if (bug and i % 2 == 1) else good
                h = jax.lax.with_sharding_constraint(h, sh)
                with jax.named_scope("mlp"):
                    z = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, w1[i]))
                    h = h + jnp.einsum("bsf,fd->bsd", z, w2[i])
        with jax.named_scope("loss"):
            return (h.astype(jnp.float32) ** 2).mean()
    return step

rows = {}
out_rows = []
for label in ("good", "bad"):
    step = make_step(label == "bad")
    g = jax.jit(jax.value_and_grad(step, argnums=(0, 1)),
                in_shardings=(NamedSharding(mesh, P(None, None, "model")),
                              NamedSharding(mesh, P(None, "model", None)),
                              NamedSharding(mesh, P("data", None, None))))
    with mesh:
        compiled = g.lower(
            jax.ShapeDtypeStruct((L, D, F), jnp.bfloat16),
            jax.ShapeDtypeStruct((L, F, D), jnp.bfloat16),
            jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16)).compile()
    tr = trace_from_hlo(compiled.as_text(), spec, label=label,
                        cost_analysis=compiled.cost_analysis())
    finds = detect.run_all(tr, expected_axes={"grad_sync": "data",
                                              "ffn": "model"})
    rows[label] = tr
    out_rows.append((f"misconfig/{label}", tr.total_est_time_s() * 1e6,
                     f"wireMB={tr.total_wire_bytes()/1e6:.1f}|"
                     f"collectives={sum(e.multiplicity for e in tr.events)}|"
                     f"findings={len(finds)}"))
    for f in finds[:3]:
        print(f"  [{label}] {f}")
slow = rows["bad"].total_est_time_s() / max(rows["good"].total_est_time_s(), 1e-12)
wire_ratio = rows["bad"].total_wire_bytes() / max(rows["good"].total_wire_bytes(), 1e-12)
out_rows.append(("misconfig/modeled_slowdown", slow,
                 f"wire_ratio={wire_ratio:.1f}|bad/good collective-time ratio "
                 f"(paper: ~5x for NUMA misbinding)"))
print("JSON" + json.dumps(out_rows))
"""


def run():
    out = run_worker(WORKER, devices=8)
    print("\n".join(l for l in out.splitlines() if not l.startswith("JSON")))
    for line in out.splitlines():
        if line.startswith("JSON"):
            return [tuple(r) for r in json.loads(line[4:])]
    raise RuntimeError("no JSON output from worker")
