"""Render the dry-run sweep JSONs into the EXPERIMENTS.md roofline tables."""
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def load(path):
    with open(os.path.join(HERE, path)) as f:
        rows = json.load(f)
    # keep the LAST occurrence per (arch, shape) — reruns override
    seen = {}
    for r in rows:
        seen[(r["arch"], r["shape"])] = r
    return seen


def fmt(rows, title):
    out = [f"### {title}", "",
           "| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL_GF | useful | mfu_bound | mem GB | fits 16G | coll/step |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    order = ["whisper-tiny", "falcon-mamba-7b", "mixtral-8x22b",
             "qwen3-moe-235b-a22b", "chatglm3-6b", "llama3-405b",
             "gemma3-4b", "h2o-danube-3-4b", "hymba-1.5b", "qwen2-vl-2b"]
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for arch in order:
        for shape in shapes:
            r = rows.get((arch, shape))
            if r is None:
                continue
            if "skipped" in r:
                out.append(f"| {arch} | {shape} | — | — | — | SKIP | — | — | "
                           f"— | — | — | {r['skipped'][:46]} |")
                continue
            if "failed" in r:
                out.append(f"| {arch} | {shape} | FAIL | | | | | | | | | "
                           f"{r['failed'][:40]} |")
                continue
            out.append(
                f"| {arch} | {shape} | {r['compute_ms']/1e3:.3f} | "
                f"{r['memory_ms']/1e3:.3f} | {r['collective_ms']/1e3:.3f} | "
                f"**{r['dominant'][:4]}** | {r['model_gflops']:.0f} | "
                f"{r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} | "
                f"{r['mem_model_gb']:.1f} | {'Y' if r['fits_hbm'] else 'N'} | "
                f"{r['n_collectives']} |")
    out.append("")
    return "\n".join(out)


if __name__ == "__main__":
    for path, title in (("sweep.json", "Single-pod 16x16 (256 chips)"),
                        ("sweep_multipod.json",
                         "Multi-pod 2x16x16 (512 chips)")):
        if os.path.exists(os.path.join(HERE, path)):
            print(fmt(load(path), title))
