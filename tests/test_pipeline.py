"""Pipeline parallelism: numerical equivalence + traced signature."""
import pytest

from repro.distributed.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(1, 4) == pytest.approx(3 / 4)
    assert bubble_fraction(16, 4) == pytest.approx(3 / 19)
    assert bubble_fraction(64, 2) == pytest.approx(1 / 65)


def test_pipeline_matches_sequential(subproc):
    out = subproc("""
import jax
import jax.numpy as jnp
import numpy as np
from repro.distributed.pipeline import pipeline_apply
from repro.core import MeshSpec, trace_from_hlo

P_STAGES, M, MB, D = 4, 6, 2, 32
mesh = jax.make_mesh((4,), ("model",))
rng = np.random.default_rng(0)
w = jnp.asarray(rng.standard_normal((P_STAGES, D, D)) * 0.3, jnp.float32)
x = jnp.asarray(rng.standard_normal((M, MB, D)), jnp.float32)

def stage(wi, h):
    return jnp.tanh(h @ wi)

fn = jax.jit(lambda w, x: pipeline_apply(stage, w, x, mesh, axis="model"))
with mesh:
    compiled = fn.lower(w, x).compile()
y = fn(w, x)

# sequential reference
ref = x
for i in range(P_STAGES):
    ref = jnp.tanh(ref @ w[i])
err = float(jnp.max(jnp.abs(y - ref)))
assert err < 1e-5, err

# trace signature: collective-permute chain classified as pipeline traffic
spec = MeshSpec((4,), ("model",))
tr = trace_from_hlo(compiled.as_text(), spec, label="pipe")
perms = [e for e in tr.events if e.kind == "collective-permute"]
assert perms, "no collective-permute in pipeline trace"
assert any(e.semantic == "pipeline" for e in tr.events), \
    {e.semantic for e in tr.events}
n_hops = sum(e.multiplicity for e in perms)
assert n_hops >= M + 4 - 2   # one hop per tick (final hop is DCE'd)
print("PIPELINE_OK", err, n_hops)
""", devices=4)
    assert "PIPELINE_OK" in out
