"""Trace diffing — the before/after workflow of the paper's case studies.

ucTrace's users compare runs (eager vs rndv configs, NUMA-aware vs not,
OMPI vs MPICH).  `diff_traces` aligns two traces by traffic class and
reports byte/count/time deltas, new/vanished classes, and a verdict line
per class — so "what did my change do to communication?" is one function
call on two compiled artifacts.

`diff_n` generalizes the alignment to N traces (the paper's "Allreduce
across MPI libraries / UCX settings" shape): one row per traffic class,
one column per trace, rendered by `report.session_table`.

Alignment is *code-aligned* by default: every trace rolls up once over
its interned categorical codes, the per-trace label tables are merged
into one union vocabulary (`store.union_rollup`), and bytes/count/time
scatter into a `(n_keys, n_traces)` matrix — no string-keyed dicts on
the N-trace hot path, so session diffs stay cheap at 100k+ sites.  The
dict-aligned per-event walk is retained as `engine="rows"`, the
reference the columnar rows are pinned byte-identical to by
tests/test_render.py.

Besides the class-level keys, `by="site"` aligns on the interned
op_name x kind x axes triple — one row per compiled callsite class —
so a regression shows up against the op_name that produced it instead
of washing out in a kind x link rollup.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import Trace, site_key
from repro.core.store import union_rollup

# per-event key functions, one per alignment mode — the dict-aligned
# reference (`engine="rows"`) and the columnar `TraceStore._codes_for`
# must key identically, label for label.
KEY_FNS = {
    "kind_link": lambda e: f"{e.kind}|{e.link_class}",
    "semantic": lambda e: e.semantic or "other",
    "site": site_key,
    "sem_kind_link": lambda e: f"{e.semantic}|{e.kind}|{e.link_class}",
}


def _norm_by(by: str) -> str:
    # historic behavior: any unknown key meant the 3-way class rollup
    return by if by in KEY_FNS else "sem_kind_link"


def _agg(trace: Trace, by: str) -> Dict[str, Dict[str, float]]:
    """Per-event reference aggregation (one dict walk over the rows)."""
    return trace.by(KEY_FNS[_norm_by(by)])


def _aligned(traces: Sequence[Trace], by: str
             ) -> Tuple[List[str], np.ndarray]:
    """Union keys (alphabetical) + (4, n_keys, n_traces) metric tensor.

    Key order matches the reference's `sorted(set(...))`, so a stable
    sort by any metric afterwards ties off identically on both paths.
    """
    union, mats = union_rollup([t.store for t in traces], _norm_by(by))
    if not union:
        return [], mats
    order = np.argsort(np.asarray(union))
    return [union[int(i)] for i in order], mats[:, order, :]


@dataclass
class DiffRow:
    key: str
    bytes_a: float
    bytes_b: float
    count_a: float
    count_b: float
    time_a: float
    time_b: float

    @property
    def bytes_ratio(self) -> float:
        if self.bytes_a == 0:
            return float("inf") if self.bytes_b else 1.0
        return self.bytes_b / self.bytes_a

    def verdict(self, threshold: float = 0.1) -> str:
        r = self.bytes_ratio
        if self.bytes_a == 0 and self.bytes_b > 0:
            return "NEW"
        if self.bytes_b == 0 and self.bytes_a > 0:
            return "GONE"
        if r > 1 + threshold:
            return f"GREW {r:.2f}x"
        if r < 1 - threshold:
            return f"SHRANK {1/r:.2f}x"
        return "~same"


def diff_traces(a: Trace, b: Trace, by: str = "kind_link",
                engine: str = "columnar") -> List[DiffRow]:
    """Align two traces by traffic class, sorted by |byte delta|."""
    if engine == "rows":
        agg_a = _agg(a, by)
        agg_b = _agg(b, by)
        zero = {"bytes": 0.0, "count": 0.0, "time_s": 0.0}
        rows = []
        for key in sorted(set(agg_a) | set(agg_b)):
            ra = agg_a.get(key, zero)
            rb = agg_b.get(key, zero)
            rows.append(DiffRow(key, ra["bytes"], rb["bytes"], ra["count"],
                                rb["count"], ra["time_s"], rb["time_s"]))
        rows.sort(key=lambda r: -(abs(r.bytes_b - r.bytes_a)))
        return rows
    keys, mats = _aligned((a, b), by)
    if not keys:
        return []
    bm, cm, tm = mats[0], mats[2], mats[3]
    order = np.argsort(-np.abs(bm[:, 1] - bm[:, 0]), kind="stable")
    return [DiffRow(keys[i], float(bm[i, 0]), float(bm[i, 1]),
                    float(cm[i, 0]), float(cm[i, 1]),
                    float(tm[i, 0]), float(tm[i, 1]))
            for i in (int(j) for j in order)]


def _filter_rows(rows: List[DiffRow], top: Optional[int] = None,
                 only_regressed: bool = False) -> List[DiffRow]:
    """Row filter shared by the rendered and JSON diff outputs.

    `only_regressed` keeps classes that grew past the verdict threshold
    or are new in B; `top` then truncates to the N largest |byte delta|
    (the rows are already delta-sorted by `diff_traces`).
    """
    if only_regressed:
        rows = [r for r in rows
                if r.verdict() == "NEW" or r.verdict().startswith("GREW")]
    if top is not None:
        rows = rows[:max(top, 0)]
    return rows


def diff_json(a: Trace, b: Trace, by: str = "kind_link",
              top: Optional[int] = None,
              only_regressed: bool = False,
              extra: Optional[Dict[str, object]] = None
              ) -> Dict[str, object]:
    """Machine-readable pairwise diff (the tooling-facing sibling of
    `render_diff`): one dict per aligned row plus modeled-time totals.

    `bytes_ratio` is `null` for rows new in B (the rendered verdict says
    NEW; infinity is not valid JSON).  `extra`, when given, lands under
    a `slice` key — the session layer uses it to record the fleet slice
    specs each side was merged from.
    """
    rows = _filter_rows(diff_traces(a, b, by), top, only_regressed)
    ta, tb = a.total_est_time_s(), b.total_est_time_s()
    payload: Dict[str, object] = {
        "a": a.label,
        "b": b.label,
        "by": _norm_by(by),
        "top": top,
        "only_regressed": only_regressed,
        "total_time_a_s": ta,
        "total_time_b_s": tb,
        "rows": [{
            "key": r.key,
            "bytes_a": r.bytes_a, "bytes_b": r.bytes_b,
            "count_a": r.count_a, "count_b": r.count_b,
            "time_a_s": r.time_a, "time_b_s": r.time_b,
            "bytes_ratio": None if (r.bytes_a == 0 and r.bytes_b > 0)
            else r.bytes_ratio,
            "verdict": r.verdict(),
        } for r in rows],
    }
    if extra is not None:
        payload["slice"] = extra
    return payload


def render_diff(a: Trace, b: Trace, by: str = "kind_link",
                top: Optional[int] = None,
                only_regressed: bool = False) -> str:
    rows = _filter_rows(diff_traces(a, b, by), top, only_regressed)
    mode = by + (", regressed only" if only_regressed else "") \
        + (f", top {top}" if top is not None else "")
    lines = [f"trace diff: '{a.label}' -> '{b.label}'  (by {mode})",
             f"{'key':42s} {'GB a':>9s} {'GB b':>9s} {'cnt a':>7s} "
             f"{'cnt b':>7s} {'ms a':>8s} {'ms b':>8s}  verdict"]
    for r in rows:
        lines.append(
            f"{r.key:42s} {r.bytes_a/1e9:9.3f} {r.bytes_b/1e9:9.3f} "
            f"{int(r.count_a):7d} {int(r.count_b):7d} "
            f"{r.time_a*1e3:8.2f} {r.time_b*1e3:8.2f}  {r.verdict()}")
    ta, tb = a.total_est_time_s(), b.total_est_time_s()
    lines.append(f"{'TOTAL modeled collective time':42s} "
                 f"{'':9s} {'':9s} {'':7s} {'':7s} "
                 f"{ta*1e3:8.2f} {tb*1e3:8.2f}  "
                 f"{'%.2fx' % (tb/ta) if ta else 'n/a'}")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# n-way alignment (session comparisons)
# --------------------------------------------------------------------------

@dataclass
class NWayRow:
    """One traffic class aligned across N traces."""

    key: str
    bytes_: List[float]
    counts: List[float]
    times: List[float]

    @property
    def max_bytes(self) -> float:
        return max(self.bytes_)

    @property
    def spread(self) -> float:
        """max/min byte ratio over traces where the class exists (>=1)."""
        present = [b for b in self.bytes_ if b > 0]
        if not present:
            return 1.0
        return max(present) / min(present)

    def verdict(self, threshold: float = 0.1) -> str:
        present = sum(1 for b in self.bytes_ if b > 0)
        if present < len(self.bytes_):
            return f"in {present}/{len(self.bytes_)}"
        r = self.spread
        return f"varies {r:.2f}x" if r > 1 + threshold else "~same"


def diff_n(traces: Sequence[Trace], by: str = "kind_link",
           engine: str = "columnar") -> List[NWayRow]:
    """Align N traces by traffic class; rows sorted by peak bytes."""
    traces = list(traces)
    if engine == "rows":
        aggs = [_agg(t, by) for t in traces]
        keys = sorted(set().union(*aggs)) if aggs else []
        zero = {"bytes": 0.0, "count": 0.0, "time_s": 0.0}
        rows = [NWayRow(key=k,
                        bytes_=[a.get(k, zero)["bytes"] for a in aggs],
                        counts=[a.get(k, zero)["count"] for a in aggs],
                        times=[a.get(k, zero)["time_s"] for a in aggs])
                for k in keys]
        rows.sort(key=lambda r: -r.max_bytes)
        return rows
    if not traces:
        return []
    keys, mats = _aligned(traces, by)
    if not keys:
        return []
    bm, cm, tm = mats[0], mats[2], mats[3]
    order = np.argsort(-bm.max(axis=1), kind="stable")
    return [NWayRow(key=keys[i], bytes_=bm[i].tolist(),
                    counts=cm[i].tolist(), times=tm[i].tolist())
            for i in (int(j) for j in order)]
