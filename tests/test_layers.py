"""Layer-level unit tests: RoPE variants, norms, embeddings, losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import ARCHS, smoke_config
from repro.models import layers as L
from repro.models.meta import materialize


def _cfg(**kw):
    return smoke_config(ARCHS["chatglm3-6b"]).replace(**kw)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def test_rope_is_rotation():
    """RoPE preserves norms (pure rotation) and position-0 is identity."""
    cfg = _cfg(rope="standard", rope_fraction=1.0)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, 4, 16)),
                    jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    y = L.apply_rope(cfg, x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    y0 = L.apply_rope(cfg, x, jnp.zeros((2, 8), jnp.int32))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(x), atol=1e-6)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n (the defining property)."""
    cfg = _cfg(rope="standard", rope_fraction=1.0)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)

    def dot_at(m, n):
        qm = L.apply_rope(cfg, q, jnp.full((1, 1), m, jnp.int32))
        kn = L.apply_rope(cfg, k, jnp.full((1, 1), n, jnp.int32))
        return float(jnp.sum(qm * kn))

    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(7, 7) == pytest.approx(dot_at(0, 0), rel=1e-4)


def test_partial_rope_leaves_pass_dims():
    """chatglm 2d rope rotates only the first half of head_dim."""
    cfg = _cfg(rope="partial", rope_fraction=0.5)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((1, 4, 2, 16)),
                    jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32), (1, 4))
    y = L.apply_rope(cfg, x, pos)
    np.testing.assert_array_equal(np.asarray(y[..., 8:]),
                                  np.asarray(x[..., 8:]))
    assert not np.allclose(np.asarray(y[..., :8])[0, 1:],
                           np.asarray(x[..., :8])[0, 1:])


def test_mrope_sections_equal_std_rope_when_positions_identical():
    """With t==h==w position ids, M-RoPE == standard RoPE."""
    cfg = _cfg(rope="mrope", mrope_sections=(4, 2, 2))   # head_dim 16
    x = jnp.asarray(np.random.default_rng(3).standard_normal((1, 6, 2, 16)),
                    jnp.float32)
    pos1 = jnp.broadcast_to(jnp.arange(6, dtype=jnp.int32), (1, 6))
    pos3 = jnp.broadcast_to(pos1, (3, 1, 6))
    y_m = L.apply_rope(cfg, x, pos3)
    y_s = L.apply_rope(cfg.replace(rope="standard", rope_fraction=1.0),
                       x, pos1)
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_s), atol=2e-5)


def test_mrope_distinct_axes_differ():
    cfg = _cfg(rope="mrope", mrope_sections=(4, 2, 2))
    x = jnp.ones((1, 4, 1, 16), jnp.float32)
    same = jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32), (3, 1, 4))
    mixed = same.at[1].set(same[1] * 2)
    assert not np.allclose(np.asarray(L.apply_rope(cfg, x, same)),
                           np.asarray(L.apply_rope(cfg, x, mixed)))


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 100), d=st.sampled_from([16, 64, 128]))
@settings(max_examples=15, deadline=None)
def test_rmsnorm_properties(seed, d):
    cfg = _cfg(norm="rmsnorm")
    p = {"scale": jnp.ones((d,))}
    x = jnp.asarray(np.random.default_rng(seed).standard_normal((2, 3, d)) * 5,
                    jnp.float32)
    y = L.apply_norm(cfg, p, x)
    ms = np.mean(np.square(np.asarray(y)), axis=-1)
    np.testing.assert_allclose(ms, 1.0, rtol=5e-2)      # unit RMS
    # scale equivariance
    y2 = L.apply_norm(cfg, p, x * 7.0)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y), rtol=5e-2,
                               atol=5e-2)


def test_layernorm_matches_numpy():
    cfg = _cfg(norm="layernorm")
    d = 32
    p = {"scale": jnp.full((d,), 1.5), "bias": jnp.full((d,), 0.25)}
    x = jnp.asarray(np.random.default_rng(5).standard_normal((2, 4, d)),
                    jnp.float32)
    y = np.asarray(L.apply_norm(cfg, p, x))
    xn = np.asarray(x)
    ref = (xn - xn.mean(-1, keepdims=True)) / np.sqrt(
        xn.var(-1, keepdims=True) + 1e-6) * 1.5 + 0.25
    np.testing.assert_allclose(y, ref, atol=2e-3)


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------

def test_onehot_embed_matches_gather():
    cfg = _cfg()
    params = materialize(L.embed_meta(cfg), jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(6).integers(
        0, cfg.vocab_size, (2, 24)), jnp.int32)
    a = L.embed_tokens(cfg, params, toks, impl="gather")
    b = L.embed_tokens(cfg, params, toks, impl="onehot")
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-2)


# --------------------------------------------------------------------------
# trace diff (paper before/after workflow)
# --------------------------------------------------------------------------

def test_trace_diff():
    from repro.core import costmodel, attribution
    from repro.core.diff import diff_traces, render_diff
    from repro.core.events import CollectiveEvent, Trace
    from repro.core.topology import MeshSpec, V5E

    mesh = MeshSpec((2, 4), ("data", "model"))

    def mk(nbytes, kind="all-reduce", mult=1):
        ev = CollectiveEvent(
            name="x", kind=kind, async_start=False, operand_bytes=nbytes,
            result_bytes=nbytes, dtype="bf16",
            replica_groups=[[0, 1, 2, 3], [4, 5, 6, 7]], group_size=4,
            num_groups=2, op_name="jit(f)/layer/mlp/psum",
            computation="main", multiplicity=mult)
        costmodel.annotate_event(ev, mesh, V5E)
        attribution.attribute_event(ev)
        return ev

    a = Trace("before", mesh.shape, mesh.axes, 8, [mk(1 << 24), mk(1 << 20)])
    b = Trace("after", mesh.shape, mesh.axes, 8,
              [mk(1 << 23), mk(1 << 20, kind="all-gather")])
    rows = diff_traces(a, b)
    by_key = {r.key: r for r in rows}
    ar = by_key["all-reduce|ici.model"]
    assert ar.verdict().startswith("SHRANK")
    assert by_key["all-gather|ici.model"].verdict() == "NEW"
    txt = render_diff(a, b)
    assert "SHRANK" in txt and "NEW" in txt and "TOTAL" in txt


def test_overlapped_bound_leq_serialized():
    from repro.core import costmodel
    from repro.core.events import CollectiveEvent, Trace
    from repro.core.topology import MeshSpec, V5E
    mesh = MeshSpec((2, 4), ("data", "model"))
    evs = []
    for i, groups in enumerate(([[0, 1, 2, 3], [4, 5, 6, 7]],
                                [[0, 4], [1, 5], [2, 6], [3, 7]])):
        ev = CollectiveEvent(
            name=f"e{i}", kind="all-reduce", async_start=False,
            operand_bytes=1 << 22, result_bytes=1 << 22, dtype="f32",
            replica_groups=groups, group_size=len(groups[0]),
            num_groups=len(groups), op_name="", computation="main")
        costmodel.annotate_event(ev, mesh, V5E)
        evs.append(ev)
    tr = Trace("t", mesh.shape, mesh.axes, 8, evs)
    assert tr.overlapped_est_time_s() <= tr.total_est_time_s()
    assert tr.overlapped_est_time_s() > 0
