"""Per-(arch x shape) execution settings: gradient-accumulation factor,
remat policy, attention impl.  Derived from HBM napkin math (v5e 16 GB):
activation checkpoints per layer must fit next to FSDP-sharded params +
optimizer moments.  Overridable from the CLI.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class StepSettings:
    accum: int = 1                 # gradient-accumulation microbatches
    remat: str = "full"            # none | dots | full
    attn_impl: str = "auto"        # auto | naive | blocked | pallas
    opt_state_dtype: str = "float32"
    accum_dtype: str = "float32"   # gradient-accumulator dtype
    seq_shard: bool = False        # Megatron-SP residual sequence sharding
    moe_group_size: int = 512
    # beyond-paper optimization toggles (see EXPERIMENTS.md §Perf)
    grad_compression: str = "none"   # none | bf16
    moe_dispatch: str = "einsum"     # einsum | sort
    # serving weight placement: None = auto (FSDP iff weights don't fit
    # replicated-over-data), True/False forces it
    serve_fsdp: "bool | None" = None
    # HSDP: shard params intra-pod, replicate across pods (multi-pod only)
    hsdp: bool = False


# train_4k accumulation per arch (per-device checkpoint-bytes bound)
_TRAIN_ACCUM = {
    "llama3-405b": 16,
    "mixtral-8x22b": 16,
    "qwen3-moe-235b-a22b": 16,
    "falcon-mamba-7b": 8,
    "chatglm3-6b": 4,
    "gemma3-4b": 4,
    "h2o-danube-3-4b": 4,
    "hymba-1.5b": 2,
    "qwen2-vl-2b": 2,
    "whisper-tiny": 1,
}

# frontier configs: bf16 moments + bf16 grad accumulation + SP residuals
# (fp32 everything needs >16 GB/dev on one 256-chip pod)
_BIG = ("llama3-405b", "qwen3-moe-235b-a22b", "mixtral-8x22b")


def settings_for(arch: str, shape_name: str) -> StepSettings:
    if shape_name == "train_4k":
        big = arch in _BIG
        return StepSettings(
            accum=_TRAIN_ACCUM.get(arch, 4),
            remat="full",
            opt_state_dtype="bfloat16" if big else "float32",
            accum_dtype="bfloat16" if big else "float32",
            # SP residual sharding (Megatron-SP) was explored for every big
            # arch and REFUTED by the tracer: the per-layer AG/RS exchange
            # multiplies the collective term 5-10x on this mesh (MoE group
            # reshapes and SSM chunk scans re-gather besides) — see
            # EXPERIMENTS.md §Perf hypothesis H2.  Saves stay batch-sharded.
            seq_shard=False,
        )
    # serving shapes: no accumulation/remat
    return StepSettings(accum=1, remat="none")
