"""Pallas TPU kernels for the framework's compute hot-spots.

flash_attention.py  — online-softmax attention, VMEM-resident scores,
                      causal/sliding-window block skipping, GQA index maps
mamba_scan.py       — chunked selective scan, recurrent state in VMEM
ops.py              — jitted wrappers (layout/padding/interpret plumbing)
ref.py              — pure-jnp oracles (the allclose ground truth)

The paper itself contributes no kernels (it is a profiler); these are
framework hot-spots identified by the tracer (EXPERIMENTS.md §Perf H3/H7).
"""
