"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the real device
count; multi-device tests spawn subprocesses with their own flags."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 560):
    """Run a python snippet with N forced host devices; return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={res.returncode}):\n{res.stderr[-4000:]}")
    return res.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
