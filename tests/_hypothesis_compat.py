"""Hypothesis shim: property tests degrade to fixed examples without it.

The tier-1 suite must collect and run on a bare interpreter (numpy + jax
only).  When `hypothesis` is installed we re-export it untouched; when it
is missing, `@given` runs the test body over a small deterministic sample
of each strategy (endpoints + interior points), and `@settings` is a no-op.
The fallback covers exactly the strategy surface this suite uses
(`integers`, `sampled_from`, plus a few neighbors for future tests) — it
is an execution floor, not a replacement for real property testing.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    from typing import Any, List, Sequence

    _MAX_EXAMPLES = 5

    class _Strategy:
        """A fixed, deterministic example list standing in for a strategy."""

        def __init__(self, examples: Sequence[Any]):
            self.examples: List[Any] = list(examples)

        def map(self, fn):
            return _Strategy([fn(x) for x in self.examples])

        def filter(self, pred):
            return _Strategy([x for x in self.examples if pred(x)])

    def _dedup(seq):
        out, seen = [], set()
        for x in seq:
            if x not in seen:
                seen.add(x)
                out.append(x)
        return out

    class _StrategiesShim:
        @staticmethod
        def integers(min_value: int = 0, max_value: int = 100) -> _Strategy:
            lo, hi = int(min_value), int(max_value)
            span = hi - lo
            return _Strategy(_dedup([
                lo, hi, lo + span // 2, lo + span // 3, lo + 2 * span // 3]))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            return _Strategy(list(elements))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy([False, True])

        @staticmethod
        def floats(min_value: float = 0.0, max_value: float = 1.0,
                   **_kw) -> _Strategy:
            lo, hi = float(min_value), float(max_value)
            return _Strategy(_dedup([lo, hi, (lo + hi) / 2]))

        @staticmethod
        def lists(elems: _Strategy, min_size: int = 0,
                  max_size: int = 4, **_kw) -> _Strategy:
            ex = elems.examples
            out = [list(ex[:n]) for n in range(min_size, min(max_size,
                                                             len(ex)) + 1)]
            return _Strategy(out or [list(ex[:min_size])])

        @staticmethod
        def tuples(*strats: _Strategy) -> _Strategy:
            n = max(len(s.examples) for s in strats) if strats else 0
            return _Strategy([tuple(s.examples[i % len(s.examples)]
                                    for s in strats) for i in range(n)])

    strategies = _StrategiesShim()

    def given(**param_strategies):
        """Run the test over zipped fixed examples (capped at a handful)."""

        def decorate(fn):
            inner = inspect.unwrap(fn)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = max((len(s.examples) for s in param_strategies.values()),
                        default=0)
                for i in range(min(n, _MAX_EXAMPLES)):
                    example = {name: s.examples[i % len(s.examples)]
                               for name, s in param_strategies.items()}
                    fn(*args, **kwargs, **example)

            # hide the generated params from pytest's fixture resolution
            sig = inspect.signature(inner)
            kept = [p for p in sig.parameters.values()
                    if p.name not in param_strategies]
            wrapper.__signature__ = sig.replace(parameters=kept)
            del wrapper.__wrapped__
            return wrapper

        return decorate

    def settings(*_a, **_kw):
        return lambda fn: fn
