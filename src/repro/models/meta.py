"""Parameter metadata: one abstract tree drives init, sharding and dry-run.

Models declare a tree of `ParamMeta` leaves (shape + *logical* axis names).
From that single tree we derive:
  * concrete initialized params        (`materialize`)
  * `jax.ShapeDtypeStruct` stand-ins   (`abstract`)           -- for the dry-run
  * `PartitionSpec`s via logical->mesh rules (`distributed.sharding`)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"                 # normal | zeros | ones
    scale: Optional[float] = None        # stddev override (default: fan-in)
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def _leaf_paths(tree, prefix=()):
    if is_meta(tree):
        yield prefix, tree
        return
    for k in sorted(tree):
        yield from _leaf_paths(tree[k], prefix + (k,))


def tree_map_meta(fn, tree):
    """Map over ParamMeta leaves, passing (path, meta)."""
    def rec(node, prefix):
        if is_meta(node):
            return fn(prefix, node)
        return {k: rec(v, prefix + (k,)) for k, v in node.items()}
    return rec(tree, ())


def _fold_path(key: jax.Array, path: Tuple[str, ...]) -> jax.Array:
    h = 2166136261
    for part in path:
        for ch in part.encode():
            h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return jax.random.fold_in(key, h)


def materialize(tree, key: jax.Array, param_dtype: str = "float32"):
    """Initialize a concrete params pytree from a meta tree."""

    def init_one(path, m: ParamMeta):
        dtype = jnp.dtype(param_dtype if m.dtype == "float32" else m.dtype)
        if m.init == "zeros":
            return jnp.zeros(m.shape, dtype)
        if m.init == "ones":
            return jnp.ones(m.shape, dtype)
        if m.init == "constant":
            return jnp.full(m.shape, m.scale or 0.0, dtype)
        if m.init == "a_log":
            # S4D-real init: A = -(1..N) per state channel
            n = m.shape[-1]
            a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), m.shape)
            return jnp.log(a).astype(dtype)
        fan_in = m.shape[-2] if len(m.shape) >= 2 else m.shape[-1]
        scale = m.scale if m.scale is not None else fan_in ** -0.5
        k = _fold_path(key, path)
        return (jax.random.normal(k, m.shape, jnp.float32) * scale).astype(dtype)

    return tree_map_meta(init_one, tree)


def abstract(tree, param_dtype: str = "float32"):
    """ShapeDtypeStruct tree (no allocation) for .lower()."""
    def one(_path, m: ParamMeta):
        dtype = jnp.dtype(param_dtype if m.dtype == "float32" else m.dtype)
        return jax.ShapeDtypeStruct(m.shape, dtype)
    return tree_map_meta(one, tree)


def logical_axes(tree):
    """Tree of logical-axis tuples, parallel to the params tree."""
    return tree_map_meta(lambda _p, m: m.logical, tree)


def param_count(tree) -> int:
    return sum(int(np.prod(m.shape)) for _, m in _leaf_paths(tree))


def param_bytes(tree, bytes_per_param: int = 4) -> int:
    return param_count(tree) * bytes_per_param
