"""llama3-405b — frontier-scale dense GQA decoder. [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    notes="pure full attention => long_500k skipped per assignment; "
          "train_4k requires grad accumulation + full remat on 256 chips",
)
