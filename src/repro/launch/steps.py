"""Step factories: train (fwd+bwd+AdamW, grad accumulation), prefill, decode.

All steps are pure jittable functions; `launch.dryrun` lowers them against
ShapeDtypeStructs, `launch.train`/`launch.serve` execute them.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.launch.presets import StepSettings
from repro.models import api as model_api
from repro.optim import adamw


def _split_micro(batch: Dict[str, jax.Array], accum: int):
    """[B, ...] -> [accum, B/accum, ...] per leaf (token/embed leaves only)."""
    def re(a):
        if a.ndim >= 1 and a.shape[0] % accum == 0 and a.shape[0] >= accum:
            return a.reshape(accum, a.shape[0] // accum, *a.shape[1:])
        return a
    out = {}
    for k, v in batch.items():
        if k == "positions":   # [3, B, S]
            out[k] = v.reshape(v.shape[0], accum, v.shape[1] // accum,
                               *v.shape[2:]).swapaxes(0, 1)
        else:
            out[k] = re(v)
    return out


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, st: StepSettings):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_for(params, micro):
        return model_api.loss_fn(cfg, params, micro,
                                 attn_impl=st.attn_impl, remat=st.remat)

    def train_step(params, opt_state, batch):
        if st.accum > 1:
            micro = _split_micro(batch, st.accum)
            acc_dt = jnp.dtype(st.accum_dtype)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_for)(params, mb)
                g = jax.tree.map(
                    lambda a, b: a + (b / st.accum).astype(a.dtype), g_acc, g)
                return (g, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros((), jnp.float32)), micro)
            loss = loss_sum / st.accum
        else:
            loss, grads = jax.value_and_grad(loss_for)(params, batch)

        if st.grad_compression == "bf16":
            # beyond-paper: cast the gradient before cross-device reduction
            # (halves grad-sync wire bytes; stochastic-rounding-free variant)
            with jax.named_scope("grad_compress"):
                grads = jax.tree.map(
                    lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)

        new_params, new_opt, metrics = adamw.update(opt_cfg, grads, opt_state,
                                                    params)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg, st: StepSettings):
    def eval_step(params, batch):
        return model_api.loss_fn(cfg, params, batch, attn_impl=st.attn_impl)
    return eval_step


def make_prefill_step(cfg, st: StepSettings, cache_len=None):
    def prefill_step(params, batch):
        return model_api.prefill(cfg, params, batch, attn_impl=st.attn_impl,
                                 cache_len=cache_len)
    return prefill_step


def make_decode_step(cfg, st: StepSettings):
    def decode_step(params, cache, tokens, pos, positions=None):
        return model_api.decode_step(cfg, params, cache, tokens, pos,
                                     positions=positions)
    return decode_step
